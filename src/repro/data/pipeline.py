"""Deterministic, resumable, host-sharded token pipeline.

Two sources:
  * ``SyntheticCorpus`` — counter-based (threefry) token stream: fully
    deterministic in (seed, step, position), no files, arbitrarily large.
    This is what dry-runs, tests and the e2e example train on.
  * ``MemmapCorpus``   — a flat uint16/uint32 token file, read via
    np.memmap with a strided cursor (the production path for real data).

Both expose: ``batch(step) -> {"tokens": [B_local, S]}`` where B_local is
this host's shard of the global batch, plus a ``cursor(step)`` that goes
into checkpoints so restarts resume exactly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticCorpus:
    def __init__(self, dc: DataConfig):
        self.dc = dc

    def batch(self, step: int) -> dict:
        dc = self.dc
        key = jax.random.fold_in(jax.random.PRNGKey(dc.seed), step)
        key = jax.random.fold_in(key, dc.host_index)
        toks = jax.random.randint(
            key, (dc.local_batch, dc.seq_len), 0, dc.vocab, jnp.int32)
        return {"tokens": toks}

    def cursor(self, step: int) -> dict:
        return {"kind": "synthetic", "seed": self.dc.seed, "step": step}

    @staticmethod
    def resume(dc: DataConfig, cursor: dict) -> tuple["SyntheticCorpus", int]:
        assert cursor["kind"] == "synthetic"
        return SyntheticCorpus(dataclasses.replace(dc, seed=cursor["seed"])), \
            cursor["step"]


class MemmapCorpus:
    def __init__(self, dc: DataConfig, path: str, dtype=np.uint16):
        self.dc = dc
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.n = len(self.tokens) // dc.seq_len

    def batch(self, step: int) -> dict:
        dc = self.dc
        # strided, host-disjoint rows; wraps deterministically
        base = step * dc.global_batch + dc.host_index * dc.local_batch
        rows = (base + np.arange(dc.local_batch)) % self.n
        out = np.stack([
            self.tokens[r * dc.seq_len:(r + 1) * dc.seq_len] for r in rows])
        return {"tokens": jnp.asarray(out.astype(np.int32) % dc.vocab)}

    def cursor(self, step: int) -> dict:
        return {"kind": "memmap", "step": step}


def make_corpus(dc: DataConfig, path: str | None = None):
    return MemmapCorpus(dc, path) if path else SyntheticCorpus(dc)
