"""Telemetry sinks: the protocol, in-memory, JSONL, and fan-out.

Counter names are dotted, namespaced by the emitting layer:

    engine.*      OverlayServer       (submits, rounds, delivered, ...)
    fleet.*       ShardedOverlayServer (submits, scale_ups, claims, ...)
    router.*      ResidencyRouter / WorkStealingRouter
    pump.*        AutoPump
    autoscaler.*  PressureAutoscaler
    edge.*        OverlayGateway

A counter that was never incremented reads as 0.0 — layers never have
to pre-register names.  `peak()` is a monotone-max gauge under the
same namespace (e.g. ``edge.peak_fleet_tiles``).

Events and step logs are for export, not for control flow: they ride a
bounded deque in memory and become JSON lines on a `JsonlSink`.
"""

from __future__ import annotations

import collections
import io
import json
import os
import threading
import time
from typing import Iterable, Protocol, runtime_checkable


@runtime_checkable
class Telemetry(Protocol):
    """What the serving layers require of a telemetry sink.

    Implementations must be thread-safe: the pump thread, the asyncio
    event loop, and caller threads all write concurrently.
    """

    def inc(self, name: str, value: float = 1.0) -> float:
        """Add ``value`` to counter ``name``; return the new total."""
        ...

    def peak(self, name: str, value: float) -> float:
        """Raise gauge ``name`` to at least ``value``; return the max."""
        ...

    def event(self, name: str, **fields) -> None:
        """Record a structured event (timestamped by the sink clock)."""
        ...

    def log_step(self, step: int, **metrics) -> None:
        """Record one step-log row (wandb-style: step + metric dict)."""
        ...

    def counter(self, name: str) -> float:
        """Read one counter/gauge; 0.0 if never written."""
        ...

    def counters(self, prefix: str = "") -> dict:
        """Snapshot all counters whose name starts with ``prefix``."""
        ...

    def reset(self, names: Iterable[str] = (), prefix: str | None = None) -> None:
        """Zero the named counters (and/or every ``prefix``-ed one)."""
        ...

    def flush(self) -> None:
        """Make buffered records durable (no-op for memory sinks)."""
        ...

    def close(self) -> None:
        """Flush and release resources; the sink stays readable."""
        ...


class InMemorySink:
    """Thread-safe in-memory sink; the default for every layer.

    Counters are exact under concurrency (one lock); events and step
    logs ride bounded deques so a hot loop can emit per-request events
    without growing memory without bound.
    """

    def __init__(self, clock=time.monotonic, max_events: int = 65536):
        self.clock = clock
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._events: collections.deque = collections.deque(maxlen=max_events)
        self._steps: collections.deque = collections.deque(maxlen=max_events)

    # ------------------------------------------------------------- write
    def inc(self, name: str, value: float = 1.0) -> float:
        with self._lock:
            new = self._counters.get(name, 0.0) + value
            self._counters[name] = new
            return new

    def peak(self, name: str, value: float) -> float:
        with self._lock:
            new = max(self._counters.get(name, value), value)
            self._counters[name] = new
            return new

    def event(self, name: str, **fields) -> None:
        rec = {"t": self.clock(), "name": name}
        rec.update(fields)
        with self._lock:
            self._events.append(rec)

    def log_step(self, step: int, **metrics) -> None:
        rec = {"t": self.clock(), "step": step}
        rec.update(metrics)
        with self._lock:
            self._steps.append(rec)

    # -------------------------------------------------------------- read
    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    def counters(self, prefix: str = "") -> dict:
        with self._lock:
            return {k: v for k, v in self._counters.items()
                    if k.startswith(prefix)}

    def events(self, name: str | None = None) -> list:
        with self._lock:
            evs = list(self._events)
        return evs if name is None else [e for e in evs if e["name"] == name]

    def steps(self) -> list:
        with self._lock:
            return list(self._steps)

    # ----------------------------------------------------------- control
    def reset(self, names: Iterable[str] = (), prefix: str | None = None) -> None:
        with self._lock:
            for n in names:
                self._counters[n] = 0.0
            if prefix is not None:
                for n in list(self._counters):
                    if n.startswith(prefix):
                        self._counters[n] = 0.0

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class JsonlSink:
    """Append-only JSON-lines sink with a crash-safe flush.

    Every event and step log becomes one JSON line the moment it is
    emitted; counters live in an internal `InMemorySink` (a line per
    `inc` would swamp the file on hot paths) and are snapshotted as a
    ``{"kind": "counters", ...}`` line on `flush()` / `close()`.
    `flush()` drains Python's buffer *and* fsyncs, so a crash after a
    flush loses nothing.

    Line schema (see docs/TELEMETRY.md):

        {"kind": "event", "t": ..., "name": ..., **fields}
        {"kind": "step",  "t": ..., "step": ..., **metrics}
        {"kind": "counters", "t": ..., "counters": {...}}
    """

    def __init__(self, path, clock=time.monotonic, max_events: int = 65536):
        self.path = os.fspath(path)
        self.mem = InMemorySink(clock=clock, max_events=max_events)
        self.clock = clock
        self._wlock = threading.Lock()
        self._closed = False
        self._f: io.TextIOWrapper | None = open(self.path, "a", encoding="utf-8")

    @property
    def closed(self) -> bool:
        """True once `close()` ran; the sink stays readable but writes,
        `flush()`, and further `close()` calls are no-ops."""
        return self._closed

    def _write(self, rec: dict) -> None:
        line = json.dumps(rec, sort_keys=True, default=str)
        with self._wlock:
            if self._closed or self._f is None:
                return
            if self._f.closed:          # handle closed out-of-band
                self._f = None
                return
            self._f.write(line + "\n")

    # ------------------------------------------------------------- write
    def inc(self, name: str, value: float = 1.0) -> float:
        return self.mem.inc(name, value)

    def peak(self, name: str, value: float) -> float:
        return self.mem.peak(name, value)

    def event(self, name: str, **fields) -> None:
        self.mem.event(name, **fields)
        rec = {"kind": "event", "t": self.clock(), "name": name}
        rec.update(fields)
        self._write(rec)

    def log_step(self, step: int, **metrics) -> None:
        self.mem.log_step(step, **metrics)
        rec = {"kind": "step", "t": self.clock(), "step": step}
        rec.update(metrics)
        self._write(rec)

    # -------------------------------------------------------------- read
    def counter(self, name: str) -> float:
        return self.mem.counter(name)

    def counters(self, prefix: str = "") -> dict:
        return self.mem.counters(prefix)

    def events(self, name: str | None = None) -> list:
        return self.mem.events(name)

    def steps(self) -> list:
        return self.mem.steps()

    # ----------------------------------------------------------- control
    def reset(self, names: Iterable[str] = (), prefix: str | None = None) -> None:
        self.mem.reset(names, prefix)

    def _snapshot_counters(self) -> None:
        counters = self.mem.counters()
        if counters:
            self._write({"kind": "counters", "t": self.clock(),
                         "counters": counters})

    def flush(self) -> None:
        """Drain Python's buffer and fsync.  A no-op after `close()` —
        flushing a closed sink must never raise on the dead handle."""
        if self._closed:
            return
        self._snapshot_counters()
        with self._wlock:
            self._fsync()

    def close(self) -> None:
        """Snapshot counters, flush, fsync, and close the file.
        Idempotent: a second `close()` (or a `flush()` after) is a
        no-op instead of a ``ValueError`` on the closed handle."""
        if self._closed:
            return
        self._snapshot_counters()
        with self._wlock:
            if self._closed:        # lost a close/close race
                return
            self._closed = True
            self._fsync()
            if self._f is not None:
                try:
                    self._f.close()
                finally:
                    self._f = None

    def _fsync(self) -> None:
        """Flush + fsync the live handle (holding ``_wlock``); tolerates
        a handle something else closed out from under the sink."""
        if self._f is None or self._f.closed:
            self._f = None
            return
        self._f.flush()
        os.fsync(self._f.fileno())


class MultiSink:
    """Fan writes out to several sinks; read through the first.

    The sharded fleet hands each replica ``MultiSink(own, fleet)``:
    the replica's `stats()` reads its own sink (first child) while the
    shared fleet sink accumulates the same increments across every
    replica that ever lived — which is exactly how retired replicas'
    rounds and deliveries survive `drain_replica` without hand-folded
    ``_retired_*`` attributes.
    """

    def __init__(self, *sinks):
        if not sinks:
            raise ValueError("MultiSink needs at least one child sink")
        self.sinks = tuple(sinks)

    # ------------------------------------------------------------- write
    def inc(self, name: str, value: float = 1.0) -> float:
        out = 0.0
        for i, s in enumerate(self.sinks):
            v = s.inc(name, value)
            if i == 0:
                out = v
        return out

    def peak(self, name: str, value: float) -> float:
        out = 0.0
        for i, s in enumerate(self.sinks):
            v = s.peak(name, value)
            if i == 0:
                out = v
        return out

    def event(self, name: str, **fields) -> None:
        for s in self.sinks:
            s.event(name, **fields)

    def log_step(self, step: int, **metrics) -> None:
        for s in self.sinks:
            s.log_step(step, **metrics)

    # -------------------------------------------------- read (first child)
    def counter(self, name: str) -> float:
        return self.sinks[0].counter(name)

    def counters(self, prefix: str = "") -> dict:
        return self.sinks[0].counters(prefix)

    def events(self, name: str | None = None) -> list:
        return self.sinks[0].events(name)

    def steps(self) -> list:
        return self.sinks[0].steps()

    # ----------------------------------------------------------- control
    def reset(self, names: Iterable[str] = (), prefix: str | None = None) -> None:
        # resets stay local to the primary: a replica zeroing its own
        # window must not erase the fleet's aggregate history
        self.sinks[0].reset(names, prefix)

    def flush(self) -> None:
        for s in self.sinks:
            s.flush()

    def close(self) -> None:
        for s in self.sinks:
            s.close()


def adopt_counters(dst: Telemetry, src: Telemetry, prefix: str = "") -> None:
    """Fold ``src``'s counters (under ``prefix``) into ``dst``.

    Used when a component built with its own private sink is later
    bound to a shared one (e.g. a router or autoscaler handed to a
    fleet): whatever it counted pre-binding carries over.
    """
    for name, value in src.counters(prefix).items():
        if value:
            dst.inc(name, value)


def read_jsonl(path) -> list:
    """Parse a `JsonlSink` file back into a list of record dicts."""
    out = []
    with open(os.fspath(path), "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
