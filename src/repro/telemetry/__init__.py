"""Structured telemetry for the serving stack.

One small protocol (`Telemetry`) carries every counter, peak gauge,
event, and step-log the serving layers emit.  The engines, the fleet,
the pump, the autoscaler, the routers, and the asyncio gateway all
write through a sink instead of growing private ``n_foo`` integers, so
`stats()` on each layer is a read-through over one store and the
conservation invariants (submitted == delivered + pending, scale_ups -
scale_downs == replicas - initial, ...) can be asserted from the
outside at any barrier.

Sinks:

- `InMemorySink` — thread-safe dict of counters plus bounded deques of
  events and step logs; the default everywhere.
- `JsonlSink` — append-only JSON-lines file with a crash-safe
  `flush()` (fsync); wraps an in-memory sink so counter reads stay
  cheap and exact.
- `MultiSink` — fan-out writes to several sinks, reads from the first.
  The sharded fleet gives each replica ``MultiSink(own, fleet_sink)``
  so per-replica stats and fleet aggregates come from one write.

See docs/TELEMETRY.md for the naming scheme and the JSONL schema.
"""

from repro.telemetry.sinks import (
    InMemorySink,
    JsonlSink,
    MultiSink,
    Telemetry,
    adopt_counters,
    read_jsonl,
)
from repro.telemetry.schema import (
    AUTOSCALER_STATS_KEYS,
    BANK_STATS_KEYS,
    ENGINE_STATS_KEYS,
    FLEET_STATS_KEYS,
    GATEWAY_STATS_KEYS,
    PUMP_STATS_KEYS,
    ROUTER_STATS_KEYS,
    SOCKET_STATS_KEYS,
    STEAL_STATS_KEYS,
    TRAIN_STATS_KEYS,
    check_stats,
)

__all__ = [
    "Telemetry",
    "InMemorySink",
    "JsonlSink",
    "MultiSink",
    "adopt_counters",
    "read_jsonl",
    "check_stats",
    "BANK_STATS_KEYS",
    "ENGINE_STATS_KEYS",
    "FLEET_STATS_KEYS",
    "GATEWAY_STATS_KEYS",
    "PUMP_STATS_KEYS",
    "ROUTER_STATS_KEYS",
    "SOCKET_STATS_KEYS",
    "STEAL_STATS_KEYS",
    "TRAIN_STATS_KEYS",
    "AUTOSCALER_STATS_KEYS",
]
