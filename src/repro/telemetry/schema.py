"""Source-of-truth schema for the serving layers' ``stats()`` dicts.

PRs 2-6 each grew their layer's ``stats()`` by hand; the key sets had
no owner, so a rename or accidental drop surfaced only when a
benchmark's pretty-printer threw a KeyError.  These frozensets are the
contract: `tests/test_stats_schema.py` asserts every layer's stats()
matches them exactly, and CI pydoc-smokes this module so the docs
can't reference keys that don't exist.

Composition mirrors the layering:

- engine stats   = BANK ∪ ENGINE            (OverlayServer.stats)
- fleet stats    = FLEET ∪ ROUTER [∪ STEAL] [∪ AUTOSCALER]
- pump stats     = wrapped server stats ∪ PUMP   (AutoPump.stats)
- gateway stats  = GATEWAY (with ``fleet`` holding the pump's dict)
"""

from __future__ import annotations

# ContextBank.stats(), folded into every engine stats() dict.  ``arena``
# nests the attached RoundArena's occupancy/recycle counters (None when
# the bank serves no pooled rounds) — a leaking arena bucket shows up as
# ``outstanding`` never returning to zero.
BANK_STATS_KEYS = frozenset({
    "capacity", "resident", "free", "loads", "evictions", "hits",
    "pinned", "generation", "ctx_cache", "occupancy", "pinned_fraction",
    "arena",
})

# OverlayServer.stats() minus the bank keys.  ``stage_walls`` nests the
# cumulative plan_s/assemble_s/execute_s/collect_s pipeline walls.
ENGINE_STATS_KEYS = frozenset({
    "submits", "rounds", "requests", "pending", "inflight", "queued",
    "queued_tiles", "tenants", "round_policy", "stage_walls",
    "tenant_latency",
})

# ResidencyRouter.stats(); WorkStealingRouter adds STEAL_STATS_KEYS.
ROUTER_STATS_KEYS = frozenset({
    "router", "route_hits", "route_misses", "residency_hit_rate",
    "migrations", "steals", "directory",
})
STEAL_STATS_KEYS = frozenset({"stolen_requests"})

# PressureAutoscaler.stats(), merged into fleet stats when attached.
AUTOSCALER_STATS_KEYS = frozenset({
    "autoscaler", "up_tiles", "up_rounds", "down_rounds", "cooldown_s",
    "min_replicas", "max_replicas", "observations", "up_decisions",
    "down_decisions", "hot_streak", "scale_up_pending", "saturated",
    "saturated_observations",
})

# ShardedOverlayServer.stats() minus router/autoscaler keys.
# ``stage_walls`` aggregates the whole fleet (replicas write through
# MultiSink to the fleet sink, so drained replicas' walls survive).
FLEET_STATS_KEYS = frozenset({
    "replicas", "submits", "pending", "queue_depth", "queued_tiles",
    "per_replica", "rounds", "requests", "evictions", "scale_ups",
    "scale_downs", "evacuated_requests", "evacuated_tiles",
    "replicas_retired", "retired_lifetime_s", "peak_replicas",
    "orphaned_results", "orphan_claims", "claims", "stage_walls",
    "tenant_latency",
})

# AutoPump.stats() adds these on top of the wrapped server's dict.
PUMP_STATS_KEYS = frozenset({
    "pump_rounds", "pump_alive", "pump_listeners", "pump_listener_errors",
})

# OverlayGateway.stats(); ``fleet`` nests the pump's stats dict.
GATEWAY_STATS_KEYS = frozenset({
    "edge_attempts", "edge_submitted", "edge_shed", "edge_queued",
    "edge_park_cancelled", "edge_waiters", "peak_edge_waiters",
    "peak_fleet_tiles", "max_fleet_tiles", "window", "widened_ticks",
    "connections", "connects", "disconnects", "orphan_sessions",
    "orphaned_tickets", "orphaned_results_held", "orphans_expired",
    "max_orphan_sessions", "reclaimed", "outstanding", "fleet",
})

# OverlaySocketServer.stats(); ``gateway`` nests the gateway's dict.
SOCKET_STATS_KEYS = frozenset({
    "listening", "open_connections", "registered_kernels",
    "wire_frames_in", "wire_frames_out", "wire_bytes_in",
    "wire_bytes_out", "wire_handshakes", "wire_registers",
    "wire_rejects", "wire_connections", "wire_disconnects",
    "wire_reparked", "gateway",
})

# TrainingTenant.stats() (launch.trainer_tenant): the co-scheduled
# training run's own counters.  ``steps``/``micro_rounds`` advance per
# committed yield point; ``preemptions``/``resumes`` count the
# between-micro-step yields to latency traffic and the submits that
# pick the run back up (paired 1:1 once the run finishes);
# ``yield_wall_s`` is host wall spent inside micro-rounds.
TRAIN_STATS_KEYS = frozenset({
    "tenant", "steps", "total_steps", "micro_rounds", "preemptions",
    "resumes", "yield_wall_s", "last_loss", "done", "outstanding",
})

_KINDS = {
    "engine": (BANK_STATS_KEYS | ENGINE_STATS_KEYS, PUMP_STATS_KEYS),
    "fleet": (FLEET_STATS_KEYS | ROUTER_STATS_KEYS,
              STEAL_STATS_KEYS | AUTOSCALER_STATS_KEYS | PUMP_STATS_KEYS),
    "gateway": (GATEWAY_STATS_KEYS, frozenset()),
    "socket": (SOCKET_STATS_KEYS, frozenset()),
    "train": (TRAIN_STATS_KEYS, frozenset()),
}


def check_stats(kind: str, stats: dict) -> None:
    """Assert ``stats`` matches the schema for ``kind``.

    ``kind`` is ``"engine"``, ``"fleet"``, ``"gateway"``,
    ``"socket"``, or ``"train"``.  Every
    required key must be present and no key outside required ∪ optional
    may appear; raises ``AssertionError`` naming the drift either way.
    """
    if kind not in _KINDS:
        raise ValueError(f"unknown stats kind {kind!r}")
    required, optional = _KINDS[kind]
    keys = set(stats)
    missing = required - keys
    extra = keys - required - optional
    assert not missing, f"{kind} stats() missing keys: {sorted(missing)}"
    assert not extra, f"{kind} stats() has undeclared keys: {sorted(extra)}"
