"""Elastic scaling + straggler mitigation (simulated on CPU, mesh-real).

Node-failure recovery path:
  1. a device set shrinks (simulated by dropping devices from the list),
  2. ``remesh`` builds the largest consistent (data, model) mesh from the
     survivors (keeping the model axis intact when possible),
  3. ``reshard_tree`` re-device_puts the last checkpoint onto the new mesh
     with freshly derived PartitionSpecs,
  4. training resumes; the data pipeline cursor comes from the checkpoint.

Straggler mitigation: at scale the slowest data-parallel worker sets the
step time.  ``straggler_scale`` implements deadline-skip with gradient
rescaling — microbatches that miss the deadline are dropped and the
summed gradient is rescaled by kept/total so the estimator stays unbiased
(bounded staleness).  The deadline signal is an input (on TPU pods it
comes from host-side timers), which keeps the function pure/jittable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_mesh_from_devices


def remesh(devices, model_parallel: int = 16):
    """Largest consistent mesh from the surviving device list."""
    return make_mesh_from_devices(devices, model_parallel)


def reshard_tree(tree, spec_tree, mesh):
    """device_put every leaf onto ``mesh`` with its PartitionSpec."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree, spec_tree,
                        is_leaf=lambda x: not isinstance(x, (dict, list)))


def straggler_scale(grads_sum, kept: jax.Array, total: int):
    """Rescale a sum-of-microbatch gradient after deadline skips.

    grads_sum = sum over kept microbatches; kept = how many arrived.
    Returns the unbiased mean-equivalent gradient."""
    scale = jnp.where(kept > 0, 1.0 / jnp.maximum(kept, 1), 0.0)
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads_sum)


def accumulate_with_deadline(grad_fn, params, microbatches, arrived_mask):
    """Gradient accumulation that skips 'late' microbatches.

    arrived_mask [M] bool — which microbatches met the deadline (in a real
    deployment this comes from per-worker heartbeats; tests drive it).
    """
    M = arrived_mask.shape[0]

    def body(carry, xs):
        acc, kept = carry
        mb, ok = xs
        g = grad_fn(params, mb)
        acc = jax.tree.map(
            lambda a, gi: a + jnp.where(ok, gi, jnp.zeros_like(gi)), acc, g)
        return (acc, kept + ok.astype(jnp.int32)), None

    zeros = jax.tree.map(jnp.zeros_like, params)
    (acc, kept), _ = jax.lax.scan(
        body, (zeros, jnp.zeros((), jnp.int32)),
        (microbatches, arrived_mask))
    return straggler_scale(acc, kept, M), kept
