"""Sharded checkpoint/restore with integrity manifest + async writes.

Layout:  <dir>/step_<N>/
            manifest.json       tree structure, shapes, dtypes, crc32 per leaf
            <leaf-name>.npy     one file per pytree leaf

Design points for 1000+ nodes (documented; exercised here single-host):
  * each host writes only the leaves (or leaf shards) it owns — the leaf
    files here are written from fully-addressable arrays, the multi-host
    variant writes `leaf.<shard>.npy` per process with the same manifest;
  * writes go to a temp dir + atomic rename, so a failure mid-save never
    corrupts the latest-good checkpoint;
  * async: `save_async` snapshots to host memory (device_get) then writes
    on a worker thread, double-buffered so at most one write is in flight;
  * restore verifies crc32 per leaf and can re-shard onto a DIFFERENT mesh
    (elastic restart path: distributed/elastic.py).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib

import jax
import numpy as np

_SAFE = re.compile(r"[^a-zA-Z0-9_.-]+")

#: dtypes numpy's npy format can't express — stored as same-width uints
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _leaf_name(path) -> str:
    return _SAFE.sub("_", jax.tree_util.keystr(path)).strip("_") or "root"


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = [_leaf_name(p) for p, _ in leaves]
    assert len(set(names)) == len(names), "leaf name collision"
    return names, [v for _, v in leaves]


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Synchronous checkpoint write. Returns the checkpoint path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    names, leaves = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if logical in _EXOTIC:   # npy can't round-trip bf16/f8 portably
            arr = arr.view(_EXOTIC[logical])
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"][name] = {
            "shape": list(arr.shape), "dtype": logical,
            "crc32": zlib.crc32(arr.tobytes()),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Double-buffered async writer: snapshot on call, write on a thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree, extra=None):
        self.wait()   # at most one write in flight
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            save(self.ckpt_dir, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def restore(ckpt_dir: str, tree_like, step: int | None = None,
            shardings=None, verify: bool = True):
    """Restore into the structure of ``tree_like``; optionally re-shard.

    ``shardings``: optional pytree of NamedSharding for the TARGET mesh —
    this is the elastic-restart path (checkpoint written on mesh A,
    restored onto mesh B).
    Returns (tree, step, extra)."""
    steps = list_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step = steps[-1] if step is None else step
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves = _flatten(tree_like)
    paths, treedef = jax.tree_util.tree_flatten(tree_like)
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(names))
    for name, like, shd in zip(names, leaves, shard_leaves):
        meta = manifest["leaves"][name]
        arr = np.load(os.path.join(path, name + ".npy"))
        if verify and zlib.crc32(arr.tobytes()) != meta["crc32"]:
            raise IOError(f"checksum mismatch for leaf {name}")
        if meta["dtype"] in _EXOTIC:
            import ml_dtypes
            arr = arr.view(getattr(ml_dtypes, meta["dtype"]))
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {like.shape}")
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step, \
        manifest.get("extra", {})
