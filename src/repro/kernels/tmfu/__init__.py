from repro.kernels.tmfu.ops import tmfu_pipeline
from repro.kernels.tmfu.kernel import tmfu_pipeline_rf
from repro.kernels.tmfu.ref import tmfu_ref

__all__ = ["tmfu_pipeline", "tmfu_pipeline_rf", "tmfu_ref"]
