from repro.kernels.tmfu.ops import tmfu_pipeline, tmfu_pipeline_multi
from repro.kernels.tmfu.kernel import tmfu_pipeline_rf, tmfu_pipeline_rf_multi
from repro.kernels.tmfu.ref import tmfu_ref

__all__ = ["tmfu_pipeline", "tmfu_pipeline_multi", "tmfu_pipeline_rf",
           "tmfu_pipeline_rf_multi", "tmfu_ref"]
