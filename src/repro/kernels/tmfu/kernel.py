"""Pallas TPU kernel: the time-multiplexed FU pipeline (paper Fig. 2/3).

Mapping of the paper's FU onto the TPU memory hierarchy:

  * Instruction memory (32x32 RAM32M)  -> int32 words in SMEM, delivered by
    scalar prefetch (PrefetchScalarGridSpec) so the VPU datapath never
    stalls on instruction fetch — the analogue of the FU's dedicated IM.
  * Register file (32-entry RAM32M)    -> a (32, bt) VMEM scratch buffer;
    'bt' lanes execute the same instruction on independent kernel
    iterations (vectorized pipeline replication, paper Fig. 4).
  * DSP48E1 + config bits, no decoder  -> jax.lax.switch branch table on the
    5-bit opcode field; operands gathered by dynamic row index (the 5-bit
    RF addresses).
  * Linear FU->FU interconnect         -> stage loop ping-ponging two VMEM
    buffers: stage s writes its full result stream, which IS stage s+1's
    register file (direct connection, no programmable routing).

The grid tiles the batch; each grid step streams one (32, bt) tile through
all S stages.  Immediates ride in SMEM as int32 bit-patterns of the f32
constants (bitcast back inside the kernel) so every context word stays a
plain 32-bit integer, like the hardware's 40-bit context stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.isa import IM_DEPTH, RF_DEPTH

DEFAULT_BLOCK_BATCH = 512


def _branch_table(dtype):
    """Opcode-indexed branch table; operands are (1, bt) vectors."""
    def _bitwise(fn):
        def g(va, vb, cv):
            if jnp.issubdtype(dtype, jnp.floating):
                it = jnp.int32 if dtype.itemsize == 4 else jnp.int16
                ia = jax.lax.bitcast_convert_type(va, it)
                ib = jax.lax.bitcast_convert_type(vb, it)
                return jax.lax.bitcast_convert_type(fn(ia, ib), dtype)
            return fn(va, vb)
        return g

    return [
        lambda va, vb, cv: va,                     # BYP
        lambda va, vb, cv: va + vb,                # ADD
        lambda va, vb, cv: va - vb,                # SUB
        lambda va, vb, cv: va * vb,                # MUL
        lambda va, vb, cv: va + cv,                # ADDC
        lambda va, vb, cv: va - cv,                # SUBC
        lambda va, vb, cv: cv - va,                # RSUBC
        lambda va, vb, cv: va * cv,                # MULC
        lambda va, vb, cv: va * va,                # SQR
        lambda va, vb, cv: jnp.maximum(va, vb),    # MAX
        lambda va, vb, cv: jnp.minimum(va, vb),    # MIN
        lambda va, vb, cv: jnp.abs(va),            # ABS
        lambda va, vb, cv: -va,                    # NEG
        _bitwise(jnp.bitwise_and),                 # AND
        _bitwise(jnp.bitwise_or),                  # OR
        _bitwise(jnp.bitwise_xor),                 # XOR
        lambda va, vb, cv: va,                     # OUT
        lambda va, vb, cv: jnp.zeros_like(va),     # NOP
    ]


def _run_stages(pfx, op_ref, a_ref, b_ref, imm_ref, rf_a, rf_b,
                *, n_stages: int, dtype):
    """Shared stage loop: run ``n_stages`` over the ping-pong RF buffers.

    ``pfx`` prefixes every SMEM instruction fetch — ``()`` for the
    single-context [S, IM] layout, ``(cid,)`` for the stacked multi-tenant
    [N, S, IM] bank — so the two datapaths cannot drift apart.
    """
    branches = _branch_table(dtype)
    is_float = jnp.issubdtype(dtype, jnp.floating)

    def stage_body(s, _):
        # ping-pong: even stages read rf_a/write rf_b, odd the reverse
        def instr_body(i, _):
            va_a = pl.load(rf_a, (pl.ds(a_ref[(*pfx, s, i)], 1), slice(None)))
            va_b = pl.load(rf_b, (pl.ds(a_ref[(*pfx, s, i)], 1), slice(None)))
            vb_a = pl.load(rf_a, (pl.ds(b_ref[(*pfx, s, i)], 1), slice(None)))
            vb_b = pl.load(rf_b, (pl.ds(b_ref[(*pfx, s, i)], 1), slice(None)))
            even = s % 2 == 0
            va = jnp.where(even, va_a, va_b)
            vb = jnp.where(even, vb_a, vb_b)
            raw = imm_ref[(*pfx, s, i)]
            if is_float:
                cv = jax.lax.bitcast_convert_type(
                    raw, jnp.float32).astype(dtype)
            else:
                cv = raw.astype(dtype)
            res = jax.lax.switch(op_ref[(*pfx, s, i)], branches, va, vb, cv)

            @pl.when(even)
            def _():
                pl.store(rf_b, (pl.ds(i, 1), slice(None)), res)

            @pl.when(jnp.logical_not(even))
            def _():
                pl.store(rf_a, (pl.ds(i, 1), slice(None)), res)
            return 0

        jax.lax.fori_loop(0, op_ref.shape[-1], instr_body, 0)
        return 0

    jax.lax.fori_loop(0, n_stages, stage_body, 0)


def _tmfu_kernel(op_ref, a_ref, b_ref, imm_ref,   # scalar-prefetch (SMEM)
                 x_ref, o_ref,                    # VMEM in/out tiles
                 rf_a, rf_b,                      # VMEM scratch (ping-pong)
                 *, n_stages: int, dtype):
    rf_a[...] = x_ref[...]
    _run_stages((), op_ref, a_ref, b_ref, imm_ref, rf_a, rf_b,
                n_stages=n_stages, dtype=dtype)
    # after S stages the live RF is rf_a if S even else rf_b
    if n_stages % 2 == 0:
        o_ref[...] = rf_a[...]
    else:
        o_ref[...] = rf_b[...]


def _tmfu_kernel_multi(ids_ref, op_ref, a_ref, b_ref, imm_ref,  # SMEM
                       x_ref, o_ref,                    # VMEM in/out tiles
                       rf_a, rf_b,                      # VMEM scratch
                       *, n_stages: int, dtype):
    """Multi-tenant TMFU: grid step g executes context ``ids_ref[g]``.

    The instruction bank rides in SMEM as stacked [N, S, IM] arrays; the
    per-tile context id is a scalar-prefetch operand, so selecting a kernel
    is an SMEM row offset — the serving analogue of pointing the FU at a
    different daisy-chained context, with zero recompilation.
    """
    cid = ids_ref[pl.program_id(0)]
    rf_a[...] = x_ref[0]
    _run_stages((cid,), op_ref, a_ref, b_ref, imm_ref, rf_a, rf_b,
                n_stages=n_stages, dtype=dtype)
    if n_stages % 2 == 0:
        o_ref[...] = rf_a[...][None]
    else:
        o_ref[...] = rf_b[...][None]


def _tmfu_rf_multi(op, src_a, src_b, imm_i32, ctx_ids, x,
                   interpret: bool, alias_x: bool):
    """Shared pallas_call builder for the multi-tenant RF pipeline.

    ``alias_x`` maps operand 5 (the [G, RF_DEPTH, T] tile stack — same
    shape/dtype as the output) onto output 0 via ``input_output_aliases``,
    so the donated input allocation IS the result buffer.  Operand indices
    count the scalar-prefetch operands: (ctx_ids, op, src_a, src_b, imm) =
    0..4, x = 5.
    """
    n_bank, n_stages, im = op.shape
    n_tiles, rf_depth, tile = x.shape
    assert rf_depth == RF_DEPTH and im == IM_DEPTH
    assert ctx_ids.shape == (n_tiles,)
    dtype = x.dtype

    kernel = functools.partial(_tmfu_kernel_multi, n_stages=n_stages,
                               dtype=dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(n_tiles,),
            in_specs=[pl.BlockSpec((1, RF_DEPTH, tile),
                                   lambda g, *_: (g, 0, 0))],
            out_specs=pl.BlockSpec((1, RF_DEPTH, tile),
                                   lambda g, *_: (g, 0, 0)),
            scratch_shapes=[pltpu.VMEM((RF_DEPTH, tile), dtype),
                            pltpu.VMEM((RF_DEPTH, tile), dtype)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_tiles, RF_DEPTH, tile), dtype),
        input_output_aliases={5: 0} if alias_x else {},
        interpret=interpret,
    )(ctx_ids, op, src_a, src_b, imm_i32, x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tmfu_pipeline_rf_multi(op, src_a, src_b, imm_i32, ctx_ids, x,
                           interpret: bool = True):
    """Run a mixed-context tile batch: x [G, RF_DEPTH, T] -> [G, RF_DEPTH, T].

    op/src_a/src_b/imm_i32: stacked bank arrays [N, S, IM] int32;
    ctx_ids: [G] int32 selecting the context for each batch tile.  One
    pallas_call, one executable, any mix of resident kernels.
    """
    return _tmfu_rf_multi(op, src_a, src_b, imm_i32, ctx_ids, x,
                          interpret=interpret, alias_x=False)


@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=(5,))
def tmfu_pipeline_rf_multi_donated(op, src_a, src_b, imm_i32, ctx_ids, x,
                                   interpret: bool = True):
    """``tmfu_pipeline_rf_multi`` with the tile stack donated AND aliased.

    The [G, RF_DEPTH, T] input has exactly the output's shape and dtype,
    so ``input_output_aliases`` lets the round's staging allocation be
    reused as its result — zero extra device buffers per round.  Caller
    contract: ``x`` is dead after this call (the serving engines consume
    each batch exactly once; see ``Overlay(donate=True)``).
    """
    return _tmfu_rf_multi(op, src_a, src_b, imm_i32, ctx_ids, x,
                          interpret=interpret, alias_x=True)


@functools.partial(jax.jit, static_argnames=("block_batch", "interpret"))
def tmfu_pipeline_rf(op, src_a, src_b, imm_i32, x,
                     block_batch: int = DEFAULT_BLOCK_BATCH,
                     interpret: bool = True):
    """Run the overlay pipeline: x [RF_DEPTH, B] -> final RF [RF_DEPTH, B].

    op/src_a/src_b: [S, IM_DEPTH] int32; imm_i32: int32 bit-patterns of the
    f32 immediates (or raw ints for integer datapaths).  B must be a
    multiple of ``block_batch``.
    """
    n_stages, im = op.shape
    rf_depth, batch = x.shape
    assert rf_depth == RF_DEPTH and im == IM_DEPTH
    assert batch % block_batch == 0, (batch, block_batch)
    dtype = x.dtype

    grid = (batch // block_batch,)
    kernel = functools.partial(_tmfu_kernel, n_stages=n_stages, dtype=dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[pl.BlockSpec((RF_DEPTH, block_batch),
                                   lambda t, *_: (0, t))],
            out_specs=pl.BlockSpec((RF_DEPTH, block_batch),
                                   lambda t, *_: (0, t)),
            scratch_shapes=[pltpu.VMEM((RF_DEPTH, block_batch), dtype),
                            pltpu.VMEM((RF_DEPTH, block_batch), dtype)],
        ),
        out_shape=jax.ShapeDtypeStruct((RF_DEPTH, batch), dtype),
        interpret=interpret,
    )(op, src_a, src_b, imm_i32, x)
