"""Pure-jnp oracle for the TMFU pipeline kernel.

Executes the encoded overlay context with plain Python loops over stages and
instruction slots — bit-identical semantics to the hardware model: every
instruction result streams to slot *i* of the next stage's register file.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dfg import Op


def _apply(opc: int, va, vb, imm, dtype):
    o = Op(int(opc))
    if o in (Op.BYP, Op.OUT):
        return va
    if o == Op.ADD:
        return va + vb
    if o == Op.SUB:
        return va - vb
    if o == Op.MUL:
        return va * vb
    if o == Op.ADDC:
        return va + imm
    if o == Op.SUBC:
        return va - imm
    if o == Op.RSUBC:
        return imm - va
    if o == Op.MULC:
        return va * imm
    if o == Op.SQR:
        return va * va
    if o == Op.MAX:
        return jnp.maximum(va, vb)
    if o == Op.MIN:
        return jnp.minimum(va, vb)
    if o == Op.ABS:
        return jnp.abs(va)
    if o == Op.NEG:
        return -va
    if o in (Op.AND, Op.OR, Op.XOR):
        fn = {Op.AND: jnp.bitwise_and, Op.OR: jnp.bitwise_or,
              Op.XOR: jnp.bitwise_xor}[o]
        if jnp.issubdtype(dtype, jnp.floating):
            it = jnp.int32 if dtype.itemsize == 4 else jnp.int16
            ia = jax.lax.bitcast_convert_type(va, it)
            ib = jax.lax.bitcast_convert_type(vb, it)
            return jax.lax.bitcast_convert_type(fn(ia, ib), dtype)
        return fn(va, vb)
    if o == Op.NOP:
        return jnp.zeros_like(va)
    raise ValueError(f"bad opcode {opc}")


def tmfu_ref(op: np.ndarray, src_a: np.ndarray, src_b: np.ndarray,
             imm: np.ndarray, x: jax.Array) -> jax.Array:
    """Reference: x [RF_DEPTH, batch] -> final RF [RF_DEPTH, batch]."""
    S, I = op.shape
    rf = jnp.asarray(x)
    dtype = rf.dtype
    for s in range(S):
        outs = []
        for i in range(I):
            va = rf[int(src_a[s, i])]
            vb = rf[int(src_b[s, i])]
            outs.append(_apply(op[s, i], va, vb,
                               jnp.asarray(imm[s, i], dtype), dtype))
        rf = jnp.stack(outs)
    return rf
