"""Public jit'd wrapper for the TMFU pipeline kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.isa import RF_DEPTH
from repro.kernels.tmfu.kernel import (DEFAULT_BLOCK_BATCH,
                                       tmfu_pipeline_rf,
                                       tmfu_pipeline_rf_multi,
                                       tmfu_pipeline_rf_multi_donated)


def _imm_to_i32(imm: jax.Array) -> jax.Array:
    """Pack immediates as int32 context words (bitcast f32 for float paths)."""
    if jnp.issubdtype(imm.dtype, jnp.floating):
        return jax.lax.bitcast_convert_type(
            imm.astype(jnp.float32), jnp.int32)
    return imm.astype(jnp.int32)


def tmfu_pipeline(ctx, x: jax.Array,
                  block_batch: int = DEFAULT_BLOCK_BATCH,
                  interpret: bool | None = None) -> jax.Array:
    """Execute an overlay Context on the Pallas datapath.

    ctx: repro.core.vm.Context;  x: [RF_DEPTH, batch] input RF image.
    Returns the primary outputs, shape [n_outputs, batch].
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rf_depth, batch = x.shape
    assert rf_depth == RF_DEPTH
    bt = min(block_batch, _round_up(batch, 128))
    padded = _round_up(batch, bt)
    if padded != batch:
        x = jnp.pad(x, ((0, 0), (0, padded - batch)))
    rf = tmfu_pipeline_rf(ctx.op, ctx.src_a, ctx.src_b,
                          _imm_to_i32(ctx.imm), x,
                          block_batch=bt, interpret=interpret)
    return rf[ctx.out_idx, :batch]


def tmfu_pipeline_multi(bank, ctx_ids: jax.Array, x: jax.Array,
                        interpret: bool | None = None,
                        donate: bool = False) -> jax.Array:
    """Execute a mixed-context tile batch on the Pallas datapath.

    bank: repro.core.bank.ContextBank; ctx_ids: [G] int32 slot ids;
    x: [G, RF_DEPTH, tile].  Returns [G, max_outputs, tile] — each tile's
    rows gathered through its selected context's output slots (callers
    slice to the kernel's real n_outputs).

    ``donate=True`` hands ``x`` to the pipeline for in-place reuse
    (``input_output_aliases`` — the RF stack has exactly the input's
    shape); ``x`` is dead afterwards, so only consume-once callers (the
    serving engines) may set it.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    op, src_a, src_b, imm = bank.tree()
    rf_fn = tmfu_pipeline_rf_multi_donated if donate else tmfu_pipeline_rf_multi
    rf = rf_fn(op, src_a, src_b, _imm_to_i32(imm),
               ctx_ids.astype(jnp.int32), x, interpret=interpret)
    out_rows = bank.out_idx[ctx_ids]                       # [G, max_out]
    return jnp.take_along_axis(rf, out_rows[:, :, None], axis=1)


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m
