"""Gradient compression (int8 + error feedback) for the DP all-reduce.

At 1000+ nodes the data-parallel gradient all-reduce crosses DCN/pod links;
8-bit quantization with per-tensor scale cuts those bytes 4x.  Error
feedback accumulates the quantization residual so the update stays unbiased
over time (1-bit-Adam-style analysis applies).

The hook quantizes+dequantizes around the (implicit, XLA-inserted)
all-reduce; on real hardware the cast happens before the collective, so
the wire bytes are int8.  The ``ef`` pytree mirrors the grads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads, ef=None):
    """Quantize grads to int8 (+error feedback).  Returns (grads', ef')."""
    if ef is None:
        ef = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize(gf)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(one, grads, ef)
    new_grads = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_ef
