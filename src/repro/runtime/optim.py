"""AdamW + cosine schedule, pure JAX, states sharded like params (ZeRO)."""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def schedule(oc: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(oc.warmup_steps, 1)
    prog = (step - oc.warmup_steps) / jnp.maximum(
        oc.total_steps - oc.warmup_steps, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(prog, 0, 1)))
    return oc.lr * jnp.where(step < oc.warmup_steps, warm, 0.1 + 0.9 * cos)


def init_opt(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "count": jnp.zeros((), jnp.int32)}


def init_opt_mixed(params_bf16):
    """Mixed precision: bf16 working params + f32 master/moments.

    Halves the FSDP weight-gather and gradient all-reduce wire bytes (the
    collectives run on the bf16 tensors); the update itself stays f32.
    """
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params_bf16)
    return {"m": jax.tree.map(jnp.zeros_like, master),
            "v": jax.tree.map(jnp.zeros_like, master),
            "master": master,
            "count": jnp.zeros((), jnp.int32)}


def adamw_update_mixed(oc: OptConfig, grads_bf16, state, _params_bf16):
    """AdamW on the f32 master; returns fresh bf16 working params."""
    new_master, sub, stats = adamw_update(
        oc, grads_bf16, {k: state[k] for k in ("m", "v", "count")},
        state["master"])
    new_params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), new_master)
    return new_params, {**sub, "master": new_master}, stats


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(oc: OptConfig, grads, state, params):
    """Returns (new_params, new_state, stats)."""
    count = state["count"] + 1
    lr = schedule(oc, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    b1, b2 = oc.b1, oc.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                     state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                     state["v"], grads)
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(p, m_, v_):
        mh = m_ / bc1
        vh = v_ / bc2
        step = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p
        return (p - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
