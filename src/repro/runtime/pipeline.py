"""Linear time-multiplexed stage pipeline over devices (paper Fig. 2 at
cluster scale).

The overlay's architecture maps 1:1 onto pipeline parallelism:

  FPGA overlay                      this runtime
  ------------------------------    ----------------------------------------
  linear array of S TM-FUs          S pipeline stages on a 1-D mesh axis
  FU executes its stage's ops       stage executes its slice of layers
  direct FU->FU link (no routing)   lax.ppermute to the next neighbour only
  data packets streaming in         M microbatches streaming in
  II = bottleneck-stage cycles      II = M + S - 1 slots for M outputs
  pipeline replication (Fig. 4)     data-parallel axis around the pipeline

``pipeline_apply`` runs inside shard_map on the 'stage' axis: each device
holds ONE stage's parameters (the FU's instruction memory analogue) and the
schedule is the paper's Table I generalized: slot t runs microbatch
t - stage on stage ``stage``.

Overlap: the ppermute of slot t's activations is issued in the same slot
as the next stage compute, so on real hardware the neighbour transfer
hides behind the stage's layer compute (compute/comm overlap).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_ii(n_microbatches: int, n_stages: int) -> dict:
    """The paper's II model generalized to the device pipeline."""
    slots = n_microbatches + n_stages - 1
    return {
        "slots": slots,
        "bubble_fraction": (n_stages - 1) / slots,
        "ii_per_output": slots / n_microbatches,
    }


def _stage_slice(tree, idx):
    return jax.tree.map(lambda x: x[idx], tree)


def pipeline_apply(mesh: Mesh, stage_fn, stage_params, x, *,
                   axis: str = "stage", collect_dtype=None):
    """Run x through S chained stages with microbatch streaming.

    stage_fn(params_i, h) -> h  (one stage's compute, e.g. its layer slice)
    stage_params: pytree with leading dim S (stage-sharded)
    x: [M, mb, ...] microbatches (replicated across the stage axis)

    Returns y [M, mb, ...] — outputs of the final stage, microbatch order.
    """
    S = mesh.shape[axis]
    M = x.shape[0]

    def worker(params_local, xs):
        # params_local: leaves [1, ...]; xs: [M, mb, ...] (replicated)
        params_i = _stage_slice(params_local, 0)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype if collect_dtype is None
                          else collect_dtype)
        outputs = jnp.zeros_like(xs)

        def slot(t, carry):
            state, outputs = carry
            # stage 0 injects microbatch t; others consume neighbour data
            inj = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), 0, keepdims=False)
            h_in = jnp.where(stage == 0, inj, state)
            h_out = stage_fn(params_i, h_in)
            # the last stage records output for microbatch t-(S-1)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            take = jnp.logical_and(stage == S - 1, t >= S - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(take,
                          h_out.astype(outputs.dtype),
                          jax.lax.dynamic_index_in_dim(
                              outputs, out_idx, 0, keepdims=False)),
                out_idx, 0)
            # direct neighbour link (the non-programmable interconnect)
            state = jax.lax.ppermute(
                h_out, axis, [(i, (i + 1) % S) for i in range(S)])
            return state, outputs

        state, outputs = jax.lax.fori_loop(0, M + S - 1, slot,
                                           (state, outputs))
        # only the last stage holds real outputs; broadcast them
        outputs = jnp.where(stage == S - 1, outputs, 0)
        return jax.lax.psum(outputs, axis)

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params), P())
    return shard_map(worker, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)(stage_params, x)


def pipeline_reference(stage_fn, stage_params, x):
    """Sequential oracle: all stages applied in order to each microbatch."""
    S = jax.tree.leaves(stage_params)[0].shape[0]

    def one(mb):
        h = mb
        for i in range(S):
            h = stage_fn(_stage_slice(stage_params, i), h)
        return h

    return jax.vmap(one)(x)
