"""Sharding rules: DP / FSDP / TP (+pod) PartitionSpecs for params, caches,
activations and optimizer state.

Baseline layout (MaxText-style 2D):
  * batch + FSDP dims ride the ('pod','data') axes (flattened),
  * tensor-parallel dims ride 'model',
  * per-tensor fallbacks when a dim is not divisible by the axis size
    (e.g. GQA kv_heads=8 on a 16-way model axis shards head_dim instead;
    odd head counts replicate).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.blocks import BlockSpec


@dataclasses.dataclass(frozen=True)
class Axes:
    """Mesh axis naming for one run."""
    batch: tuple[str, ...] = ("data",)   # DP/FSDP axes (may include 'pod')
    tp: str = "model"

    def sizes(self, mesh) -> tuple[int, int]:
        d = int(np.prod([mesh.shape[a] for a in self.batch]))
        t = mesh.shape[self.tp] if self.tp is not None else 1
        return d, t


def for_mesh(mesh, layout: str = "2d") -> Axes:
    """layout '2d': DP/FSDP x TP (baseline).  layout 'fsdp': every axis is
    a batch/FSDP axis — no tensor parallelism, no per-layer activation
    all-reduces (the beyond-paper hillclimb layout for small-activation
    archs)."""
    names = mesh.axis_names
    if layout == "fsdp":
        return Axes(batch=tuple(names), tp=None)
    return Axes(batch=tuple(n for n in names if n != "model"), tp="model")


def _div(n, k):
    return n % k == 0


# ------------------------------------------------------------ param rules
def _attn_shardings(ax: Axes, tp_size: int, dims_ok=True):
    f, t = ax.batch, ax.tp
    return {
        "wq": {"w": P(f, t)}, "wk": {"w": P(f, t)}, "wv": {"w": P(f, t)},
        "wo": {"w": P(t, f)},
    }


def _block_shardings(cfg, spec: BlockSpec, ax: Axes, tp: int):
    f, t = ax.batch, ax.tp
    if spec.kind == "mamba":
        d = cfg.ssm
        return {
            "ln": {"g": P(None)},
            "mixer": {
                "in_proj": {"w": P(f, t)},
                "conv_w": P(None, t), "conv_b": P(t),
                "A_log": P(None), "D": P(None), "dt_bias": P(None),
                "norm": {"g": P(t) if _div(d.d_inner, tp) else P(None)},
                "out_proj": {"w": P(t, f)},
            },
        }
    p = {"ln1": {"g": P(None)}, "ln2": {"g": P(None)},
         "attn": _attn_shardings(ax, tp)}
    if spec.moe:
        p["moe"] = {
            "router": {"w": P(f, None)},
            "w_up": P(None, f, t), "w_gate": P(None, f, t),
            "w_down": P(None, t, f),
        }
        if cfg.n_shared_experts:
            p["moe"]["shared"] = {
                "up": {"w": P(f, t)}, "gate": {"w": P(f, t)},
                "down": {"w": P(t, f)}}
    else:
        p["mlp"] = {"up": {"w": P(f, t)}, "gate": {"w": P(f, t)},
                    "down": {"w": P(t, f)}}
    if spec.cross:
        p["lnx"] = {"g": P(None)}
        p["xattn"] = _attn_shardings(ax, tp)
    return p


def _stack_shardings(cfg, stack, ax: Axes, tp: int):
    out = []
    for spec in stack.blocks:
        bs = _block_shardings(cfg, spec, ax, tp)
        if not spec.shared:  # stacked leaves gain a leading layer dim
            bs = jax.tree.map(
                lambda p: P(*((None,) + tuple(p))), bs,
                is_leaf=lambda x: isinstance(x, P))
        out.append(bs)
    return out


def param_shardings(cfg, mesh, ax: Axes | None = None):
    """PartitionSpec tree matching init_params(cfg) exactly."""
    ax = ax or for_mesh(mesh)
    _, tp = ax.sizes(mesh)
    f, t = ax.batch, ax.tp
    p = {
        "embed": P(t, None),          # vocab-sharded (uneven shards OK)
        "head": P(f, t),
        "final_norm": {"g": P(None)},
        "stacks": [_stack_shardings(cfg, s, ax, tp) for s in cfg.stacks],
    }
    if cfg.encoder is not None:
        p["enc_stacks"] = [_stack_shardings(cfg, s, ax, tp)
                           for s in cfg.encoder.stacks]
        p["enc_norm"] = {"g": P(None)}
    return p


# ------------------------------------------------------------ cache rules
def _kv_head_spec(cfg, mesh, ax: Axes):
    """(kh_spec, hd_spec): shard kv_heads if divisible, else head_dim."""
    if ax.tp is None:
        return None, None
    tp = mesh.shape[ax.tp]
    if _div(cfg.n_kv_heads, tp):
        return ax.tp, None
    if _div(cfg.head_dim, tp):
        return None, ax.tp
    return None, None


def cache_shardings(cfg, mesh, global_batch: int, ax: Axes | None = None):
    """Cache PartitionSpec tree matching init_caches(cfg) structure.

    batch >= dp => shard batch over DP axes; batch==1 (long-context) =>
    shard the cache SEQUENCE over the DP axes instead (context parallel).
    """
    ax = ax or for_mesh(mesh)
    dp, tp = ax.sizes(mesh)
    seq_parallel = not _div(global_batch, dp)
    bspec = None if seq_parallel else ax.batch
    sspec = ax.batch if seq_parallel else None
    kh, hd = _kv_head_spec(cfg, mesh, ax)
    out = []
    for stack in cfg.stacks:
        st = []
        for spec in stack.blocks:
            if spec.kind == "mamba":
                d = cfg.ssm
                st.append({
                    "conv": P(None, bspec, None,
                              ax.tp if _div(d.d_inner + 2 * d.n_groups
                                            * d.d_state, tp) else None),
                    "ssm": P(None, bspec, None,
                             ax.tp if _div(d.d_state, tp) else None, None),
                })
            else:
                c = {"k": P(None, bspec, sspec, kh, hd),
                     "v": P(None, bspec, sspec, kh, hd)}
                if spec.cross:
                    c["xk"] = P(None, bspec, sspec, kh, hd)
                    c["xv"] = P(None, bspec, sspec, kh, hd)
                st.append(c)
        out.append(st)
    return out


# -------------------------------------------------------------- batch rules
def batch_shardings(cfg, mesh, global_batch: int, kind: str,
                    ax: Axes | None = None):
    ax = ax or for_mesh(mesh)
    dp, _ = ax.sizes(mesh)
    bspec = ax.batch if _div(global_batch, dp) else None
    b = {"tokens": P(bspec, None)}
    if cfg.vision_tokens:
        b["vision_embeds"] = P(bspec, None, None)
    if cfg.encoder is not None:
        b["frame_embeds"] = P(bspec, None, None)
    return b


def opt_shardings(param_specs):
    """AdamW state mirrors param sharding (ZeRO-style: fully sharded)."""
    return {"m": param_specs, "v": param_specs, "count": P()}


def sanitize(spec_tree, sds_tree, mesh):
    """Drop sharding on any dim the axis size does not divide.

    jit in_shardings demand exact divisibility; configs have odd dims
    (vocab 51865, head_dim 112, 80 ssm heads...).  Walks the spec tree
    against the matching ShapeDtypeStruct tree and nulls offending axes.
    """
    def ax_size(entry):
        if entry is None:
            return 1
        if isinstance(entry, (tuple, list)):
            n = 1
            for a in entry:
                n *= mesh.shape[a]
            return n
        return mesh.shape[entry]

    def fix(spec, sds):
        if not isinstance(spec, P):
            return spec
        shape = sds.shape
        ent = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for d, e in zip(shape, ent[:len(shape)]):
            out.append(e if e is not None and d % ax_size(e) == 0 else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, sds_tree,
                        is_leaf=lambda x: isinstance(x, P))


def to_named(tree, mesh):
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p), tree,
        is_leaf=lambda x: isinstance(x, P))
