"""Jittable train / prefill / decode steps used by launchers and dry-runs."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.runtime import optim as O
from repro.runtime.compress import compress_decompress


def decorate_batch(cfg, dc, batch, seq_len: int | None = None):
    """Attach the zero vision/frame embeds that archs with those towers
    expect, in place; returns the batch.  The single batch-shaping point
    shared by the CLI trainer (``launch.train``) and the co-scheduled
    training tenant (``launch.trainer_tenant``) — the bit-identity
    differential between the two paths depends on them building the
    SAME batch for the same step."""
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.zeros(
            (dc.local_batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder is not None:
        batch["frame_embeds"] = jnp.zeros(
            (dc.local_batch, seq_len or dc.seq_len, cfg.d_model),
            jnp.bfloat16)
    return batch


def make_train_step(cfg, oc: O.OptConfig, *, compress_grads: bool = False,
                    mixed: bool = False):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    mixed=True: params arrive in bf16 and the f32 master lives in
    opt_state (halves weight-gather + grad-reduce wire bytes).
    Gradients optionally pass the int8 compression hook (error feedback is
    carried in opt_state['ef'] when enabled).
    """

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch))(params)
        if compress_grads:
            ef = opt_state.get("ef")
            grads, ef = compress_decompress(grads, ef)
            opt_state = dict(opt_state, ef=ef)
        if mixed:
            new_params, new_state, stats = O.adamw_update_mixed(
                oc, grads,
                {k: opt_state[k] for k in ("m", "v", "master", "count")},
                params)
        else:
            new_params, new_state, stats = O.adamw_update(
                oc, grads, {k: opt_state[k] for k in ("m", "v", "count")},
                params)
        if compress_grads:
            new_state = dict(new_state, ef=opt_state["ef"])
        return new_params, new_state, {"loss": loss, **stats}

    return train_step


def make_prefill_step(cfg, cache_len=None):
    def prefill_step(params, batch):
        logits, caches = M.prefill(
            cfg, params, batch["tokens"], cache_len=cache_len,
            extra_embeds=batch.get("vision_embeds"),
            frame_embeds=batch.get("frame_embeds"))
        return logits, caches

    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, caches, token, pos):
        logits, new_caches = M.decode_step(cfg, params, caches, token, pos)
        # greedy next token (serving driver may re-sample)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, next_tok, new_caches

    return decode_step
