"""Model substrate layers: norms, RoPE, GQA attention, MLP, MoE.

Functional style: ``init_*`` returns a param pytree; ``*_apply`` consumes it.
Compute dtype is bf16 (params stored f32, cast at use); softmax and
reductions run in f32.  Attention uses an online-softmax (flash-style)
chunked path for long sequences so activation memory stays bounded, with a
window-limited variant that only visits the kv chunks a sliding-window
layer can actually see (keeps HLO FLOPs honest for local-attention archs).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

COMPUTE_DTYPE = jnp.bfloat16

#: when True, weights are explicitly all-gathered (replicated constraint)
#: AFTER the bf16 cast and before use — forces XLA into FSDP-style
#: weight-gathering (bf16 on the wire) instead of activation partial-sums.
_WEIGHT_GATHER = False


def set_weight_gather(on: bool) -> None:
    global _WEIGHT_GATHER
    _WEIGHT_GATHER = bool(on)


def maybe_gather(w):
    if _WEIGHT_GATHER:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(w, P(*([None] * w.ndim)))
    return w


# ------------------------------------------------------------------- basics


def init_linear(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}


def linear(p, x):
    return x @ maybe_gather(p["w"].astype(x.dtype))


def init_norm(_key, d):
    return {"g": jnp.ones((d,), jnp.float32)}


def rms_norm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nrm * p["g"]).astype(x.dtype)


# --------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, hd]; positions [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta), jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32)[..., None, :] \
        * freqs  # [..., S, 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int


def init_attention(key, d_model, dims: AttnDims):
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, kh, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    return {
        "wq": init_linear(kq, d_model, h * hd),
        "wk": init_linear(kk, d_model, kh * hd),
        "wv": init_linear(kv, d_model, kh * hd),
        "wo": init_linear(ko, h * hd, d_model, scale=(h * hd) ** -0.5),
    }


def _mask(q_pos, k_pos, causal, window):
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]),
                 jnp.bool_)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    return m


def _sdpa(q, k, v, q_pos, k_pos, causal, window):
    """Direct attention on small blocks. q [B,Sq,KH,G,hd], k/v [B,Sk,KH,hd]."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = _mask(q_pos, k_pos, causal, window)  # [B?,Sq,Sk] broadcast
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)


def _flash(q, k, v, q_pos, k_pos, causal, window, kv_chunk=1024):
    """Online-softmax over kv chunks. Shapes as _sdpa; returns [B,Sq,KH,G,hd]."""
    B, Sq, KH, G, hd = q.shape
    Sk = k.shape[1]
    nkc = -(-Sk // kv_chunk)
    pad = nkc * kv_chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2 ** 30)
    scale = hd ** -0.5
    kc = k.reshape(B, nkc, kv_chunk, KH, hd)
    vc = v.reshape(B, nkc, kv_chunk, KH, hd)
    pc = k_pos.reshape(B, nkc, kv_chunk)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs  # [B,ck,KH,hd], [B,ck]
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, kb,
                            preferred_element_type=jnp.float32) * scale
        msk = _mask(q_pos, pb, causal, window)
        logits = jnp.where(msk[:, None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb)
        acc_new = acc * alpha[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KH, G, Sq, hd), v.dtype)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
         pc.transpose(1, 0, 2)))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4)  # [B,Sq,KH,G,hd]


def _flash_windowed(q, k, v, q_pos, k_pos, causal, window, q_chunk=512):
    """Sliding-window attention visiting only reachable kv (causal).

    Scans q chunks; for each, slices the kv span [start, start+span) where
    span = window + q_chunk.  Keeps FLOPs ~O(S*window) instead of O(S^2).
    """
    B, Sq, KH, G, hd = q.shape
    Sk = k.shape[1]
    span = window + q_chunk
    nqc = -(-Sq // q_chunk)
    padq = nqc * q_chunk - Sq
    if padq:
        q = jnp.pad(q, ((0, 0), (0, padq), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, padq)), constant_values=2 ** 30)
    qc = q.reshape(B, nqc, q_chunk, KH, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qpc = q_pos.reshape(B, nqc, q_chunk).transpose(1, 0, 2)
    kpad = jnp.pad(k, ((0, 0), (0, span), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (0, span), (0, 0), (0, 0)))
    kp_pad = jnp.pad(k_pos, ((0, 0), (0, span)), constant_values=2 ** 30)

    def body(c, xs):
        qb, qpb = xs
        start = jnp.maximum(c * q_chunk - window, 0)
        kb = jax.lax.dynamic_slice_in_dim(kpad, start, span, 1)
        vb = jax.lax.dynamic_slice_in_dim(vpad, start, span, 1)
        pb = jax.lax.dynamic_slice_in_dim(kp_pad, start, span, 1)
        out = _sdpa(qb, kb, vb, qpb, pb, causal, window)
        return c + 1, out

    _, outs = jax.lax.scan(body, 0, (qc, qpc))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(
        B, nqc * q_chunk, KH, G, hd)
    return out[:, :Sq]


def attention_apply(p, x, *, dims: AttnDims, positions, causal=True,
                    window=None, rope_theta=10000.0, kv=None, kv_positions=None,
                    use_rope=True, flash_threshold=2048):
    """Self- or cross-attention.  x [B,S,D]; kv (xk_src) for cross-attn."""
    B, S, _ = x.shape
    h, kh, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    g = h // kh
    q = linear(p["wq"], x).reshape(B, S, kh, g, hd)
    src = x if kv is None else kv
    Sk = src.shape[1]
    k = linear(p["wk"], src).reshape(B, Sk, kh, hd)
    v = linear(p["wv"], src).reshape(B, Sk, kh, hd)
    kpos = positions if kv is None else kv_positions
    if use_rope:
        q = apply_rope(q.reshape(B, S, kh * g, hd), positions,
                       rope_theta).reshape(B, S, kh, g, hd)
        k = apply_rope(k, kpos, rope_theta)
    if window is not None and causal and Sk > flash_threshold:
        out = _flash_windowed(q, k, v, positions, kpos, causal, window)
    elif Sk > flash_threshold:
        out = _flash(q, k, v, positions, kpos, causal, window)
    else:
        out = _sdpa(q, k, v, positions, kpos, causal, window)
    out = out.reshape(B, S, h * hd)
    return linear(p["wo"], out)


def attention_decode(p, x, cache_k, cache_v, pos, *, dims: AttnDims,
                     window=None, rope_theta=10000.0, use_rope=True):
    """Single-token decode with in-place cache append.

    x [B,1,D]; cache_k/v [B,S,KH,hd]; pos [] scalar write position.
    Returns (out [B,1,D], cache_k, cache_v).
    """
    B, _, _ = x.shape
    h, kh, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    g = h // kh
    S = cache_k.shape[1]
    q = linear(p["wq"], x).reshape(B, 1, kh, g, hd)
    k_new = linear(p["wk"], x).reshape(B, 1, kh, hd)
    v_new = linear(p["wv"], x).reshape(B, 1, kh, hd)
    posv = jnp.full((B, 1), pos)
    if use_rope:
        q = apply_rope(q.reshape(B, 1, h, hd), posv,
                       rope_theta).reshape(B, 1, kh, g, hd)
        k_new = apply_rope(k_new, posv, rope_theta)
    write_at = pos % S  # ring buffer (sliding-window caches wrap)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), write_at, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), write_at, axis=1)
    # absolute positions of cache slots (ring-aware)
    slot = jnp.arange(S)
    wraps = (pos // S)
    k_pos = jnp.where(slot <= write_at, wraps * S + slot,
                      (wraps - 1) * S + slot)
    k_pos = jnp.broadcast_to(k_pos[None], (B, S))
    scale = hd ** -0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, cache_k.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    valid = (k_pos <= pos) & (k_pos >= 0)
    if window is not None:
        valid &= k_pos > pos - window
    logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, cache_v.astype(x.dtype))
    out = out.reshape(B, 1, h * hd)
    return linear(p["wo"], out), cache_k, cache_v


# ----------------------------------------------------------------------- MLP
def init_mlp(key, d_model, d_ff, gated=True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": init_linear(k1, d_model, d_ff),
         "down": init_linear(k2, d_ff, d_model, scale=d_ff ** -0.5)}
    if gated:
        p["gate"] = init_linear(k3, d_model, d_ff)
    return p


def mlp_apply(p, x, act=jax.nn.silu):
    up = linear(p["up"], x)
    if "gate" in p:
        up = up * act(linear(p["gate"], x))
    else:
        up = act(up)
    return linear(p["down"], up)


# ----------------------------------------------------------------------- MoE
def init_moe(key, d_model, expert_d_ff, n_experts, n_shared=0,
             shared_d_ff=None):
    kr, ke, ks = jax.random.split(key, 3)
    k1, k2, k3 = jax.random.split(ke, 3)
    scale = d_model ** -0.5
    p = {
        "router": init_linear(kr, d_model, n_experts),
        "w_up": jax.random.normal(
            k1, (n_experts, d_model, expert_d_ff)) * scale,
        "w_gate": jax.random.normal(
            k2, (n_experts, d_model, expert_d_ff)) * scale,
        "w_down": jax.random.normal(
            k3, (n_experts, expert_d_ff, d_model)) * expert_d_ff ** -0.5,
    }
    if n_shared:
        p["shared"] = init_mlp(ks, d_model,
                               shared_d_ff or n_shared * expert_d_ff)
    return p


def moe_apply(p, x, *, top_k: int):
    """Sorted-dispatch MoE (MegaBlocks-style) via lax.ragged_dot.

    FLOPs are exactly T*k per-expert work — no dense all-expert compute,
    no capacity padding.  Returns (out, aux_loss).
    """
    B, S, D = x.shape
    E = p["w_up"].shape[0]
    T = B * S
    xt = x.reshape(T, D)
    logits = linear(p["router"], xt).astype(jnp.float32)   # [T,E]
    gates, eid = jax.lax.top_k(jax.nn.softmax(logits, -1), top_k)  # [T,k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(eid[:, 0], E, dtype=jnp.float32), 0)
    router_prob = jnp.mean(jax.nn.softmax(logits, -1), 0)
    aux = E * jnp.sum(density * router_prob)

    flat_e = eid.reshape(-1)                                # [T*k]
    order = jnp.argsort(flat_e)
    token_of = order // top_k
    xs = xt[token_of]                                       # [T*k, D]
    group_sizes = jnp.bincount(flat_e, length=E)
    up = jax.lax.ragged_dot(xs, maybe_gather(p["w_up"].astype(xs.dtype)),
                            group_sizes)
    gate = jax.lax.ragged_dot(xs, maybe_gather(p["w_gate"].astype(xs.dtype)),
                              group_sizes)
    hidden = up * jax.nn.silu(gate)
    out_s = jax.lax.ragged_dot(hidden,
                               maybe_gather(p["w_down"].astype(xs.dtype)),
                               group_sizes)                 # [T*k, D]
    # unsort and combine with gate weights
    w = gates.reshape(-1)[order].astype(out_s.dtype)        # sorted weights
    combined = jnp.zeros((T, D), out_s.dtype).at[token_of].add(out_s * w[:, None])
    if "shared" in p:
        combined = combined + mlp_apply(p["shared"], xt)
    return combined.reshape(B, S, D), aux
