"""Residual blocks + stack runner.

A model is an ordered list of *stacks*; each stack applies its tuple of
``BlockSpec``s ``count`` times with params stacked on a leading axis.

The stack runner realizes the paper's central axis:

  * ``tm`` (time-multiplexed, default) — ``lax.scan`` over the stacked
    params: ONE compiled block body re-issued over the layer stream, the
    direct analogue of the paper's FU executing its stage's instruction
    list (tiny 'instruction memory' = small HLO).
  * ``spatial`` — a Python loop unrolling every layer into the program,
    the SCFU-SCN analogue (one FU per op; big HLO, maximal scheduling
    freedom).

``shared=True`` blocks (zamba2's shared attention) keep ONE param set that
is re-applied at every scan step — the paper's time-multiplexing taken to
the weight level.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import ssm as ssm_mod
from repro.models.layers import (AttnDims, attention_apply, attention_decode,
                                 init_attention, init_mlp, init_moe,
                                 init_norm, linear, mlp_apply, moe_apply,
                                 rms_norm)


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: str                    # "attn" | "mamba"
    window: int | None = None    # sliding-window size (attn)
    moe: bool = False
    shared: bool = False         # params shared across scan steps (zamba2)
    cross: bool = False          # + cross-attention sublayer (whisper dec)
    causal: bool = True
    use_rope: bool = True


@dataclasses.dataclass(frozen=True)
class StackSpec:
    count: int
    blocks: tuple[BlockSpec, ...]


# ----------------------------------------------------------- param builders
def init_block(key, cfg, spec: BlockSpec):
    dims = AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    ks = jax.random.split(key, 8)
    if spec.kind == "mamba":
        return {"ln": init_norm(ks[0], cfg.d_model),
                "mixer": ssm_mod.init_mamba2(ks[1], cfg.ssm)}
    p = {"ln1": init_norm(ks[0], cfg.d_model),
         "attn": init_attention(ks[1], cfg.d_model, dims),
         "ln2": init_norm(ks[2], cfg.d_model)}
    if spec.moe:
        p["moe"] = init_moe(ks[3], cfg.d_model, cfg.expert_d_ff,
                            cfg.n_experts,
                            n_shared=cfg.n_shared_experts,
                            shared_d_ff=cfg.shared_expert_d_ff)
    else:
        p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff)
    if spec.cross:
        p["lnx"] = init_norm(ks[4], cfg.d_model)
        p["xattn"] = init_attention(ks[5], cfg.d_model, dims)
    return p


def init_stack(key, cfg, stack: StackSpec):
    """Params for one stack: leaves [count, ...] (shared blocks unstacked)."""
    out = []
    for j, spec in enumerate(stack.blocks):
        kj = jax.random.fold_in(key, j)
        if spec.shared:
            out.append(init_block(kj, cfg, spec))
        else:
            ks = jax.random.split(kj, stack.count)
            per = [init_block(k, cfg, spec) for k in ks]
            out.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    return out


# ------------------------------------------------------------- cache builders
def init_block_cache(cfg, spec: BlockSpec, batch: int, cache_len: int,
                     mem_len: int = 0, dtype=jnp.bfloat16):
    dims = AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    if spec.kind == "mamba":
        d = cfg.ssm
        conv_ch = d.d_inner + 2 * d.n_groups * d.d_state
        return {
            "conv": jnp.zeros((batch, d.d_conv - 1, conv_ch), dtype),
            "ssm": jnp.zeros((batch, d.n_heads, d.d_state, d.head_dim),
                             jnp.float32),
        }
    S = min(cache_len, spec.window) if spec.window else cache_len
    c = {"k": jnp.zeros((batch, S, dims.n_kv_heads, dims.head_dim), dtype),
         "v": jnp.zeros((batch, S, dims.n_kv_heads, dims.head_dim), dtype)}
    if spec.cross:
        c["xk"] = jnp.zeros((batch, mem_len, dims.n_kv_heads,
                             dims.head_dim), dtype)
        c["xv"] = jnp.zeros((batch, mem_len, dims.n_kv_heads,
                             dims.head_dim), dtype)
    return c


def init_stack_cache(cfg, stack: StackSpec, batch, cache_len, mem_len=0,
                     dtype=jnp.bfloat16):
    return [jax.tree.map(
        lambda x: jnp.broadcast_to(x, (stack.count,) + x.shape),
        init_block_cache(cfg, spec, batch, cache_len, mem_len, dtype))
        for spec in stack.blocks]


# --------------------------------------------------------------- block apply
def block_apply(cfg, spec: BlockSpec, p, h, positions, memory=None,
                mem_positions=None):
    """Full-sequence (train / prefill) application.  Returns (h, kv) where
    kv = (k_full, v_full [, xk, xv]) streams for cache construction."""
    dims = AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    if spec.kind == "mamba":
        return h + ssm_mod.mamba2_apply(p["mixer"], rms_norm(p["ln"], h),
                                        dims=cfg.ssm), None
    a = attention_apply(p["attn"], rms_norm(p["ln1"], h), dims=dims,
                        positions=positions, causal=spec.causal,
                        window=spec.window, rope_theta=cfg.rope_theta,
                        use_rope=spec.use_rope)
    h = h + a
    if spec.cross:
        x = attention_apply(p["xattn"], rms_norm(p["lnx"], h), dims=dims,
                            positions=positions, causal=False, window=None,
                            rope_theta=cfg.rope_theta, use_rope=False,
                            kv=memory, kv_positions=mem_positions)
        h = h + x
    inner = rms_norm(p["ln2"], h)
    if spec.moe:
        out, aux = moe_apply(p["moe"], inner, top_k=cfg.top_k)
    else:
        out, aux = mlp_apply(p["mlp"], inner), 0.0
    return h + out, aux


def block_decode(cfg, spec: BlockSpec, p, h, cache, pos):
    """Single-token decode; cache is this block's dict (unstacked)."""
    dims = AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    if spec.kind == "mamba":
        y, conv, ssm_st = ssm_mod.mamba2_decode(
            p["mixer"], rms_norm(p["ln"], h), cache["conv"], cache["ssm"],
            dims=cfg.ssm)
        return h + y, {"conv": conv, "ssm": ssm_st}
    a, ck, cv = attention_decode(p["attn"], rms_norm(p["ln1"], h),
                                 cache["k"], cache["v"], pos, dims=dims,
                                 window=spec.window,
                                 rope_theta=cfg.rope_theta,
                                 use_rope=spec.use_rope)
    h = h + a
    new_cache = dict(cache, k=ck, v=cv)
    if spec.cross:
        # cross K/V were filled at prefill; attend over all memory slots
        B = h.shape[0]
        S_mem = cache["xk"].shape[1]
        q = linear(p["xattn"]["wq"], rms_norm(p["lnx"], h)).reshape(
            B, 1, dims.n_kv_heads, dims.n_heads // dims.n_kv_heads,
            dims.head_dim)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", q,
                            cache["xk"].astype(q.dtype),
                            preferred_element_type=jnp.float32)
        logits = logits * dims.head_dim ** -0.5
        probs = jax.nn.softmax(logits, -1).astype(h.dtype)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", probs,
                       cache["xv"].astype(h.dtype))
        o = o.reshape(B, 1, dims.n_heads * dims.head_dim)
        h = h + linear(p["xattn"]["wo"], o)
    inner = rms_norm(p["ln2"], h)
    if spec.moe:
        out, _ = moe_apply(p["moe"], inner, top_k=cfg.top_k)
    else:
        out = mlp_apply(p["mlp"], inner)
    return h + out, new_cache


def block_fill_cache(cfg, spec: BlockSpec, p, h_pre, cache, memory=None):
    """Populate a block's KV cache from a full prefill pass.

    h_pre is the block input; recomputes k/v projections (cheap vs attn)."""
    if spec.kind == "mamba":
        conv_st, ssm_st = ssm_mod.mamba2_states(
            p["mixer"], rms_norm(p["ln"], h_pre), dims=cfg.ssm)
        return dict(cache, conv=conv_st.astype(cache["conv"].dtype),
                    ssm=ssm_st)
    dims = AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    from repro.models.layers import apply_rope
    B, S, _ = h_pre.shape
    x = rms_norm(p["ln1"], h_pre)
    k = linear(p["attn"]["wk"], x).reshape(B, S, dims.n_kv_heads,
                                           dims.head_dim)
    v = linear(p["attn"]["wv"], x).reshape(B, S, dims.n_kv_heads,
                                           dims.head_dim)
    if spec.use_rope:
        k = apply_rope(k, jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
                       cfg.rope_theta)
    # ring layout: absolute position p lives at slot p % W (decode assumes it)
    W = cache["k"].shape[1]
    if S <= W:
        new_k = jnp.zeros_like(cache["k"]).at[:, :S].set(
            k.astype(cache["k"].dtype))
        new_v = jnp.zeros_like(cache["v"]).at[:, :S].set(
            v.astype(cache["v"].dtype))
    else:
        import numpy as np
        slots = jnp.asarray(np.arange(S - W, S) % W)
        new_k = jnp.zeros_like(cache["k"]).at[:, slots].set(
            k[:, -W:].astype(cache["k"].dtype))
        new_v = jnp.zeros_like(cache["v"]).at[:, slots].set(
            v[:, -W:].astype(cache["v"].dtype))
    new = dict(cache, k=new_k, v=new_v)
    if spec.cross and memory is not None:
        Sm = memory.shape[1]
        xm = memory
        xk = linear(p["xattn"]["wk"], xm).reshape(B, Sm, dims.n_kv_heads,
                                                  dims.head_dim)
        xv = linear(p["xattn"]["wv"], xm).reshape(B, Sm, dims.n_kv_heads,
                                                  dims.head_dim)
        new["xk"] = xk.astype(cache["xk"].dtype)
        new["xv"] = xv.astype(cache["xv"].dtype)
    return new


# --------------------------------------------------------------- stack runner
def run_stack(cfg, stack: StackSpec, sp, h, positions, *, mode="train",
              memory=None, mem_positions=None, caches=None, pos=None):
    """Apply one stack.  mode: train|prefill|decode.

    Returns (h, aux_sum, new_caches).  In tm mode the body is scanned; in
    spatial mode it is unrolled.  Shared-block params ride as closures.
    """
    tm = getattr(cfg, "scan_layers", True)
    specs = stack.blocks
    shared_params = [sp[j] if s.shared else None
                     for j, s in enumerate(specs)]

    def step(h, per_layer):
        params_j, cache_j = per_layer
        aux_total = 0.0
        new_caches = []
        for j, spec in enumerate(specs):
            pj = shared_params[j] if spec.shared else params_j[j]
            if mode == "decode":
                h_new, c_new = block_decode(cfg, spec, pj, h,
                                            cache_j[j], pos)
                new_caches.append(c_new)
            else:
                h_pre = h
                h_new, aux = block_apply(cfg, spec, pj, h, positions,
                                         memory, mem_positions)
                if aux is not None:
                    aux_total = aux_total + aux
                if mode == "prefill":
                    new_caches.append(block_fill_cache(
                        cfg, spec, pj, h_pre, cache_j[j], memory))
            h = h_new
        return h, (aux_total, new_caches)

    # assemble per-layer xs: params (stacked, shared -> dummy zeros-free) +
    # caches (stacked)
    params_xs = [jnp.zeros((stack.count,)) if s.shared else sp[j]
                 for j, s in enumerate(specs)]
    cache_stacked = caches if caches is not None else [{} for _ in specs]

    if tm:
        body = step
        if mode == "train":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if getattr(cfg, "remat_policy", "full") == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(step, policy=policy)
        h, (aux, new_caches) = jax.lax.scan(
            body, h, (params_xs, cache_stacked))
        aux = jnp.sum(aux) if hasattr(aux, "shape") else aux
        return h, aux, new_caches
    # spatial: unroll
    aux_total = 0.0
    outs = []
    for i in range(stack.count):
        params_i = jax.tree.map(lambda x: x[i], params_xs)
        cache_i = jax.tree.map(lambda x: x[i], cache_stacked)
        h, (aux, c_new) = step(h, (params_i, cache_i))
        aux_total += aux
        outs.append(c_new)
    if outs and outs[0]:
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        new_caches = cache_stacked
    return h, aux_total, new_caches