from repro.models.blocks import BlockSpec, StackSpec
from repro.models.model import (EncoderSpec, ModelConfig, decode_step,
                                dense_stacks, forward, init_caches,
                                init_params, loss_fn, prefill)
from repro.models.ssm import SSMDims

__all__ = ["BlockSpec", "StackSpec", "EncoderSpec", "ModelConfig",
           "SSMDims", "dense_stacks", "forward", "init_params", "loss_fn",
           "prefill", "decode_step", "init_caches"]
