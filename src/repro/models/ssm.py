"""Mamba2 (SSD — state-space duality) blocks, chunked-parallel + decode.

Implements the SSD algorithm of arXiv:2405.21060: the sequence is split
into chunks; within a chunk the dual quadratic (attention-like) form runs
on the MXU; across chunks a small recurrent state [H, P, N] is carried by
an associative-scan-friendly recurrence.  Decode is the O(1) recurrent
step.  This is the sub-quadratic path that makes ``long_500k`` runnable
for the ssm/hybrid architectures.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.layers import init_linear, linear, rms_norm


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_state: int          # N
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64    # P
    n_groups: int = 1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim


def init_mamba2(key, dims: SSMDims):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    din, N, H, G = dims.d_inner, dims.d_state, dims.n_heads, dims.n_groups
    d_in_proj = 2 * din + 2 * G * N + H   # z, x, B, C, dt
    conv_ch = din + 2 * G * N             # conv over x, B, C
    return {
        "in_proj": init_linear(k1, dims.d_model, d_in_proj),
        "conv_w": jax.random.normal(k2, (dims.d_conv, conv_ch),
                                    jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D": jnp.ones((H,), jnp.float32),
        # inverse-softplus of dt_init=0.01 so softplus(dt_bias) ~ 0.01
        "dt_bias": jnp.full((H,), math.log(math.expm1(0.01))),
        "norm": {"g": jnp.ones((din,), jnp.float32)},
        "out_proj": init_linear(k5, din, dims.d_model, scale=din ** -0.5),
    }


def _split_proj(proj, dims: SSMDims):
    din, N, H, G = dims.d_inner, dims.d_state, dims.n_heads, dims.n_groups
    z, xBC, dt = jnp.split(proj, [din, din + din + 2 * G * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv via K shifted adds (K is tiny)."""
    K = w.shape[0]
    out = xBC * w[K - 1].astype(xBC.dtype)
    for k in range(1, K):
        shifted = jnp.pad(xBC, ((0, 0), (k, 0), (0, 0)))[:, :-k]
        out = out + shifted * w[K - 1 - k].astype(xBC.dtype)
    return jax.nn.silu(out + b.astype(xBC.dtype))


def _ssd_chunked(xh, dt, A, Bm, Cm, D, chunk: int):
    """SSD core. xh [b,l,h,p]; dt [b,l,h]; A [h]<0; Bm/Cm [b,l,g,n]; D [h].

    Scans over chunks carrying the [b,h,n,p] state, so peak activation
    memory is one chunk's quadratic block, not the whole sequence's.
    Returns y [b,l,h,p].
    """
    b, l_orig, h, p = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    pad = (-l_orig) % chunk
    if pad:  # zero-pad the tail: dt=0, x=0 contribute nothing causally
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    l = l_orig + pad
    nc = l // chunk
    f32 = jnp.float32
    Bh = jnp.repeat(Bm, rep, axis=2)  # [b,l,h,n] broadcast groups -> heads
    Ch = jnp.repeat(Cm, rep, axis=2)
    a = (dt.astype(f32) * A.astype(f32))        # [b,l,h] log-decay <= 0
    xdt = xh * dt[..., None].astype(xh.dtype)   # dt folded into inputs
    # chunk-major: [nc, b, chunk, ...]
    def chunked(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    ac, xc_s, Bc_s, Cc_s, xres = map(
        chunked, (a, xdt, Bh, Ch, xh))
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def scan_fn(hprev, inp):
        a_c, xc, Bc, Cc, xr = inp                    # [b,chunk,...]
        cum = jnp.cumsum(a_c, axis=1)                # [b,q,h]
        total = cum[:, -1:, :]                       # [b,1,h]
        # intra-chunk dual form
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # [b,q,k,h]
        Lmat = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bqhn,bkhn->bqkh", Cc.astype(f32),
                            Bc.astype(f32)) * Lmat
        y_diag = jnp.einsum("bqkh,bkhp->bqhp", scores.astype(xc.dtype), xc)
        # contribution of carried state
        y_off = jnp.einsum("bqhn,bqh,bhnp->bqhp",
                           Cc.astype(f32), jnp.exp(cum), hprev)
        # new chunk state
        decay_to_end = jnp.exp(total - cum)          # [b,k,h]
        S_c = jnp.einsum("bkhn,bkh,bkhp->bhnp",
                         Bc.astype(f32), decay_to_end, xc.astype(f32))
        hnew = hprev * jnp.exp(total[:, 0, :])[..., None, None] + S_c
        y = y_diag.astype(f32) + y_off \
            + D.astype(f32)[None, None, :, None] * xr.astype(f32)
        return hnew, y.astype(xh.dtype)

    h0 = jnp.zeros((b, h, n, p), f32)
    _, ys = jax.lax.scan(scan_fn, h0, (ac, xc_s, Bc_s, Cc_s, xres))
    return ys.swapaxes(0, 1).reshape(b, l, h, p)[:, :l_orig]


def mamba2_apply(p, x, *, dims: SSMDims, chunk: int = 256):
    """Full-sequence Mamba2 block. x [B,L,D] -> [B,L,D]."""
    B, L, _ = x.shape
    din, N, H, G = dims.d_inner, dims.d_state, dims.n_heads, dims.n_groups
    proj = linear(p["in_proj"], x)
    z, xBC, dt_raw = _split_proj(proj, dims)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xh, Bm, Cm = jnp.split(xBC, [din, din + G * N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y = _ssd_chunked(xh.reshape(B, L, H, dims.head_dim), dt, A,
                     Bm.reshape(B, L, G, N), Cm.reshape(B, L, G, N),
                     p["D"], min(chunk, L))
    y = y.reshape(B, L, din) * jax.nn.silu(z)
    y = rms_norm(p["norm"], y)
    return linear(p["out_proj"], y)


def mamba2_states(p, x, *, dims: SSMDims, chunk: int = 256):
    """Final (conv_state, ssm_state) after a full prefill of x [B,L,D]."""
    B, L, _ = x.shape
    din, N, H, G = dims.d_inner, dims.d_state, dims.n_heads, dims.n_groups
    proj = linear(p["in_proj"], x)
    z, xBC_raw, dt_raw = _split_proj(proj, dims)
    conv_state = xBC_raw[:, -(dims.d_conv - 1):, :]
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xh, Bm, Cm = jnp.split(xBC, [din, din + G * N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    ssm_state = _ssd_final_state(
        xh.reshape(B, L, H, dims.head_dim), dt, A,
        Bm.reshape(B, L, G, N), Cm.reshape(B, L, G, N), min(chunk, L))
    return conv_state, ssm_state


def _ssd_final_state(xh, dt, A, Bm, Cm, chunk):
    b, l, h, p = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    pad = (-l) % chunk
    if pad:  # zero tail: dt=0 & x=0 leave the state untouched
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l += pad
    nc = l // chunk
    f32 = jnp.float32
    Bh = jnp.repeat(Bm, rep, axis=2)
    a = dt.astype(f32) * A.astype(f32)
    xdt = (xh * dt[..., None].astype(xh.dtype)).astype(f32)

    def chunked(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    def scan_fn(hprev, inp):
        a_c, xc, Bc = inp
        cum = jnp.cumsum(a_c, axis=1)
        total = cum[:, -1:, :]
        decay_to_end = jnp.exp(total - cum)
        S_c = jnp.einsum("bkhn,bkh,bkhp->bhnp", Bc.astype(f32),
                         decay_to_end, xc)
        return hprev * jnp.exp(total[:, 0, :])[..., None, None] + S_c, None

    h0 = jnp.zeros((b, h, n, p), f32)
    hfin, _ = jax.lax.scan(scan_fn, h0, (chunked(a), chunked(xdt),
                                         chunked(Bh)))
    # state layout used by decode: [B,H,N,P]
    return hfin


def mamba2_decode(p, x, conv_state, ssm_state, *, dims: SSMDims):
    """O(1) recurrent step.  x [B,1,D]; conv_state [B,K-1,C];
    ssm_state [B,H,N,P].  Returns (y, conv_state, ssm_state)."""
    B = x.shape[0]
    din, N, H, G = dims.d_inner, dims.d_state, dims.n_heads, dims.n_groups
    K = dims.d_conv
    proj = linear(p["in_proj"], x)[:, 0]                   # [B, d_in_proj]
    z, xBC, dt_raw = _split_proj(proj, dims)
    # conv over (state ++ current)
    full = jnp.concatenate([conv_state,
                            xBC[:, None, :].astype(conv_state.dtype)], 1)
    w = p["conv_w"].astype(full.dtype)
    conv = jnp.einsum("bkc,kc->bc", full, w) + p["conv_b"].astype(full.dtype)
    conv = jax.nn.silu(conv)
    conv_state = full[:, 1:]
    xh, Bm, Cm = jnp.split(conv, [din, din + G * N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    rep = H // G
    Bh = jnp.repeat(Bm.reshape(B, G, N), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm.reshape(B, G, N), rep, axis=1).astype(jnp.float32)
    xhh = xh.reshape(B, H, dims.head_dim).astype(jnp.float32)
    decay = jnp.exp(dt * A)                                 # [B,H]
    ssm_state = ssm_state * decay[..., None, None] \
        + jnp.einsum("bhn,bh,bhp->bhnp", Bh, dt, xhh)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, ssm_state) \
        + p["D"].astype(jnp.float32)[None, :, None] * xhh
    y = y.reshape(B, din).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(p["norm"], y)
    return linear(p["out_proj"], y)[:, None, :], conv_state, ssm_state
