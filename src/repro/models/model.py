"""Composable LM definition covering all assigned architecture families.

A ``ModelConfig`` is a list of stacks (see blocks.py) + embedding/head and
optional encoder (whisper) / vision-stub (internvl2) plumbing.  All models
share one forward/prefill/decode implementation.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import blocks as B
from repro.models.blocks import BlockSpec, StackSpec
from repro.models.layers import COMPUTE_DTYPE, init_norm, rms_norm
from repro.models.ssm import SSMDims


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    """Whisper-style encoder: precomputed frame embeddings in, memory out."""
    stacks: tuple[StackSpec, ...]
    frame_dim: int            # stub frontend output dim (== d_model)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int
    stacks: tuple[StackSpec, ...]
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0
    shared_expert_d_ff: int = 0
    # SSM
    ssm: Optional[SSMDims] = None
    # enc-dec (audio)
    encoder: Optional[EncoderSpec] = None
    # VLM stub: number of precomputed patch-embedding tokens prepended
    vision_tokens: int = 0
    # execution mode: time-multiplexed (scan) vs spatial (unrolled)
    scan_layers: bool = True
    # sinusoidal absolute positions added to decoder embeddings (whisper)
    use_abs_pos: bool = False
    # remat policy for scanned stacks: 'full' recomputes everything
    # (minimum memory), 'dots' saves matmul outputs (trades HBM for the
    # recompute pass — §Perf iteration 5)
    remat_policy: str = "full"
    # attention family flags
    full_attention: bool = True   # False => sub-quadratic (ssm/hybrid/local)
    aux_loss_weight: float = 0.01

    @property
    def n_layers(self) -> int:
        return sum(s.count * len(s.blocks) for s in self.stacks)

    def param_count(self) -> int:
        """Total params (analytic, from shapes)."""
        shapes = jax.eval_shape(lambda k: init_params(self, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        return sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k+shared experts only)."""
        total = self.param_count()
        if not self.n_experts:
            return total
        per_expert = 3 * self.d_model * self.expert_d_ff
        n_moe = sum(s.count for s in self.stacks
                    for b in s.blocks if b.moe)
        inactive = n_moe * (self.n_experts - self.top_k) * per_expert
        return total - inactive


def dense_stacks(n_layers: int, *, window_pattern=None, moe=False,
                 causal=True, use_rope=True) -> tuple[StackSpec, ...]:
    """Uniform dense/MoE stacks; window_pattern=(sizes...) cycles layers."""
    if window_pattern is None:
        return (StackSpec(n_layers, (BlockSpec("attn", moe=moe,
                                               causal=causal,
                                               use_rope=use_rope),)),)
    P = len(window_pattern)
    full, rem = divmod(n_layers, P)
    sts = []
    if full:
        sts.append(StackSpec(full, tuple(
            BlockSpec("attn", window=w, moe=moe) for w in window_pattern)))
    if rem:
        sts.append(StackSpec(1, tuple(
            BlockSpec("attn", window=w, moe=moe)
            for w in window_pattern[:rem])))
    return tuple(sts)


# ----------------------------------------------------------------- params
def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 6 + len(cfg.stacks))
    p = {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                   jnp.float32) * 0.02,
        "head": jax.random.normal(ks[1], (cfg.d_model, cfg.vocab),
                                  jnp.float32) * cfg.d_model ** -0.5,
        "final_norm": init_norm(ks[2], cfg.d_model),
        "stacks": [B.init_stack(ks[6 + i], cfg, s)
                   for i, s in enumerate(cfg.stacks)],
    }
    if cfg.encoder is not None:
        p["enc_stacks"] = [B.init_stack(jax.random.fold_in(ks[3], i),
                                        cfg, s)
                           for i, s in enumerate(cfg.encoder.stacks)]
        p["enc_norm"] = init_norm(ks[4], cfg.d_model)
    return p


# ---------------------------------------------------------------- forward
def _embed(cfg, params, tokens):
    h = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    return h * jnp.asarray(cfg.d_model ** 0.5, COMPUTE_DTYPE)


def _sinusoid(S, D, dtype):
    pos = np.arange(S)[:, None]
    dim = np.arange(0, D, 2)[None, :] / D
    ang = pos / (10000.0 ** dim)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], -1)
    return jnp.asarray(emb, dtype)


def encode(cfg, params, frame_embeds):
    """Whisper encoder: frame_embeds [B,S,D] (stub frontend output)."""
    h = frame_embeds.astype(COMPUTE_DTYPE) \
        + _sinusoid(frame_embeds.shape[1], cfg.d_model, COMPUTE_DTYPE)[None]
    positions = jnp.broadcast_to(jnp.arange(h.shape[1])[None],
                                 h.shape[:2])
    for sp, stack in zip(params["enc_stacks"], cfg.encoder.stacks):
        h, _, _ = B.run_stack(cfg, stack, sp, h, positions, mode="train")
    return rms_norm(params["enc_norm"], h)


def forward(cfg: ModelConfig, params, tokens, *, extra_embeds=None,
            frame_embeds=None, mode="train", caches=None):
    """Full-sequence pass.  tokens [B,S]; extra_embeds [B,Sv,D] (vision);
    frame_embeds [B,Se,D] (audio encoder input).

    Returns (logits [B,S_total,V], aux_loss, new_caches).
    """
    h = _embed(cfg, params, tokens)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    Bsz, S, _ = h.shape
    if cfg.use_abs_pos:
        h = h + _sinusoid(S, cfg.d_model, h.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (Bsz, S))
    memory, mem_pos = None, None
    if cfg.encoder is not None:
        memory = encode(cfg, params, frame_embeds)
        mem_pos = jnp.broadcast_to(
            jnp.arange(memory.shape[1])[None], memory.shape[:2])
    aux_total = 0.0
    new_caches = []
    for i, (sp, stack) in enumerate(zip(params["stacks"], cfg.stacks)):
        h, aux, c = B.run_stack(
            cfg, stack, sp, h, positions, mode=mode, memory=memory,
            mem_positions=mem_pos,
            caches=None if caches is None else caches[i])
        aux_total = aux_total + jnp.sum(aux)
        new_caches.append(c)
    h = rms_norm(params["final_norm"], h)
    from repro.models.layers import maybe_gather
    logits = h @ maybe_gather(params["head"].astype(h.dtype))
    return logits, aux_total, new_caches


def loss_fn(cfg, params, batch):
    """Next-token cross entropy.  batch: tokens [B,S] (+ stub embeds)."""
    tokens = batch["tokens"]
    logits, aux, _ = forward(
        cfg, params, tokens[:, :-1],
        extra_embeds=batch.get("vision_embeds"),
        frame_embeds=batch.get("frame_embeds"), mode="train")
    # targets align with the text positions (vision prefix emits no loss)
    tgt = tokens[:, 1:]
    logits = logits[:, -tgt.shape[1]:]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll + cfg.aux_loss_weight * aux


# ------------------------------------------------------------------ caches
def init_caches(cfg, batch, cache_len, mem_len=0, dtype=jnp.bfloat16):
    return [B.init_stack_cache(cfg, s, batch, cache_len, mem_len, dtype)
            for s in cfg.stacks]


def prefill(cfg, params, tokens, *, cache_len=None, extra_embeds=None,
            frame_embeds=None):
    """Run the full prompt, returning (logits_last, caches)."""
    S = tokens.shape[1] + (extra_embeds.shape[1] if extra_embeds is not None
                           else 0)
    cache_len = cache_len or S
    mem_len = frame_embeds.shape[1] if frame_embeds is not None else 0
    caches = init_caches(cfg, tokens.shape[0], cache_len, mem_len)
    logits, _, new_caches = forward(
        cfg, params, tokens, extra_embeds=extra_embeds,
        frame_embeds=frame_embeds, mode="prefill", caches=caches)
    return logits[:, -1], new_caches


def decode_step(cfg, params, caches, token, pos):
    """One token step.  token [B,1]; pos scalar absolute position.

    Returns (logits [B,V], new_caches)."""
    h = _embed(cfg, params, token)
    if cfg.use_abs_pos:
        D = cfg.d_model
        pos_f = jnp.asarray(pos, jnp.float32)
        dim = jnp.arange(0, D, 2) / D
        ang = pos_f / (10000.0 ** dim)
        emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
        h = h + emb.astype(h.dtype)[None, None, :]
    Bsz = h.shape[0]
    new_caches = []
    for i, (sp, stack) in enumerate(zip(params["stacks"], cfg.stacks)):
        h, _, c = B.run_stack(cfg, stack, sp, h, None, mode="decode",
                              caches=caches[i], pos=pos)
        new_caches.append(c)
    h = rms_norm(params["final_norm"], h)
    logits = (h @ params["head"].astype(h.dtype))[:, 0]
    return logits, new_caches