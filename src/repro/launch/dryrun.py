import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces artifacts/dryrun/<arch>_<shape>_<mesh>.json:
  * memory_analysis (per-device bytes),
  * cost_analysis (HLO FLOPs / bytes accessed),
  * per-collective wire bytes parsed from the post-SPMD optimized HLO,
  * the three roofline terms (compute / memory / collective seconds) and
    MODEL_FLOPS = 6 N_active D (train) or 2 N_active D (serve).

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp                              # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCHS, SHAPES, get_config,       # noqa: E402
                           skip_reason)
from repro.launch.mesh import make_production_mesh   # noqa: E402
from repro.models import model as M                  # noqa: E402
from repro.runtime import optim as O                 # noqa: E402
from repro.runtime import sharding as S              # noqa: E402
from repro.runtime import steps as St                # noqa: E402

# ------------------------------------------------------- hardware constants
PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link ICI

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4,
                "u32": 4, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\(?)([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo: str):
    """Per-device wire bytes per collective (ring model)."""
    out = []
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, dtype, dims, kind = m.groups()
        size = _shape_bytes(dtype, dims)
        # group size
        g = 0
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).strip("{}").split(","))
        else:
            im = _IOTA_RE.search(line)
            if im:
                g = int(im.group(2))
        g = max(g, 2)
        frac = (g - 1) / g
        if kind == "all-reduce":
            wire = 2 * size * frac           # ring: reduce-scatter+all-gather
        elif kind == "all-gather":
            wire = size * frac               # result is the gathered buffer
        elif kind == "reduce-scatter":
            wire = size * g * frac           # result is the scattered shard
        elif kind == "all-to-all":
            wire = size * frac
        else:                                # collective-permute
            wire = size
        out.append({"kind": kind, "dtype": dtype, "bytes": size,
                    "group": g, "wire_bytes": wire})
    return out


# -------------------------------------------------------------- input specs
def input_specs(arch: str, shape: str, spatial: bool = False,
                remat: str = "full"):
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    import dataclasses
    cfg = get_config(arch)
    if spatial:
        cfg = dataclasses.replace(cfg, scan_layers=False)
    if remat != "full":
        cfg = dataclasses.replace(cfg, remat_policy=remat)
    seq, gbatch, kind = SHAPES[shape]
    sds = jax.ShapeDtypeStruct
    batch = {}
    if kind == "train":
        batch["tokens"] = sds((gbatch, seq), jnp.int32)
        if cfg.vision_tokens:
            batch["tokens"] = sds((gbatch, seq - cfg.vision_tokens),
                                  jnp.int32)
            batch["vision_embeds"] = sds(
                (gbatch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.encoder is not None:
            batch["frame_embeds"] = sds((gbatch, seq, cfg.d_model),
                                        jnp.bfloat16)
    elif kind == "prefill":
        batch["tokens"] = sds((gbatch, seq), jnp.int32)
        if cfg.vision_tokens:
            batch["tokens"] = sds((gbatch, seq - cfg.vision_tokens),
                                  jnp.int32)
            batch["vision_embeds"] = sds(
                (gbatch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.encoder is not None:
            batch["frame_embeds"] = sds((gbatch, seq, cfg.d_model),
                                        jnp.bfloat16)
    else:  # decode
        batch["tokens"] = sds((gbatch, 1), jnp.int32)
    return cfg, batch, (seq, gbatch, kind)


def _sds_tree(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_cell(arch: str, shape: str, mesh, mesh_name: str,
               spatial: bool = False, layout: str = "2d",
               mixed: bool = False, remat: str = "full"):
    cfg, batch_sds, (seq, gbatch, kind) = input_specs(arch, shape, spatial,
                                                      remat)
    from repro.models import layers as L
    L.set_weight_gather(layout == "fsdp")
    ax = S.for_mesh(mesh, layout)
    params_sds = jax.eval_shape(
        lambda k: M.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    if mixed:
        params_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
            params_sds)
    pspec = S.sanitize(S.param_shardings(cfg, mesh, ax), params_sds, mesh)
    p_shard = S.to_named(pspec, mesh)
    bspec_all = S.batch_shardings(cfg, mesh, gbatch, kind, ax)
    bspec = {k: bspec_all[k] for k in batch_sds}
    b_shard = S.to_named(S.sanitize(bspec, batch_sds, mesh), mesh)
    t0 = time.time()
    with mesh:
        if kind == "train":
            oc = O.OptConfig()
            step = St.make_train_step(cfg, oc, mixed=mixed)
            if mixed:
                opt_sds = jax.eval_shape(
                    lambda p: O.init_opt_mixed(p), params_sds)
                o_shard = S.to_named(
                    {"m": pspec, "v": pspec, "master": pspec,
                     "count": P()}, mesh)
            else:
                opt_sds = jax.eval_shape(
                    lambda p: O.init_opt(p), params_sds)
                o_shard = S.to_named(
                    {"m": pspec, "v": pspec, "count": P()}, mesh)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        elif kind == "prefill":
            step = St.make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            step = St.make_decode_step(cfg)
            cache_sds = jax.eval_shape(
                lambda: M.init_caches(cfg, gbatch, seq,
                                      mem_len=seq if cfg.encoder else 0))
            c_shard = S.to_named(
                S.sanitize(S.cache_shardings(cfg, mesh, gbatch, ax),
                           cache_sds, mesh), mesh)
            tok_sds = jax.ShapeDtypeStruct((gbatch, 1), jnp.int32)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard,
                              NamedSharding(mesh, P(ax.batch if gbatch > 1
                                                    else None, None)),
                              NamedSharding(mesh, P())),
                donate_argnums=(1,))
            lowered = jitted.lower(params_sds, cache_sds, tok_sds, pos_sds)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    L.set_weight_gather(False)
    return cfg, compiled, (seq, gbatch, kind), t_lower, t_compile


def run_cell(arch: str, shape: str, mesh_name: str, outdir: str,
             spatial: bool = False, layout: str = "2d",
             mixed: bool = False, remat: str = "full"):
    reason = skip_reason(arch, shape)
    variant = ("" if layout == "2d" else f"_{layout}") + \
        ("_mixed" if mixed else "") + \
        ("" if remat == "full" else f"_remat-{remat}")
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "mode": "spatial" if spatial else "tm",
           "layout": layout, "mixed": mixed, "remat": remat}
    fname = os.path.join(
        outdir, f"{arch}_{shape}_{mesh_name}{variant}.json".replace("/", "-"))
    if reason:
        rec["skipped"] = reason
        _write(fname, rec)
        print(f"[skip] {arch} x {shape} ({mesh_name}): {reason}")
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = mesh.size
    try:
        cfg, compiled, (seq, gbatch, kind), t_lo, t_co = lower_cell(
            arch, shape, mesh, mesh_name, spatial, layout, mixed, remat)
    except Exception as e:  # a failure here is a bug in the system
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        _write(fname, rec)
        print(f"[FAIL] {arch} x {shape} ({mesh_name}): {rec['error']}")
        return rec
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:
        rec["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float))}
    except Exception as e:
        rec["cost"] = {"error": str(e)}
    colls = parse_collectives(compiled.as_text())
    agg = {}
    for c in colls:
        a = agg.setdefault(c["kind"], {"count": 0, "wire_bytes": 0.0})
        a["count"] += 1
        a["wire_bytes"] += c["wire_bytes"]
    rec["collectives"] = agg
    coll_bytes = sum(a["wire_bytes"] for a in agg.values())

    hlo_flops = rec.get("cost", {}).get("flops", 0.0)
    hlo_bytes = rec.get("cost", {}).get("bytes accessed", 0.0)
    # model FLOPs: 6 N D train, 2 N D serve (active params for MoE)
    n_active = cfg.active_param_count()
    tokens = gbatch * (seq if kind != "decode" else 1)
    model_flops = (6 if kind == "train" else 2) * n_active * tokens
    rec["roofline"] = {
        "chips": n_chips,
        "hlo_flops_per_device": hlo_flops,
        "hlo_bytes_per_device": hlo_bytes,
        "collective_wire_bytes_per_device": coll_bytes,
        "t_compute_s": hlo_flops / PEAK_FLOPS,
        "t_memory_s": hlo_bytes / HBM_BW,
        "t_collective_s": coll_bytes / LINK_BW,
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / n_chips,
        "useful_flops_ratio": (model_flops / n_chips) / hlo_flops
        if hlo_flops else None,
    }
    terms = {k: rec["roofline"][f"t_{k}_s"]
             for k in ("compute", "memory", "collective")}
    rec["roofline"]["bottleneck"] = max(terms, key=terms.get)
    rec["timing"] = {"lower_s": t_lo, "compile_s": t_co}
    _write(fname, rec)
    print(f"[ok] {arch} x {shape} ({mesh_name}): "
          f"compute {terms['compute']:.4f}s memory {terms['memory']:.4f}s "
          f"coll {terms['collective']:.4f}s -> "
          f"{rec['roofline']['bottleneck']}  (compile {t_co:.0f}s)")
    return rec


def _write(fname, rec):
    os.makedirs(os.path.dirname(fname), exist_ok=True)
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--spatial", action="store_true",
                    help="unroll layer stacks (exact HLO cost accounting; "
                         "also the paper's SCFU-analogue datapoint)")
    ap.add_argument("--layout", default="2d", choices=["2d", "fsdp"])
    ap.add_argument("--mixed", action="store_true",
                    help="bf16 params + f32 master in opt state")
    ap.add_argument("--remat", default="full", choices=["full", "dots"])
    args = ap.parse_args()
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    failures = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape in shapes:
                fname = os.path.join(
                    args.out, f"{arch}_{shape}_{mesh_name}.json")
                if args.skip_existing and os.path.exists(fname):
                    try:
                        with open(fname) as f:
                            if "error" not in json.load(f):
                                continue
                    except Exception:
                        pass
                rec = run_cell(arch, shape, mesh_name, args.out,
                               spatial=args.spatial, layout=args.layout,
                               mixed=args.mixed, remat=args.remat)
                failures += 1 if "error" in rec else 0
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
