"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
state.  Single pod: 16x16 = 256 chips ('data','model').  Multi-pod: 2 pods
x 256 = 512 chips ('pod','data','model'); the pod axis carries only
data-parallel traffic (DCN-friendly).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serving_mesh(n_replicas: int | None = None, devices=None):
    """One device per overlay-serving replica (the sharded context banks).

    Unlike the SPMD training meshes above, serving replicas are
    INDEPENDENT single-device workers — each hosts its own ContextBank
    working set and executes its own rounds — so the 'mesh' is just a
    placement list.  When ``n_replicas`` exceeds the live device count the
    assignment wraps (several replicas share a device): correctness is
    unchanged — residency routing and the directory work per replica, not
    per device — which is exactly what lets the differential tests run
    2/4/8 replicas on single-device CI (or on fake devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count``, see
    tests/conftest.py).
    """
    if devices is None:
        devices = jax.devices()
    if n_replicas is None:
        n_replicas = len(devices)
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    return [devices[i % len(devices)] for i in range(n_replicas)]


def device_sharing(devices) -> dict[int, int]:
    """How many serving replicas share each physical device.

    ``{device id: replica count}`` for a ``make_serving_mesh`` placement
    list.  Counts > 1 mean replicas wrap onto one device (CI fake-device
    runs, oversubscribed fleets): correctness is unchanged, but
    cross-replica overlap — the effect the sharded and work-stealing
    benchmarks measure — is then time-sliced, not parallel, which is why
    the benchmarks print this next to their speedups.
    """
    sharing: dict[int, int] = {}
    for d in devices:
        sharing[d.id] = sharing.get(d.id, 0) + 1
    return sharing


def least_shared_device(pool, in_use):
    """The pool device hosting the fewest current serving replicas.

    ``pool`` is the candidate device list (usually ``jax.devices()``),
    ``in_use`` the fleet's current placement list (one entry per live
    replica, duplicates meaning replicas share that device).  This is the
    elastic-autoscaling placement rule: a new replica lands where it
    oversubscribes the hardware least, so grown capacity is real
    parallelism for as long as physical devices remain and only then
    time-slicing.  Ties break on device id for determinism.
    """
    if not pool:
        raise ValueError("least_shared_device: empty device pool")
    sharing = device_sharing(in_use)
    return min(pool, key=lambda d: (sharing.get(d.id, 0), d.id))


def make_mesh_from_devices(devices, model_parallel: int = 16):
    """Elastic re-mesh: build the largest (data, model) mesh from a live
    device list (used by distributed.elastic on simulated failures)."""
    import numpy as np
    n = len(devices)
    model = model_parallel
    while model > 1 and n % model:
        model //= 2
    data = n // model
    arr = np.asarray(devices[: data * model]).reshape(data, model)
    from jax.sharding import Mesh
    return Mesh(arr, ("data", "model"))
