"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
state.  Single pod: 16x16 = 256 chips ('data','model').  Multi-pod: 2 pods
x 256 = 512 chips ('pod','data','model'); the pod axis carries only
data-parallel traffic (DCN-friendly).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_from_devices(devices, model_parallel: int = 16):
    """Elastic re-mesh: build the largest (data, model) mesh from a live
    device list (used by distributed.elastic on simulated failures)."""
    import numpy as np
    n = len(devices)
    model = model_parallel
    while model > 1 and n % model:
        model //= 2
    data = n // model
    arr = np.asarray(devices[: data * model]).reshape(data, model)
    from jax.sharding import Mesh
    return Mesh(arr, ("data", "model"))
