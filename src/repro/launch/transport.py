"""Length-prefixed frame codec for the gateway's socket transport.

The serving stack's asyncio edge (``launch/gateway.py``) multiplexes
thousands of in-process connections; ``launch/socket_gateway.py`` puts a
real wire under them.  This module is the wire's *message fabric*: every
message travels as one self-describing FRAME —

    +-------+---------+-------+-----------------+----------------+
    | magic | version | codec | payload length  | payload bytes  |
    | 2 B   | 1 B     | 1 B   | 4 B big-endian  | <= size cap    |
    +-------+---------+-------+-----------------+----------------+

— the same length-prefixed point-to-point discipline a NoC-style overlay
interconnect uses to move packets between functional units: a fixed
header any endpoint can parse without trusting the peer, then an opaque
payload.  Design rules, each enforced here rather than by convention:

* VERSIONED — the header carries ``PROTOCOL_VERSION``; a frame from a
  different protocol generation raises :class:`ProtocolVersionError`
  instead of being misparsed (the socket layer turns that into an
  explicit handshake refusal).
* SIZE-CAPPED — ``max_bytes`` bounds the payload both ways: a declared
  length past the cap raises :class:`FrameTooLargeError` *before* any
  payload is read, so a hostile or buggy peer cannot make the server
  allocate unbounded memory from four header bytes.
* REJECT, don't guess — bad magic, garbage payloads, and truncated
  streams raise typed errors (:class:`MalformedFrameError`,
  :class:`TruncatedFrameError`); the socket layer counts them as
  ``wire.rejects`` and drops the connection.
* CODEC-TAGGED — each frame names its payload codec (msgpack when the
  optional dependency is present, JSON always).  ``numpy`` arrays ride
  as raw little-endian bytes (base64 under JSON), so a float32 tensor
  round-trips BIT-EXACTLY through either codec — the loopback soak's
  oracle parity check depends on that.

The codec is transport-agnostic: ``encode_frame``/``decode_frame`` work
on ``bytes`` (property-tested in tests/test_transport.py), and
``read_frame``/``write_frame`` adapt them to asyncio streams.
"""

from __future__ import annotations

import asyncio
import base64
import json
import struct

import numpy as np

try:  # optional, like hypothesis: the wire falls back to JSON without it
    import msgpack
    HAVE_MSGPACK = True
except ModuleNotFoundError:  # pragma: no cover - exercised in msgpack-less CI
    msgpack = None
    HAVE_MSGPACK = False

__all__ = [
    "CODECS", "DEFAULT_MAX_FRAME_BYTES", "FrameTooLargeError",
    "HAVE_MSGPACK", "HEADER_BYTES", "MalformedFrameError",
    "PROTOCOL_VERSION", "ProtocolVersionError", "TransportError",
    "TruncatedFrameError", "decode_frame", "default_codec", "encode_frame",
    "read_frame", "write_frame",
]

#: protocol generation; bumped on any incompatible frame/message change
PROTOCOL_VERSION = 1

#: two magic bytes open every frame: cheap resync/garbage detection
MAGIC = b"\xf5\x9e"

_HEADER = struct.Struct(">2sBBI")       # magic, version, codec id, length
HEADER_BYTES = _HEADER.size

#: payload size cap (bytes) applied on both encode and decode
DEFAULT_MAX_FRAME_BYTES = 32 << 20

_CODEC_IDS = {"json": 0, "msgpack": 1}
_CODEC_NAMES = {v: k for k, v in _CODEC_IDS.items()}

#: codecs THIS endpoint can encode/decode, preference order
CODECS = ("msgpack", "json") if HAVE_MSGPACK else ("json",)


class TransportError(RuntimeError):
    """Base class for frame-codec and wire failures."""


class MalformedFrameError(TransportError):
    """Bad magic, unknown codec, or an undecodable payload."""


class TruncatedFrameError(MalformedFrameError):
    """The stream/buffer ended mid-frame."""


class FrameTooLargeError(TransportError):
    """Declared payload length exceeds the size cap (either direction)."""


class ProtocolVersionError(TransportError):
    """The peer speaks a different protocol generation."""


def default_codec() -> str:
    """The preferred codec this endpoint supports (msgpack when present)."""
    return CODECS[0]


# --------------------------------------------------------------- payload
# ndarrays are tagged and carried as raw bytes so both codecs round-trip
# them bit-exactly; everything else must be JSON-able (dict/list/str/num).
_ND_TAG = "__nd__"


def _pack(obj, binary: bool):
    if isinstance(obj, np.ndarray):
        raw = np.ascontiguousarray(obj).tobytes()
        return {_ND_TAG: [str(obj.dtype), list(obj.shape)],
                "b": raw if binary else
                base64.b64encode(raw).decode("ascii")}
    if isinstance(obj, (np.integer, np.floating, np.bool_)):
        return obj.item()
    if isinstance(obj, dict):
        return {k: _pack(v, binary) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_pack(v, binary) for v in obj]
    return obj


def _restore(obj):
    if isinstance(obj, dict):
        tag = obj.get(_ND_TAG)
        if tag is not None:
            dtype, shape = tag
            raw = obj["b"]
            if isinstance(raw, str):
                raw = base64.b64decode(raw)
            return np.frombuffer(raw, dtype=np.dtype(dtype)).reshape(shape)
        return {k: _restore(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_restore(v) for v in obj]
    return obj


def _encode_payload(obj, codec: str) -> bytes:
    if codec == "json":
        return json.dumps(_pack(obj, binary=False),
                          separators=(",", ":")).encode("utf-8")
    if codec == "msgpack":
        if not HAVE_MSGPACK:
            raise MalformedFrameError(
                "msgpack codec requested but msgpack is not installed")
        return msgpack.packb(_pack(obj, binary=True), use_bin_type=True)
    raise MalformedFrameError(f"unknown codec {codec!r}")


def _decode_payload(payload: bytes, codec_id: int):
    name = _CODEC_NAMES.get(codec_id)
    if name is None:
        raise MalformedFrameError(f"unknown codec id {codec_id}")
    try:
        if name == "json":
            obj = json.loads(payload.decode("utf-8"))
        else:
            if not HAVE_MSGPACK:
                raise MalformedFrameError(
                    "peer sent a msgpack frame but msgpack is not "
                    "installed here")
            obj = msgpack.unpackb(payload, raw=False)
    except MalformedFrameError:
        raise
    except Exception as e:
        raise MalformedFrameError(f"undecodable {name} payload: {e}") from e
    return _restore(obj)


# ---------------------------------------------------------------- frames
def encode_frame(obj, codec: str | None = None,
                 max_bytes: int = DEFAULT_MAX_FRAME_BYTES) -> bytes:
    """Serialize one message into a framed byte string."""
    codec = codec or default_codec()
    payload = _encode_payload(obj, codec)
    if len(payload) > max_bytes:
        raise FrameTooLargeError(
            f"payload is {len(payload)} bytes, cap is {max_bytes}")
    return _HEADER.pack(MAGIC, PROTOCOL_VERSION, _CODEC_IDS[codec],
                        len(payload)) + payload


def decode_frame(buf: bytes, max_bytes: int = DEFAULT_MAX_FRAME_BYTES):
    """Parse one frame from ``buf``; returns ``(message, bytes_consumed)``.

    Raises :class:`TruncatedFrameError` when ``buf`` holds less than one
    complete frame — a stream consumer should read more and retry.
    """
    if len(buf) < HEADER_BYTES:
        raise TruncatedFrameError(
            f"need {HEADER_BYTES} header bytes, have {len(buf)}")
    magic, version, codec_id, length = _HEADER.unpack_from(buf)
    if magic != MAGIC:
        raise MalformedFrameError(f"bad frame magic {magic!r}")
    if version != PROTOCOL_VERSION:
        raise ProtocolVersionError(
            f"peer frame is protocol v{version}, this end speaks "
            f"v{PROTOCOL_VERSION}")
    if length > max_bytes:
        raise FrameTooLargeError(
            f"declared payload of {length} bytes exceeds cap {max_bytes}")
    end = HEADER_BYTES + length
    if len(buf) < end:
        raise TruncatedFrameError(
            f"need {end} bytes for the declared payload, have {len(buf)}")
    return _decode_payload(bytes(buf[HEADER_BYTES:end]), codec_id), end


# --------------------------------------------------------------- streams
async def read_frame(reader: asyncio.StreamReader,
                     max_bytes: int = DEFAULT_MAX_FRAME_BYTES,
                     on_bytes=None):
    """Read one frame from an asyncio stream.

    Returns the decoded message, or ``None`` on clean EOF (the peer
    closed between frames).  EOF *inside* a frame raises
    :class:`TruncatedFrameError`; an over-cap declared length raises
    :class:`FrameTooLargeError` before any payload byte is read.
    ``on_bytes``, when given, is called with the complete frame's size
    (header + payload) after a successful read — the socket layer's
    ``wire.bytes_in`` accounting hook.
    """
    try:
        hdr = await reader.readexactly(HEADER_BYTES)
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            return None
        raise TruncatedFrameError(
            f"stream ended {len(e.partial)} bytes into a frame "
            f"header") from e
    magic, version, codec_id, length = _HEADER.unpack(hdr)
    if magic != MAGIC:
        raise MalformedFrameError(f"bad frame magic {magic!r}")
    if version != PROTOCOL_VERSION:
        raise ProtocolVersionError(
            f"peer frame is protocol v{version}, this end speaks "
            f"v{PROTOCOL_VERSION}")
    if length > max_bytes:
        raise FrameTooLargeError(
            f"declared payload of {length} bytes exceeds cap {max_bytes}")
    try:
        payload = await reader.readexactly(length)
    except asyncio.IncompleteReadError as e:
        raise TruncatedFrameError(
            f"stream ended {len(e.partial)}/{length} bytes into a "
            f"frame payload") from e
    if on_bytes is not None:
        on_bytes(HEADER_BYTES + length)
    return _decode_payload(payload, codec_id)


async def write_frame(writer: asyncio.StreamWriter, obj,
                      codec: str | None = None,
                      max_bytes: int = DEFAULT_MAX_FRAME_BYTES) -> int:
    """Encode + write one frame and drain; returns bytes written."""
    frame = encode_frame(obj, codec, max_bytes)
    writer.write(frame)
    await writer.drain()
    return len(frame)
