"""Real socket transport for the serving gateway (asyncio streams).

``launch/gateway.py`` gave the fleet an asyncio edge, but its
"connections" were in-process objects — the "millions of users" north
star had no wire.  This module binds :class:`OverlayGateway` to a real
transport: an :class:`OverlaySocketServer` speaking the length-prefixed
frame fabric of ``launch/transport.py`` over asyncio streams, and a
:class:`RemoteOverlayClient` any process can point at ``host:port``.

The protocol is REGISTER-ONCE, the wire analogue of the paper's
time-multiplexed context bank (and of just-in-time overlay assembly:
ship the program description once, then address it by key):

* ``register`` — the client serializes a kernel's DFG and its content
  key (``repro.core.bank.context_key``: name + digest of the encoded
  instruction image).  The server compiles the DFG, *verifies the
  digest matches* (a corrupted or mismatched kernel is rejected, never
  silently served), and caches it in a server-wide registry.
* ``submit`` — every request after registration carries only the KEY,
  the input arrays, and a client request id.  No program bytes ride the
  hot path, exactly as no instruction fetch rides the overlay's
  steady-state datapath.

Everything the in-process edge guarantees carries over unchanged,
because every socket connection IS a ``GatewayConnection`` underneath:
per-connection admission, edge backpressure (a shed surfaces to the
client as :class:`GatewayOverloadedError` with the server's
``retry_after`` hint), session-keyed reconnect reclaim, and the
``flush_sync`` barrier (the ``flush`` frame runs the engine's
bit-for-bit barrier drain server-side).

Delivery is ACK-RETIRED so "zero ticket loss" survives a socket dying
mid-flight: the server holds every pushed result in a per-connection
unacked store until the client's ``ack`` frame retires it; results
still unacked when the connection drops are re-parked under the
session (``OverlayGateway.park_result``), so a reconnect reclaims them.
The boundary case — client received a result but its ack was lost —
re-delivers identical bytes on reclaim (at-least-once, never lost).

Telemetry rides the gateway's own sink under the ``wire.*`` namespace:
frames/bytes in/out, handshakes, registers, rejects, connections.

::

    # server process
    async with OverlaySocketServer.local(n_replicas=2, port=9178) as srv:
        await srv.serve_forever()

    # client process
    async with RemoteOverlayClient("127.0.0.1", 9178, tenant="alice",
                                   session="a-1") as client:
        t = await client.submit(kernel, [xs])      # registers once
        outs = await client.result(t)

See docs/SERVING.md#the-socket-transport for the frame schema and
``benchmarks/gateway_load.py --loopback`` for the framing-tax study.
"""

from __future__ import annotations

import asyncio
import collections
import itertools
import math

import numpy as np

from repro.core.bank import context_key
from repro.core.dfg import DFG, Node, Op
from repro.core.overlay import compile_program
from repro.launch.gateway import (GatewayClosedError, GatewayError,
                                  GatewayOverloadedError, OverlayGateway)
from repro.launch.transport import (DEFAULT_MAX_FRAME_BYTES, CODECS,
                                    FrameTooLargeError, MalformedFrameError,
                                    PROTOCOL_VERSION, ProtocolVersionError,
                                    TransportError, read_frame, write_frame)
from repro.sched.admission import AdmissionError

__all__ = [
    "OverlaySocketServer", "RemoteGatewayError", "RemoteOverlayClient",
    "dfg_from_wire", "dfg_to_wire",
]


class RemoteGatewayError(GatewayError):
    """A server-side failure with no more specific local exception."""


# --------------------------------------------------------- kernel handshake
def dfg_to_wire(dfg: DFG) -> dict:
    """Serialize a DFG for the register-once handshake (codec-neutral)."""
    return {
        "name": dfg.name,
        "inputs": list(dfg.inputs),
        "outputs": list(dfg.outputs),
        "nodes": [[n.name, int(n.op), list(n.args), n.imm]
                  for n in dfg.nodes.values()],
    }


def dfg_from_wire(spec: dict) -> DFG:
    """Rebuild (and re-validate) a DFG from its wire form."""
    nodes = [Node(name=name, op=Op(op), args=tuple(args), imm=imm)
             for name, op, args, imm in spec["nodes"]]
    return DFG.build(spec["name"], spec["inputs"], nodes, spec["outputs"])


def _error_to_exc(msg: dict) -> Exception:
    """Map a server ``error`` frame back onto the local exception type."""
    kind = msg.get("kind")
    text = msg.get("message", "")
    if kind == "overloaded":
        return GatewayOverloadedError(text,
                                      retry_after=msg.get("retry_after")
                                      or 0.0)
    if kind == "admission":
        return AdmissionError(msg.get("tenant", "?"),
                              msg.get("retry_after", math.inf))
    if kind == "closed":
        return GatewayClosedError(text)
    if kind == "version":
        return ProtocolVersionError(text)
    if kind == "unregistered":
        return KeyError(text)
    return RemoteGatewayError(f"{kind}: {text}")


class _SocketSession:
    """Server-side state of one accepted socket connection."""

    def __init__(self, writer: asyncio.StreamWriter):
        self.writer = writer
        self.wlock = asyncio.Lock()
        self.codec = "json"             # until the hello negotiates one
        self.conn = None                # the underlying GatewayConnection
        self.tasks: set[asyncio.Task] = set()
        #: results pushed but not yet acked: ticket -> outputs.  Whatever
        #: is still here when the socket dies is re-parked under the
        #: session so a reconnect reclaims it — delivery is only DONE
        #: when the client says so.
        self.unacked: dict[int, object] = {}

    def spawn(self, coro) -> None:
        t = asyncio.ensure_future(coro)
        self.tasks.add(t)
        t.add_done_callback(self.tasks.discard)


class OverlaySocketServer:
    """Asyncio-streams server binding an :class:`OverlayGateway` to TCP.

    ``gateway`` is wrapped, not owned: closing the server closes the
    listener and every accepted connection but leaves the gateway to its
    owner — unless the server built it via :meth:`local`.  ``port=0``
    binds an ephemeral port (read it back from :attr:`port` after
    :meth:`start`).
    """

    def __init__(self, gateway: OverlayGateway, host: str = "127.0.0.1",
                 port: int = 0, *,
                 max_frame_bytes: int = DEFAULT_MAX_FRAME_BYTES):
        self.gateway = gateway
        self.host = host
        self._port = port
        self.max_frame_bytes = max_frame_bytes
        self.telemetry = gateway.telemetry
        #: register-once kernel registry, shared across ALL connections:
        #: context key -> CompiledKernel
        self._registry: dict[tuple, object] = {}
        self._server: asyncio.AbstractServer | None = None
        self._sessions: set[_SocketSession] = set()
        self._owns_gateway = False
        self._closed = False

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def local(cls, host: str = "127.0.0.1", port: int = 0, *,
              max_frame_bytes: int = DEFAULT_MAX_FRAME_BYTES,
              **gateway_kw) -> "OverlaySocketServer":
        """Build engine + pump + gateway + socket server in one call
        (`OverlayGateway.local` under the hood); the server owns the
        gateway and closes it on ``aclose``."""
        srv = cls(OverlayGateway.local(**gateway_kw), host, port,
                  max_frame_bytes=max_frame_bytes)
        srv._owns_gateway = True
        return srv

    async def start(self) -> "OverlaySocketServer":
        """Bind and start accepting (idempotent)."""
        if self._server is not None:
            return self
        if self._closed:
            raise GatewayClosedError("socket server is closed")
        self.gateway._require_loop()
        self._server = await asyncio.start_server(
            self._handle, self.host, self._port)
        self._port = self._server.sockets[0].getsockname()[1]
        return self

    @property
    def port(self) -> int:
        """The bound port (the ephemeral one when built with ``port=0``)."""
        return self._port

    async def serve_forever(self) -> None:
        await self.start()
        await self._server.serve_forever()

    async def aclose(self) -> None:
        """Stop accepting, tear down live connections (their undelivered
        work parks under their sessions), and close the gateway if this
        server built it.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        handlers = [t for s in list(self._sessions) for t in (s.tasks or ())]
        for s in list(self._sessions):
            try:
                s.writer.close()
            except Exception:
                pass
        # handler coroutines notice EOF and unwind themselves; give their
        # per-submit tasks a chance to re-park before yanking them
        for t in handlers:
            t.cancel()
        if handlers:
            await asyncio.gather(*handlers, return_exceptions=True)
        while self._sessions:
            await asyncio.sleep(0.001)
        if self._owns_gateway:
            await self.gateway.aclose()

    async def __aenter__(self) -> "OverlaySocketServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    # -------------------------------------------------------------- metrics
    def stats(self) -> dict:
        """Wire-level counters + the wrapped gateway's stats dict."""
        tel = self.telemetry
        return {
            "listening": self._server is not None and not self._closed,
            "open_connections": len(self._sessions),
            "registered_kernels": len(self._registry),
            "wire_frames_in": int(tel.counter("wire.frames_in")),
            "wire_frames_out": int(tel.counter("wire.frames_out")),
            "wire_bytes_in": int(tel.counter("wire.bytes_in")),
            "wire_bytes_out": int(tel.counter("wire.bytes_out")),
            "wire_handshakes": int(tel.counter("wire.handshakes")),
            "wire_registers": int(tel.counter("wire.registers")),
            "wire_rejects": int(tel.counter("wire.rejects")),
            "wire_connections": int(tel.counter("wire.connections")),
            "wire_disconnects": int(tel.counter("wire.disconnects")),
            "wire_reparked": int(tel.counter("wire.reparked")),
            "gateway": self.gateway.stats(),
        }

    # ------------------------------------------------------------- handler
    async def _send(self, sess: _SocketSession, msg: dict,
                    codec: str | None = None) -> None:
        async with sess.wlock:
            n = await write_frame(sess.writer, msg, codec or sess.codec,
                                  self.max_frame_bytes)
        self.telemetry.inc("wire.frames_out")
        self.telemetry.inc("wire.bytes_out", n)

    def _count_in(self, n: int) -> None:
        self.telemetry.inc("wire.frames_in")
        self.telemetry.inc("wire.bytes_in", n)

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        tel = self.telemetry
        tel.inc("wire.connections")
        sess = _SocketSession(writer)
        self._sessions.add(sess)
        try:
            if await self._handshake(sess, reader):
                await self._read_loop(sess, reader)
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            if sess.conn is not None:
                # close the connection FIRST — its body is await-free, so
                # parking is atomic within this loop turn.  Cancelling the
                # serve tasks first would cancel their result futures and
                # then yield (gather), letting a pump tick claim a
                # delivered result into a cancelled future and drop it.
                await sess.conn.close()
            for t in list(sess.tasks):
                t.cancel()
            if sess.tasks:
                await asyncio.gather(*sess.tasks, return_exceptions=True)
            if sess.conn is not None:
                # everything pushed but never acked goes back to the
                # session's orphan store: the client may never have seen it
                for ticket, ys in sess.unacked.items():
                    self.gateway.park_result(sess.conn.session, ticket, ys)
                    tel.inc("wire.reparked")
                sess.unacked.clear()
            tel.inc("wire.disconnects")
            self._sessions.discard(sess)
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    async def _handshake(self, sess: _SocketSession,
                         reader: asyncio.StreamReader) -> bool:
        """Consume the hello frame; reply welcome (or a refusal).
        Returns True when the connection may proceed to the read loop."""
        tel = self.telemetry
        try:
            hello = await read_frame(reader, self.max_frame_bytes,
                                     on_bytes=self._count_in)
        except ProtocolVersionError as e:
            tel.inc("wire.rejects")
            await self._send(sess, {"type": "error", "kind": "version",
                                    "message": str(e)}, "json")
            return False
        except (MalformedFrameError, FrameTooLargeError) as e:
            tel.inc("wire.rejects")
            await self._send(sess, {"type": "error", "kind": "malformed",
                                    "message": str(e)}, "json")
            return False
        if hello is None:
            return False
        if not isinstance(hello, dict) or hello.get("type") != "hello":
            tel.inc("wire.rejects")
            await self._send(sess, {"type": "error", "kind": "protocol",
                                    "message": "expected a hello frame"},
                             "json")
            return False
        if hello.get("proto") != PROTOCOL_VERSION:
            tel.inc("wire.rejects")
            await self._send(sess, {
                "type": "error", "kind": "version",
                "message": (f"server speaks protocol v{PROTOCOL_VERSION}; "
                            f"client sent v{hello.get('proto')}")}, "json")
            return False
        offered = hello.get("codecs") or ["json"]
        sess.codec = next((c for c in CODECS if c in offered), "json")
        try:
            sess.conn = self.gateway.connect(
                tenant=hello.get("tenant") or "default",
                session=hello.get("session"))
        except GatewayClosedError as e:
            await self._send(sess, {"type": "error", "kind": "closed",
                                    "message": str(e)}, "json")
            return False
        tel.inc("wire.handshakes")
        await self._send(sess, {
            "type": "welcome", "proto": PROTOCOL_VERSION,
            "codec": sess.codec, "session": sess.conn.session,
            "tile": getattr(self.gateway.server, "tile", 128)}, "json")
        return True

    async def _read_loop(self, sess: _SocketSession,
                         reader: asyncio.StreamReader) -> None:
        tel = self.telemetry
        while True:
            try:
                msg = await read_frame(reader, self.max_frame_bytes,
                                       on_bytes=self._count_in)
            except (MalformedFrameError, FrameTooLargeError,
                    ProtocolVersionError) as e:
                tel.inc("wire.rejects")
                try:
                    await self._send(sess, {"type": "error",
                                            "kind": "malformed",
                                            "message": str(e)})
                except Exception:
                    pass
                return
            if msg is None or not isinstance(msg, dict) \
                    or msg.get("type") == "bye":
                return
            mtype = msg.get("type")
            if mtype == "register":
                await self._serve_register(sess, msg)
            elif mtype == "submit":
                sess.spawn(self._serve_submit(sess, msg))
            elif mtype == "flush":
                sess.spawn(self._serve_flush(sess, msg))
            elif mtype == "reclaim":
                sess.spawn(self._serve_reclaim(sess, msg))
            elif mtype == "ack":
                for t in msg.get("tickets") or ():
                    sess.unacked.pop(t, None)
            else:
                tel.inc("wire.rejects")
                await self._send(sess, {
                    "type": "error", "kind": "protocol",
                    "req": msg.get("req"),
                    "message": f"unknown frame type {mtype!r}"})

    # --------------------------------------------------------- frame serving
    async def _serve_register(self, sess: _SocketSession, msg: dict) -> None:
        req = msg.get("req")
        key = tuple(msg.get("key") or ())
        if key in self._registry:       # register-once: later ones are acks
            await self._send(sess, {"type": "registered", "req": req,
                                    "key": list(key)})
            return
        try:
            kernel = compile_program(dfg_from_wire(msg["dfg"]))
        except Exception as e:
            self.telemetry.inc("wire.rejects")
            await self._send(sess, {"type": "error", "kind": "bad_kernel",
                                    "req": req, "message": repr(e)})
            return
        actual = context_key(kernel)
        if tuple(actual) != key:
            # the client's claimed identity does not match what its DFG
            # compiles to — refuse rather than serve a kernel under a key
            # some other client may later collide with
            self.telemetry.inc("wire.rejects")
            await self._send(sess, {
                "type": "error", "kind": "key_mismatch", "req": req,
                "message": (f"claimed context key {key!r} but the DFG "
                            f"compiles to {tuple(actual)!r}")})
            return
        self._registry[key] = kernel
        self.telemetry.inc("wire.registers")
        self.telemetry.event("wire_register", key=list(key),
                             tenant=sess.conn.tenant)
        await self._send(sess, {"type": "registered", "req": req,
                                "key": list(key)})

    async def _serve_submit(self, sess: _SocketSession, msg: dict) -> None:
        conn, req = sess.conn, msg.get("req")
        kernel = self._registry.get(tuple(msg.get("key") or ()))
        if kernel is None:
            self.telemetry.inc("wire.rejects")
            await self._send(sess, {
                "type": "error", "kind": "unregistered", "req": req,
                "message": (f"kernel key {msg.get('key')!r} was never "
                            f"registered on this server")})
            return
        xs = [np.asarray(x) for x in msg.get("xs") or []]
        try:
            ticket = await conn.submit(kernel, xs)
        except GatewayOverloadedError as e:
            await self._send(sess, {"type": "error", "kind": "overloaded",
                                    "req": req, "message": str(e),
                                    "retry_after": e.retry_after})
            return
        except AdmissionError as e:
            await self._send(sess, {"type": "error", "kind": "admission",
                                    "req": req, "message": str(e),
                                    "tenant": e.tenant,
                                    "retry_after": e.retry_after})
            return
        except GatewayClosedError as e:
            await self._send(sess, {"type": "error", "kind": "closed",
                                    "req": req, "message": str(e)})
            return
        except asyncio.CancelledError:
            raise
        except Exception as e:
            await self._send(sess, {"type": "error", "kind": "internal",
                                    "req": req, "message": repr(e)})
            return
        await self._send(sess, {"type": "ticket", "req": req,
                                "ticket": ticket})
        try:
            ys = await conn.result(ticket)
        except (asyncio.CancelledError, GatewayClosedError):
            return      # teardown: conn.close() parks the ticket
        except KeyError as e:
            await self._send(sess, {"type": "error", "kind": "claimed",
                                    "req": req, "ticket": ticket,
                                    "message": str(e)})
            return
        ys = [np.asarray(y) for y in ys]
        sess.unacked[ticket] = ys       # before the write: no ack can race
        try:
            await self._send(sess, {"type": "result", "ticket": ticket,
                                    "ys": ys})
        except asyncio.CancelledError:
            raise                       # teardown re-parks via unacked
        except (ConnectionError, RuntimeError):
            pass                        # ditto: still in unacked

    async def _serve_flush(self, sess: _SocketSession, msg: dict) -> None:
        try:
            results = await self.gateway.flush_sync()
        except asyncio.CancelledError:
            raise
        except Exception as e:
            await self._send(sess, {"type": "error", "kind": "internal",
                                    "req": msg.get("req"),
                                    "message": repr(e)})
            return
        await self._send(sess, {"type": "flushed", "req": msg.get("req"),
                                "n": len(results)})

    async def _serve_reclaim(self, sess: _SocketSession, msg: dict) -> None:
        try:
            out = await sess.conn.reclaim()
        except asyncio.CancelledError:
            raise
        except Exception as e:
            await self._send(sess, {"type": "error", "kind": "internal",
                                    "req": msg.get("req"),
                                    "message": repr(e)})
            return
        pairs = [[t, [np.asarray(y) for y in ys]]
                 for t, ys in sorted(out.items())]
        # reclaim is claim-once gateway-side, so the values ride the
        # unacked store too: if this frame never lands, teardown re-parks
        for t, ys in pairs:
            sess.unacked[t] = ys
        await self._send(sess, {"type": "reclaimed", "req": msg.get("req"),
                                "results": pairs})


class RemoteOverlayClient:
    """Client end of the socket gateway: the `GatewayConnection` surface
    (``submit`` / ``result`` / ``results`` / ``drain`` / ``flush_sync`` /
    ``reclaim``) over one TCP connection.

    Kernels are registered once per (client, kernel) — ``submit`` sends
    the DFG on first use of a kernel and only its content key after.
    ``session`` names the reconnectable identity exactly like the
    in-process gateway: a client that dies with results in flight can be
    replaced by a new client with the same session id, and ``reclaim()``
    returns everything the server held for it.
    """

    def __init__(self, host: str, port: int, *, tenant: str = "default",
                 session: str | None = None,
                 max_frame_bytes: int = DEFAULT_MAX_FRAME_BYTES):
        self.host = host
        self.port = port
        self.tenant = tenant
        self.session = session
        self.max_frame_bytes = max_frame_bytes
        self.codec: str | None = None       # negotiated at connect
        self.tile = 128
        self.closed = False
        self.counters: collections.Counter = collections.Counter()
        self._reader: asyncio.StreamReader | None = None
        self._writer: asyncio.StreamWriter | None = None
        self._reader_task: asyncio.Task | None = None
        self._wlock = asyncio.Lock()
        self._req_seq = itertools.count()
        #: req id -> future (register/submit/flush/reclaim acks)
        self._reqs: dict[int, asyncio.Future] = {}
        #: ticket -> future resolving to its outputs
        self._results: dict[int, asyncio.Future] = {}
        #: context key -> future completing when registration is acked
        self._registered: dict[tuple, asyncio.Future] = {}

    # ------------------------------------------------------------ lifecycle
    async def connect(self) -> "RemoteOverlayClient":
        """Open the socket and run the hello/welcome handshake."""
        if self._writer is not None or self.closed:
            raise GatewayError("client already connected or closed")
        reader, writer = await asyncio.open_connection(self.host, self.port)
        self._reader, self._writer = reader, writer
        n = await write_frame(writer, {
            "type": "hello", "proto": PROTOCOL_VERSION,
            "tenant": self.tenant, "session": self.session,
            "codecs": list(CODECS)}, "json", self.max_frame_bytes)
        self._count("out", n)
        resp = await read_frame(reader, self.max_frame_bytes,
                                on_bytes=lambda n: self._count("in", n))
        if resp is None:
            raise TransportError("server closed during the handshake")
        if resp.get("type") == "error":
            raise _error_to_exc(resp)
        if resp.get("type") != "welcome":
            raise MalformedFrameError(
                f"expected a welcome frame, got {resp.get('type')!r}")
        self.codec = resp["codec"]
        self.tile = resp.get("tile", 128)
        self._reader_task = asyncio.get_running_loop().create_task(
            self._read_loop())
        return self

    async def aclose(self) -> None:
        """Close the connection (idempotent).  Results still in flight are
        re-parked server-side under this client's session."""
        if self.closed:
            return
        self.closed = True
        if self._writer is not None:
            try:
                await self._send({"type": "bye"})
            except Exception:
                pass
        if self._reader_task is not None:
            self._reader_task.cancel()
            try:
                await self._reader_task
            except (asyncio.CancelledError, Exception):
                pass
        self._fail_pending(GatewayClosedError("client closed"))
        if self._writer is not None:
            try:
                self._writer.close()
                await self._writer.wait_closed()
            except Exception:
                pass

    async def __aenter__(self) -> "RemoteOverlayClient":
        return await self.connect()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    # -------------------------------------------------------------- plumbing
    def _count(self, direction: str, n: int) -> None:
        self.counters[f"frames_{direction}"] += 1
        self.counters[f"bytes_{direction}"] += n

    def _check_open(self) -> None:
        if self.closed or self._writer is None:
            raise GatewayClosedError(
                f"client (tenant={self.tenant!r}, session={self.session!r})"
                f" is not connected")

    async def _send(self, msg: dict) -> None:
        async with self._wlock:
            n = await write_frame(self._writer, msg, self.codec,
                                  self.max_frame_bytes)
        self._count("out", n)

    def _new_req(self) -> tuple[int, asyncio.Future]:
        req = next(self._req_seq)
        fut = asyncio.get_running_loop().create_future()
        self._reqs[req] = fut
        return req, fut

    async def _read_loop(self) -> None:
        exc: Exception | None = None
        try:
            while True:
                msg = await read_frame(
                    self._reader, self.max_frame_bytes,
                    on_bytes=lambda n: self._count("in", n))
                if msg is None:
                    break
                await self._dispatch(msg)
        except asyncio.CancelledError:
            return
        except (TransportError, ConnectionError) as e:
            exc = e
        finally:
            self._fail_pending(exc or GatewayClosedError(
                "server closed the connection"))

    async def _dispatch(self, msg: dict) -> None:
        mtype = msg.get("type")
        if mtype == "ticket":
            ticket = msg["ticket"]
            loop = asyncio.get_running_loop()
            self._results.setdefault(ticket, loop.create_future())
            fut = self._reqs.pop(msg.get("req"), None)
            if fut is not None and not fut.done():
                fut.set_result(ticket)
        elif mtype == "result":
            # NOT acked here: the ack means "the caller CLAIMED it", so
            # results a dropping client received but never awaited stay
            # unacked server-side and re-park for reclaim — the wire
            # analogue of close() parking done-but-unawaited futures
            ticket = msg["ticket"]
            ys = [np.asarray(y) for y in msg.get("ys") or []]
            fut = self._results.get(ticket)
            if fut is not None and not fut.done():
                fut.set_result(ys)
                self.counters["delivered"] += 1
        elif mtype in ("registered", "flushed", "reclaimed"):
            fut = self._reqs.pop(msg.get("req"), None)
            if fut is not None and not fut.done():
                fut.set_result(msg)
        elif mtype == "error":
            exc = _error_to_exc(msg)
            req, ticket = msg.get("req"), msg.get("ticket")
            fut = self._reqs.pop(req, None) if req is not None else None
            if fut is None and ticket is not None:
                fut = self._results.pop(ticket, None)
            if fut is not None and not fut.done():
                fut.set_exception(exc)
            # a connection-level refusal (no req/ticket) fails everything
            elif fut is None:
                self._fail_pending(exc)

    def _fail_pending(self, exc: Exception) -> None:
        for fut in list(self._reqs.values()) + list(self._results.values()):
            if not fut.done():
                fut.set_exception(exc)
                fut.exception()         # mark retrieved: awaiters still see it
        self._reqs.clear()
        for key, fut in list(self._registered.items()):
            if not fut.done():
                fut.set_exception(exc)
                fut.exception()

    async def _ack(self, tickets) -> None:
        """Retire claimed tickets server-side (best effort: a closed
        connection just leaves them unacked, i.e. reclaimable)."""
        tickets = list(tickets)
        if not tickets or self.closed or self._writer is None:
            return
        try:
            await self._send({"type": "ack", "tickets": tickets})
        except (ConnectionError, RuntimeError):
            pass

    # ---------------------------------------------------------------- client
    async def _ensure_registered(self, kernel) -> tuple:
        key = context_key(kernel)
        fut = self._registered.get(key)
        if fut is not None:
            await asyncio.shield(fut)
            return key
        loop = asyncio.get_running_loop()
        fut = self._registered[key] = loop.create_future()
        req, ack = self._new_req()
        try:
            await self._send({"type": "register", "req": req,
                              "key": list(key),
                              "dfg": dfg_to_wire(kernel.dfg)})
            await ack
        except Exception as e:
            self._registered.pop(key, None)
            if not fut.done():
                fut.set_exception(e)
                fut.exception()
            raise
        self.counters["registered"] += 1
        if not fut.done():
            fut.set_result(True)
        return key

    async def submit(self, kernel, xs) -> int:
        """Register-once + submit; returns the fleet's global ticket.

        Server-side admission and backpressure surface as the SAME
        exceptions the in-process gateway raises (``AdmissionError``,
        ``GatewayOverloadedError`` with ``retry_after``, ...).
        """
        self._check_open()
        key = await self._ensure_registered(kernel)
        req, fut = self._new_req()
        await self._send({"type": "submit", "req": req, "key": list(key),
                          "xs": [np.asarray(x) for x in xs]})
        ticket = await fut
        self.counters["submitted"] += 1
        return ticket

    async def result(self, ticket: int):
        """Await one ticket's outputs (claim-once, like the engine)."""
        self._check_open()
        fut = self._results.get(ticket)
        if fut is None:
            raise KeyError(f"ticket {ticket} is not outstanding on this "
                           f"client")
        try:
            ys = await fut
        finally:
            if fut.done() and not fut.cancelled():
                self._results.pop(ticket, None)
        await self._ack([ticket])
        return ys

    async def results(self):
        """``async for ticket, outs`` in completion order, streaming."""
        while self._results:
            self._check_open()
            done = [t for t, f in self._results.items() if f.done()]
            if not done:
                await asyncio.wait(list(self._results.values()),
                                   return_when=asyncio.FIRST_COMPLETED)
                continue
            for t in done:
                fut = self._results.pop(t)
                await self._ack([t])
                yield t, fut.result()

    async def drain(self) -> dict:
        """Await everything outstanding on this client."""
        out = {}
        async for t, ys in self.results():
            out[t] = ys
        return out

    async def flush_sync(self) -> dict:
        """Run the engine's barrier drain server-side, then claim every
        ticket outstanding on THIS client; returns ``{ticket: outputs}``."""
        self._check_open()
        req, fut = self._new_req()
        await self._send({"type": "flush", "req": req})
        await fut                       # barrier completed server-side
        out = {}
        for t in list(self._results):
            out[t] = await self.result(t)
        return out

    async def reclaim(self) -> dict:
        """Claim results parked under this client's session by a previous
        (dropped) connection — exactly once server-side."""
        self._check_open()
        req, fut = self._new_req()
        await self._send({"type": "reclaim", "req": req})
        msg = await fut
        out = {int(t): [np.asarray(y) for y in ys]
               for t, ys in msg.get("results") or []}
        await self._ack(out)            # returned to the caller = claimed
        self.counters["reclaimed"] += len(out)
        return out

    @property
    def outstanding(self) -> frozenset[int]:
        """Tickets submitted on this client and not yet claimed."""
        return frozenset(self._results)

    def stats(self) -> dict:
        return {"codec": self.codec, "closed": self.closed,
                "outstanding": len(self._results),
                **{k: int(v) for k, v in sorted(self.counters.items())}}
