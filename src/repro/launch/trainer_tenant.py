"""Training as a tenant: co-scheduled train + serve under one fleet.

``TrainingTenant`` slices a training run (``launch.train.run_training``
over ``runtime.steps.make_train_step``) into bounded MICRO-ROUNDS and
submits each through the serving engine — ``OverlayServer`` or
``ShardedOverlayServer`` — as a bulk-tier work flow
(``server.submit_work``).  The engine's round policy is wrapped in
:class:`repro.sched.preempt.PreemptibleTier`, so:

* a training micro-round only occupies a round slot the latency tier
  left idle (bulk rounds form only when NO latency flow is queued);
* a latency arrival mid-micro-round preempts training BETWEEN
  micro-steps, never mid-step: the ``should_yield`` hook is polled at
  every step boundary, and every boundary is a complete checkpoint —
  params, optimizer moments, error-feedback ``ef``, and the
  data-pipeline cursor advance atomically per step (the
  ``run_training`` yield-point contract), so preempt/resume is
  exactly-once by construction;
* ``tenant_quanta`` on the inner DRR bounds training's share among
  bulk flows, and the tier structure means serving can starve training
  to zero throughput but training can NEVER starve serving.

The differential guarantee (tests/test_train_tenant.py): a co-scheduled
run is BIT-IDENTICAL — params, opt_state, loss trace — to a standalone
``run_training`` loop on the same seed, under every round policy and
under fleet grow/drain churn.  benchmarks/train_serve_study.py measures
the serving-p99 cost of co-scheduling at matched load.

Quickstart::

    server = OverlayServer(bank_capacity=8)
    tenant = TrainingTenant(server, cfg, oc, dc, steps=100,
                            yield_every=4)
    while not tenant.done:
        tenant.tick()          # claim last round / submit the next
        server.flush()         # latency traffic rides the same drain
    final_params = tenant.params
"""

from __future__ import annotations

import time

from repro.data.pipeline import SyntheticCorpus
from repro.launch.train import run_training
from repro.sched.preempt import BULK_PREFIX
from repro.telemetry import InMemorySink, MultiSink

__all__ = ["TrainingTenant"]

#: default tenant name — the ``bulk:`` prefix alone marks it bulk-tier
DEFAULT_TRAIN_TENANT = BULK_PREFIX + "train"


class TrainingTenant:
    """Drive a training run through a serving engine as a bulk tenant.

    Parameters
    ----------
    server : OverlayServer | ShardedOverlayServer
        The engine to co-schedule under.  Its round policy is wrapped
        in ``PreemptibleTier`` (idempotent) via ``make_preemptible``.
    cfg, oc, dc :
        Model / optimizer / data configs, exactly as ``run_training``
        takes them.
    steps : int
        Total training steps for the run.
    tenant : str
        Flow name; must be bulk-tier (default ``"bulk:train"``).
    yield_every : int
        Max micro-steps per micro-round — the preemption granularity.
        ``should_yield`` is polled between steps, so a micro-round
        occupies the engine for at most ``yield_every`` steps and
        usually fewer under latency pressure.
    cost_tiles : int
        Admission/DRR cost charged per micro-round (work requests hold
        no tiles; this is the scheduling weight).
    should_yield : callable | None
        Zero-arg predicate polled between micro-steps; True preempts
        the micro-round.  Defaults to "any latency-tier tenant has
        queued tiles" (``server.queued_by_tenant``).
    telemetry :
        Own sink for the ``train.*`` counters; defaults to a fresh
        ``InMemorySink`` fanned out to the server's sink through
        ``MultiSink``, so fleet-level stores see training counters too.
    """

    def __init__(self, server, cfg, oc, dc, *, steps: int,
                 tenant: str = DEFAULT_TRAIN_TENANT, yield_every: int = 4,
                 cost_tiles: int = 1, compress_grads: bool = False,
                 mixed: bool = False, corpus=None, params=None,
                 opt_state=None, start_step: int = 0, should_yield=None,
                 step_fn=None, telemetry=None, clock=time.monotonic):
        if steps <= start_step:
            raise ValueError(f"steps ({steps}) must exceed "
                             f"start_step ({start_step})")
        if yield_every < 1:
            raise ValueError(f"yield_every must be >= 1, got {yield_every}")
        self.server = server
        self.tenant = tenant
        self.steps = int(steps)
        self.yield_every = int(yield_every)
        self.cost_tiles = max(1, int(cost_tiles))
        self.clock = clock
        self._should_yield = (should_yield if should_yield is not None
                              else self._latency_backlogged)
        own = telemetry if telemetry is not None else InMemorySink()
        server_sink = getattr(server, "telemetry", None)
        self.telemetry = (MultiSink(own, server_sink)
                          if server_sink is not None else own)
        # installs (or extends) the PreemptibleTier over the engine's
        # round policy — every replica on a sharded fleet, and every
        # replica added later (the fleet remembers the bulk spec)
        server.make_preemptible(bulk_tenants={tenant})
        self.corpus = corpus if corpus is not None else SyntheticCorpus(dc)
        # yield_every=1 → one record per step: every step boundary is a
        # yield point the tenant can commit and preempt at
        self._gen = run_training(
            cfg, oc, dc, steps=self.steps, yield_every=1,
            corpus=self.corpus, params=params, opt_state=opt_state,
            start_step=start_step, compress_grads=compress_grads,
            mixed=mixed, step_fn=step_fn)
        #: committed state — updated at every yield point, never mid-step
        self.params = params
        self.opt_state = opt_state
        self.cursor = self.corpus.cursor(start_step)
        self.losses: list[float] = []
        self.step_trace: list[int] = []
        self.last_loss: float | None = None
        self._ticket: int | None = None
        self._exhausted = False
        self._resume_pending = False
        self._last_preempted = False
        self._last_summary: dict | None = None

    # ------------------------------------------------------------- predicates
    def _latency_backlogged(self) -> bool:
        """Default preemption signal: any NON-bulk tenant has queued
        work on the engine.  Bulk flows (including this tenant) never
        trigger a yield — bulk does not preempt bulk."""
        q = self.server.queued_by_tenant()
        return any(tiles > 0 and t != self.tenant
                   and not str(t).startswith(BULK_PREFIX)
                   for t, tiles in q.items())

    @property
    def done(self) -> bool:
        """True once every step is committed AND its result claimed."""
        return self._exhausted and self._ticket is None

    @property
    def outstanding(self) -> bool:
        """A micro-round is submitted and not yet claimed."""
        return self._ticket is not None

    # ------------------------------------------------------------ micro-round
    def _micro_round(self) -> dict:
        """The work callable one engine round runs: up to ``yield_every``
        training steps, committing state at EVERY step boundary and
        polling ``should_yield`` between steps.  Returns a light
        summary (floats only — safe to park in a fleet orphan store)."""
        t0 = self.clock()
        steps: list[int] = []
        losses: list[float] = []
        preempted = False
        for _ in range(self.yield_every):
            try:
                rec = next(self._gen)
            except StopIteration:
                self._exhausted = True
                break
            # the commit: every field advances together or not at all
            self.params = rec["params"]
            self.opt_state = rec["opt_state"]
            self.cursor = rec["cursor"]
            self.last_loss = rec["loss"]
            self.losses.append(rec["loss"])
            self.step_trace.append(rec["step"])
            steps.append(rec["step"])
            losses.append(rec["loss"])
            self.telemetry.inc("train.steps")
            if rec["step"] + 1 >= self.steps:
                self._exhausted = True
                break
            if self._should_yield():
                preempted = True
                self.telemetry.inc("train.preemptions")
                break
        self.telemetry.inc("train.micro_rounds")
        self.telemetry.inc("train.yield_wall_s", self.clock() - t0)
        self._last_preempted = preempted
        return {"steps": steps, "losses": losses, "preempted": preempted}

    # ------------------------------------------------------------------ drive
    def tick(self):
        """One scheduling beat: claim the last micro-round's result if
        delivered, then (if idle and not finished) submit the next
        micro-round.  Never blocks; call between engine drains.  Returns
        the most recently CLAIMED summary, or None before the first."""
        if self._ticket is not None:
            try:
                out = self.server.try_result(self._ticket)
            except KeyError:
                # a flush()/as_completed() driver claimed the summary
                # already — fine: every state commit lives on the tenant
                # itself, the ticket's payload is informational
                out = {"preempted": self._last_preempted}
            if out is None:
                return self._last_summary
            self._ticket = None
            self._last_summary = out
            if out.get("preempted"):
                self._resume_pending = True
            self._last_preempted = False
        if not self._exhausted and self._ticket is None:
            if self._resume_pending:
                self.telemetry.inc("train.resumes")
                self._resume_pending = False
            self._ticket = self.server.submit_work(
                self._micro_round, tenant=self.tenant,
                cost=self.cost_tiles, label="train")
        return self._last_summary

    def run(self, *, max_rounds: int | None = None) -> dict:
        """Convenience synchronous drive: tick + flush until ``done``.

        With latency traffic enqueued by someone else between flushes,
        the tier serves it first; alone, this trains flat-out.  Returns
        ``stats()``."""
        rounds = 0
        while not self.done:
            self.tick()
            if self._ticket is not None:
                self.server.flush()
            rounds += 1
            if max_rounds is not None and rounds >= max_rounds:
                break
        return self.stats()

    # ------------------------------------------------------------------ stats
    def stats(self) -> dict:
        """Schema-checked (``check_stats("train", ...)``) counter view."""
        c = self.telemetry.counter
        return {
            "tenant": self.tenant,
            "steps": int(c("train.steps")),
            "total_steps": self.steps,
            "micro_rounds": int(c("train.micro_rounds")),
            "preemptions": int(c("train.preemptions")),
            "resumes": int(c("train.resumes")),
            "yield_wall_s": float(c("train.yield_wall_s")),
            "last_loss": self.last_loss,
            "done": self.done,
            "outstanding": self.outstanding,
        }
