"""Training launcher.

  python -m repro.launch.train --arch deepseek-7b --smoke --steps 20
  python -m repro.launch.train --arch mamba2-2.7b --smoke --steps 50 \
      --ckpt-dir /tmp/ck --ckpt-every 10 --simulate-failure-at 30

On real hardware this runs under the production mesh; on CPU it uses the
host's devices (optionally --force-devices N for a simulated mesh).
Features exercised: sharded params/opt, remat'd scanned stacks, AdamW,
async checkpointing, deterministic resumable data, simulated-failure
restart (elastic re-mesh), optional int8 gradient compression.

The step loop itself lives in :func:`run_training` — an importable
generator shared by this CLI and the co-scheduled training tenant
(``launch.trainer_tenant.TrainingTenant``), so "training as a tenant"
runs the EXACT same per-step math as the standalone launcher
(tests/test_train_tenant.py holds the two bit-identical).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import init_params
from repro.runtime import optim as O
from repro.runtime.steps import decorate_batch, make_train_step

__all__ = ["main", "run_training"]


def run_training(cfg, oc, dc, *, steps: int, yield_every: int = 1,
                 corpus=None, params=None, opt_state=None,
                 start_step: int = 0, compress_grads: bool = False,
                 mixed: bool = False, donate: bool = False, step_fn=None):
    """Generator over training steps: the importable step-slicing loop.

    Runs ``make_train_step(cfg, oc, ...)`` from ``start_step`` to
    ``steps``, yielding a RECORD at every yield point — after every
    step by default, after every ``yield_every``-th step otherwise.
    Each record carries::

        {"step", "loss", "grad_norm", "lr", "wall_s",   # floats
         "window",              # [(step, loss, grad_norm, lr), ...]
                                # per-step floats since the last yield
         "params", "opt_state", # the post-step state (live refs)
         "cursor"}              # corpus cursor for step+1 (resume token)

    The yield points ARE the preempt/resume contract: a consumer that
    stops iterating between records (the training tenant preempting for
    latency traffic) holds a complete, consistent checkpoint — params,
    optimizer moments, error-feedback ``ef`` (inside ``opt_state`` when
    ``compress_grads``), and the data-pipeline cursor all advance
    atomically per step, never mid-step.  Resuming is re-entering
    ``run_training`` with the yielded ``params``/``opt_state`` and
    ``start_step = record["step"] + 1`` on the same ``dc`` seed — or
    simply continuing to iterate the SAME generator (what the tenant
    does), which is exactly-once by construction.

    ``params``/``opt_state`` default to the standard seed-0 init;
    ``step_fn`` defaults to a fresh ``jax.jit`` of the step (pass one in
    to share compilation across restarts).  ``donate=True`` donates
    params/opt buffers to the jit for the CLI's memory profile — then
    only the LATEST record's state refs are valid.
    """
    if steps <= start_step:
        return
    if yield_every < 1:
        raise ValueError(f"yield_every must be >= 1, got {yield_every}")
    corpus = corpus if corpus is not None else SyntheticCorpus(dc)
    if params is None:
        params = init_params(cfg, jax.random.PRNGKey(0))
    if opt_state is None:
        opt_state = (O.init_opt_mixed(params) if mixed
                     else O.init_opt(params))
    if step_fn is None:
        step_fn = jax.jit(
            make_train_step(cfg, oc, compress_grads=compress_grads,
                            mixed=mixed),
            donate_argnums=(0, 1) if donate else ())
    window: list[tuple] = []
    for step in range(start_step, steps):
        batch = decorate_batch(cfg, dc, corpus.batch(step))
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])     # blocks: the step is DONE here
        wall = time.perf_counter() - t0
        window.append((step, loss, float(metrics["grad_norm"]),
                       float(metrics["lr"])))
        if (step + 1 - start_step) % yield_every == 0 or step + 1 == steps:
            yield {"step": step, "loss": loss,
                   "grad_norm": window[-1][2], "lr": window[-1][3],
                   "wall_s": wall, "window": window,
                   "params": params, "opt_state": opt_state,
                   "cursor": corpus.cursor(step + 1)}
            window = []


def main(argv=None):
    from repro.distributed import checkpoint as C
    from repro.distributed.elastic import remesh, reshard_tree
    from repro.runtime import sharding as S

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure-at", type=int, default=None,
                    help="drop devices + re-mesh + restore at this step")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--trace-out", default=None,
                    help="write the exact {steps, losses} trace as JSON "
                         "(the CLI-vs-library differential test reads it)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    devices = list(jax.devices())
    mesh = remesh(devices, model_parallel=min(
        len(devices), 16 if not args.smoke else 1))
    ax = S.for_mesh(mesh)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"arch: {cfg.name} params~{cfg.param_count():,}")

    oc = O.OptConfig(lr=args.lr, total_steps=max(args.steps, 10),
                     warmup_steps=max(2, args.steps // 20))
    dc = DataConfig(global_batch=args.batch, seq_len=args.seq,
                    vocab=cfg.vocab)
    corpus = SyntheticCorpus(dc)

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = O.init_opt(params)
    start_step = 0
    ckpt = C.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir and C.list_steps(args.ckpt_dir):
        (params, opt_state), start_step, extra = C.restore(
            args.ckpt_dir, (params, opt_state))
        print(f"resumed from step {start_step}")

    def _reshard(params, opt_state, mesh, ax):
        pspec = S.sanitize(S.param_shardings(cfg, mesh, ax),
                           jax.eval_shape(lambda: params), mesh)
        params = reshard_tree(params, pspec, mesh)
        opt_state = {"m": reshard_tree(opt_state["m"], pspec, mesh),
                     "v": reshard_tree(opt_state["v"], pspec, mesh),
                     "count": opt_state["count"]}
        return params, opt_state

    def _step_fn():
        return jax.jit(make_train_step(cfg, oc,
                                       compress_grads=args.compress_grads),
                       donate_argnums=(0, 1))

    params, opt_state = _reshard(params, opt_state, mesh, ax)
    step_fn = _step_fn()

    tokens_per_step = args.batch * args.seq
    t_hist = []
    trace = {"steps": [], "losses": []}
    fail_at = args.simulate_failure_at
    while start_step < args.steps:
        last_step = None
        with mesh:
            for rec in run_training(cfg, oc, dc, steps=args.steps,
                                    corpus=corpus, params=params,
                                    opt_state=opt_state,
                                    start_step=start_step, step_fn=step_fn):
                step, loss = rec["step"], rec["loss"]
                params, opt_state = rec["params"], rec["opt_state"]
                last_step = step
                t_hist.append(rec["wall_s"])
                trace["steps"].append(step)
                trace["losses"].append(loss)
                if step % args.log_every == 0:
                    print(f"step {step:5d} loss {loss:8.4f} "
                          f"gnorm {rec['grad_norm']:8.3f} "
                          f"lr {rec['lr']:.2e} "
                          f"{tokens_per_step / rec['wall_s']:,.0f} tok/s")
                if not np.isfinite(loss):
                    print("NaN/inf loss — aborting")
                    return 1
                if ckpt and (step + 1) % args.ckpt_every == 0:
                    ckpt.save_async(step + 1, (params, opt_state),
                                    extra=rec["cursor"])
                if fail_at is not None and step + 1 == fail_at:
                    break           # "device loss" before step fail_at runs
        if last_step is None or last_step + 1 >= args.steps:
            break
        if fail_at is not None and last_step + 1 == fail_at:
            print(f"[elastic] simulating failure at step {fail_at}: "
                  f"dropping half the devices + restoring checkpoint")
            assert ckpt is not None, "--ckpt-dir required"
            ckpt.wait()
            mesh = remesh(devices[: max(1, len(devices) // 2)],
                          model_parallel=1)
            ax = S.for_mesh(mesh)
            (params, opt_state), rstep, extra = C.restore(
                args.ckpt_dir, jax.eval_shape(lambda: (params, opt_state)))
            params, opt_state = _reshard(params, opt_state, mesh, ax)
            step_fn = _step_fn()
            start_step = rstep
            fail_at = None
        else:
            start_step = last_step + 1
    if ckpt:
        ckpt.save_async(args.steps, (params, opt_state),
                        extra=corpus.cursor(args.steps))
        ckpt.wait()
    if args.trace_out:
        with open(args.trace_out, "w") as f:
            json.dump(trace, f)
    med = float(np.median(t_hist)) if t_hist else 0.0
    print(f"done: median step {med * 1e3:.1f} ms, "
          f"{tokens_per_step / med:,.0f} tok/s" if med else "done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
