"""Training launcher.

  python -m repro.launch.train --arch deepseek-7b --smoke --steps 20
  python -m repro.launch.train --arch mamba2-2.7b --smoke --steps 50 \
      --ckpt-dir /tmp/ck --ckpt-every 10 --simulate-failure-at 30

On real hardware this runs under the production mesh; on CPU it uses the
host's devices (optionally --force-devices N for a simulated mesh).
Features exercised: sharded params/opt, remat'd scanned stacks, AdamW,
async checkpointing, deterministic resumable data, simulated-failure
restart (elastic re-mesh), optional int8 gradient compression.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure-at", type=int, default=None,
                    help="drop devices + re-mesh + restore at this step")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from repro.distributed import checkpoint as C
    from repro.distributed.elastic import remesh, reshard_tree
    from repro.models import init_params
    from repro.runtime import optim as O
    from repro.runtime import sharding as S
    from repro.runtime.steps import make_train_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    devices = list(jax.devices())
    mesh = remesh(devices, model_parallel=min(
        len(devices), 16 if not args.smoke else 1))
    ax = S.for_mesh(mesh)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"arch: {cfg.name} params~{cfg.param_count():,}")

    oc = O.OptConfig(lr=args.lr, total_steps=max(args.steps, 10),
                     warmup_steps=max(2, args.steps // 20))
    dc = DataConfig(global_batch=args.batch, seq_len=args.seq,
                    vocab=cfg.vocab)
    corpus = SyntheticCorpus(dc)

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = O.init_opt(params)
    start_step = 0
    ckpt = C.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir and C.list_steps(args.ckpt_dir):
        (params, opt_state), start_step, extra = C.restore(
            args.ckpt_dir, (params, opt_state))
        print(f"resumed from step {start_step}")

    pspec = S.sanitize(S.param_shardings(cfg, mesh, ax),
                       jax.eval_shape(lambda: params), mesh)
    params = reshard_tree(params, pspec, mesh)
    opt_state = {"m": reshard_tree(opt_state["m"], pspec, mesh),
                 "v": reshard_tree(opt_state["v"], pspec, mesh),
                 "count": opt_state["count"]}

    step_fn = jax.jit(make_train_step(cfg, oc,
                                      compress_grads=args.compress_grads),
                      donate_argnums=(0, 1))

    tokens_per_step = args.batch * args.seq
    t_hist = []
    with mesh:
        for step in range(start_step, args.steps):
            if args.simulate_failure_at is not None \
                    and step == args.simulate_failure_at:
                print(f"[elastic] simulating failure at step {step}: "
                      f"dropping half the devices + restoring checkpoint")
                assert ckpt is not None, "--ckpt-dir required"
                ckpt.wait()
                mesh = remesh(devices[: max(1, len(devices) // 2)],
                              model_parallel=1)
                ax = S.for_mesh(mesh)
                (params, opt_state), rstep, extra = C.restore(
                    args.ckpt_dir, jax.eval_shape(lambda: (params,
                                                           opt_state)))
                step = rstep
                pspec = S.sanitize(S.param_shardings(cfg, mesh, ax),
                                   jax.eval_shape(lambda: params), mesh)
                params = reshard_tree(params, pspec, mesh)
                opt_state = {"m": reshard_tree(opt_state["m"], pspec, mesh),
                             "v": reshard_tree(opt_state["v"], pspec, mesh),
                             "count": opt_state["count"]}
                step_fn = jax.jit(make_train_step(
                    cfg, oc, compress_grads=args.compress_grads),
                    donate_argnums=(0, 1))
                args.simulate_failure_at = None
            batch = corpus.batch(step)
            if cfg.vision_tokens:
                batch["vision_embeds"] = jnp.zeros(
                    (dc.local_batch, cfg.vision_tokens, cfg.d_model),
                    jnp.bfloat16)
            if cfg.encoder is not None:
                batch["frame_embeds"] = jnp.zeros(
                    (dc.local_batch, args.seq, cfg.d_model), jnp.bfloat16)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            t_hist.append(dt)
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):8.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"{tokens_per_step / dt:,.0f} tok/s")
            if not np.isfinite(loss):
                print("NaN/inf loss — aborting")
                return 1
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(step + 1, (params, opt_state),
                                extra=corpus.cursor(step + 1))
    if ckpt:
        ckpt.save_async(args.steps, (params, opt_state),
                        extra=corpus.cursor(args.steps))
        ckpt.wait()
    med = float(np.median(t_hist)) if t_hist else 0.0
    print(f"done: median step {med * 1e3:.1f} ms, "
          f"{tokens_per_step / med:,.0f} tok/s" if med else "done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
