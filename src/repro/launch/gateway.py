"""Asyncio serving gateway: the fleet's network-shaped front door.

Everything behind this module is a THREADED serving stack — the engines
(``launch.serve.OverlayServer`` / ``ShardedOverlayServer``) are driven by
``sched.pump.AutoPump``'s background drain thread, and every entry point
blocks under one reentrant lock.  That is the right shape for in-process
Python callers and exactly the wrong shape for "millions of users": a
front-end must hold thousands of cheap concurrent conversations, each
submitting a trickle and awaiting its own results.  ``OverlayGateway``
bridges the two worlds the way JIT-assembly overlays keep a heavy
resident datapath behind a thin stateful control plane: the pump thread
keeps the device busy, and an asyncio event loop multiplexes the
connections.

The bridge, concretely:

* ``GatewayConnection.submit`` is a coroutine returning the fleet's own
  global ticket; ``await conn.result(ticket)`` and the streaming
  ``async for ticket, outs in conn.results()`` resolve from per-ticket
  ``asyncio.Future``\\ s.
* The pump's TICK is the only signal: the gateway registers an
  ``AutoPump.add_tick_listener`` observer, and every pump iteration
  (productive or idle) schedules one ``_tick`` on the event loop via
  ``loop.call_soon_threadsafe`` — the pump thread never touches asyncio
  state directly, and the loop never blocks on the engine beyond one
  batched ``try_results`` claim under the pump lock.
* ADMISSION is per connection: each connection carries its own
  ``sched.admission.AdmissionControl`` (token buckets in dispatch
  tiles), layered above whatever fleet-level admission the engine was
  built with.

Backpressure is COUPLED to the autoscaler (the interesting part):

* The edge enforces ``max_fleet_tiles`` — a submit that would push the
  fleet's undelivered depth (``pending_tiles``) past the bound either
  parks at the edge (``overflow="wait"``: the coroutine suspends, FIFO)
  or is shed (``overflow="shed"``: ``GatewayOverloadedError``).  Fleet
  queue depth therefore stays bounded no matter how many connections
  pile in; the benchmark asserts shedding engages BEFORE the bound is
  exceeded.
* While the fleet's :class:`~repro.sched.autoscale.PressureAutoscaler`
  reports ``scale_up_pending`` (pressure observed, capacity below
  ``max_replicas``), the edge WIDENS: the depth bound and every
  connection's admission window stretch by ``widen_factor`` — capacity
  is coming, so queueing a little deeper beats rejecting traffic the
  grown fleet could have served.  The widening REVERTS automatically
  when the scale-up lands (the autoscaler's hot streak resets on the
  ``up`` decision).
* When the autoscaler is ``saturated`` (wants to grow, fleet at
  ``max_replicas``) — or scaling down — no widening applies: overload
  sheds/queues at the gateway edge instead of accumulating inside the
  fleet, which is where it would bloat every tenant's latency tail.

Disconnect is GRACEFUL and loss-free: closing (or dropping) a connection
cancels its pending awaits, but its fleet-side tickets are never
orphaned — the gateway parks them in a per-``session`` registry while
their results land in the engine's delivered store (or the fleet orphan
store, if their replica is drained meanwhile), and a reconnect with the
same session id reclaims every one of them exactly once
(``conn.reclaim()``).  ``flush_sync`` through the gateway delegates to
the engine's barrier drain under the pump lock — the bit-for-bit oracle
is unchanged by the asyncio layer (tests/test_gateway.py holds it to
that).

::

    async with OverlayGateway.local(n_replicas=2, autoscale=True) as gw:
        async with gw.connect(tenant="alice", session="a-1") as conn:
            t = await conn.submit(kernel, xs)
            outs = await conn.result(t)

See docs/SERVING.md#the-asyncio-gateway for the API and knob guide, and
``benchmarks/gateway_load.py`` for the load-generator study.
"""

from __future__ import annotations

import asyncio
import collections
import dataclasses
import time

import numpy as np

from repro.sched import AdmissionControl, AutoPump

__all__ = [
    "DEFAULT_RETRY_AFTER", "GatewayClosedError", "GatewayConnection",
    "GatewayError", "GatewayOverloadedError", "OverlayGateway",
]

#: fallback resubmission hint (seconds) when the pump's poll interval is
#: unavailable — the pump stopped, or its interval is unset/invalid
DEFAULT_RETRY_AFTER = 0.05


class GatewayError(RuntimeError):
    """Base class for gateway-edge failures."""


class GatewayClosedError(GatewayError):
    """The gateway or connection was closed; no further submits."""


class GatewayOverloadedError(GatewayError):
    """Shed at the edge: admitting this request would push fleet depth
    past the configured bound (and the edge is not parking work).

    ``retry_after`` is a resubmission hint in seconds — one pump poll
    interval, i.e. the soonest the pressure reading can change.
    """

    def __init__(self, msg: str, retry_after: float = 0.0):
        super().__init__(msg)
        self.retry_after = retry_after


@dataclasses.dataclass
class _EdgeWaiter:
    """One submit parked at the edge, awaiting fleet capacity."""

    future: asyncio.Future        # resolved with the fleet ticket
    conn: "GatewayConnection"
    kernel: object
    xs: list
    cost: int


class OverlayGateway:
    """Asyncio front-end over a pump-driven serving engine.

    ``server`` is an ``OverlayServer`` / ``ShardedOverlayServer`` — the
    gateway wraps it in its own :class:`~repro.sched.pump.AutoPump` — or
    an already-constructed ``AutoPump`` (the gateway then shares it and
    leaves its lifecycle to the owner).

    Knobs:

    * ``max_fleet_tiles`` — edge backpressure bound on the fleet's
      undelivered depth (dispatch tiles).  ``None`` disables edge
      backpressure (admission controls still apply).
    * ``widen_factor`` — how far the bound and the per-connection
      admission windows stretch while the autoscaler reports a scale-up
      pending (>= 1; 1 disables the coupling).
    * ``overflow`` — ``"wait"`` parks over-bound submits at the edge
      (FIFO, bounded by ``max_edge_waiters``, beyond which they shed);
      ``"shed"`` rejects them immediately with
      :class:`GatewayOverloadedError`.
    * ``admission`` / ``default_admission`` — per-connection token-bucket
      specs (``{tenant: (rate, burst)}`` and a lazy default), applied at
      THIS edge per connection, independent of any fleet-level admission.
    """

    def __init__(self, server, *, max_fleet_tiles: int | None = 256,
                 widen_factor: float = 2.0, overflow: str = "wait",
                 max_edge_waiters: int = 4096,
                 max_orphan_sessions: int | None = 1024,
                 admission: dict | None = None,
                 default_admission: tuple | None = None,
                 poll_interval: float = 0.002, clock=time.monotonic,
                 telemetry=None):
        if overflow not in ("wait", "shed"):
            raise ValueError(
                f"overflow must be 'wait' or 'shed', got {overflow!r}")
        if widen_factor < 1.0:
            raise ValueError(
                f"widen_factor must be >= 1, got {widen_factor}")
        if max_fleet_tiles is not None and max_fleet_tiles < 1:
            raise ValueError(
                f"max_fleet_tiles must be >= 1 or None, got "
                f"{max_fleet_tiles}")
        if max_orphan_sessions is not None and max_orphan_sessions < 1:
            raise ValueError(
                f"max_orphan_sessions must be >= 1 or None, got "
                f"{max_orphan_sessions}")
        if isinstance(server, AutoPump):
            self._pump = server
            self._owns_pump = False
        else:
            self._pump = AutoPump(server, poll_interval=poll_interval)
            self._owns_pump = True
        self.max_fleet_tiles = max_fleet_tiles
        self.widen_factor = widen_factor
        self.overflow = overflow
        self.max_edge_waiters = max_edge_waiters
        self.max_orphan_sessions = max_orphan_sessions
        self.clock = clock
        #: per-connection admission spec (each connect() builds its own
        #: AdmissionControl from this, so buckets are per connection)
        self._admission_spec = (admission, default_admission)
        self._loop: asyncio.AbstractEventLoop | None = None
        self._closed = False
        self._connections: set[GatewayConnection] = set()
        #: fleet ticket -> owning connection (live awaits only)
        self._outstanding: dict[int, GatewayConnection] = {}
        #: session id -> {fleet tickets} of disconnected-but-undelivered
        #: (or unclaimed) work, reclaimable exactly once on reconnect.
        #: Ordered least- to most-recently-parked so a session that never
        #: reconnects can be LRU-expired at ``max_orphan_sessions``.
        self._orphan_sessions: collections.OrderedDict[str, set[int]] = \
            collections.OrderedDict()
        #: results the gateway had ALREADY claimed from the engine into a
        #: future when the connection dropped before awaiting them; held
        #: here (engine-side claim-once already spent) until reclaimed
        self._orphan_results: dict[int, object] = {}
        self._edge_waiters: collections.deque[_EdgeWaiter] = \
            collections.deque()
        self._tick_scheduled = False
        #: a gateway-level bulk drain (flush/flush_sync) is claiming
        #: results in an executor thread: ticks must neither claim
        #: concurrently (they would see "already claimed" and poison the
        #: futures _absorb_results is about to resolve) nor submit edge
        #: waiters (pump.submit would block the event loop on the pump
        #: lock the drain holds)
        self._draining = False
        # edge telemetry: every counter lives in the structured sink —
        # by default the pump's (= the wrapped engine's), so the edge,
        # the pump, and the fleet tell one story through one store
        from repro.telemetry import InMemorySink
        self.telemetry = (telemetry if telemetry is not None
                          else getattr(self._pump, "telemetry", None)
                          or InMemorySink(clock=clock))

    # ------------------------------------------------- counters (read-through)
    @property
    def n_attempts(self) -> int:
        """Submits that passed per-connection admission (parked or not)."""
        return int(self.telemetry.counter("edge.attempts"))

    @property
    def n_submitted(self) -> int:
        return int(self.telemetry.counter("edge.submitted"))

    @property
    def n_shed(self) -> int:
        return int(self.telemetry.counter("edge.shed"))

    @property
    def n_edge_queued(self) -> int:
        return int(self.telemetry.counter("edge.queued"))

    @property
    def n_park_cancelled(self) -> int:
        """Parked submits that never reached the fleet (connection or
        gateway closed, or the awaiting task cancelled, while queued)."""
        return int(self.telemetry.counter("edge.park_cancelled"))

    @property
    def n_reclaimed(self) -> int:
        return int(self.telemetry.counter("edge.reclaimed"))

    @property
    def n_connects(self) -> int:
        return int(self.telemetry.counter("edge.connects"))

    @property
    def n_disconnects(self) -> int:
        return int(self.telemetry.counter("edge.disconnects"))

    @property
    def peak_fleet_tiles(self) -> int:
        return int(self.telemetry.counter("edge.peak_fleet_tiles"))

    @property
    def peak_edge_waiters(self) -> int:
        return int(self.telemetry.counter("edge.peak_edge_waiters"))

    @property
    def n_widened_ticks(self) -> int:
        return int(self.telemetry.counter("edge.widened_ticks"))

    @property
    def n_orphans_expired(self) -> int:
        """Sessions LRU-expired from the orphan store (never reclaimed)."""
        return int(self.telemetry.counter("edge.orphans_expired"))

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def local(cls, *, n_replicas: int = 1, bank_capacity: int = 8,
              autoscale: bool = False, max_replicas: int = 4,
              server_kw: dict | None = None, autoscaler_kw: dict | None = None,
              **gateway_kw) -> "OverlayGateway":
        """Build a self-contained local gateway: engine + pump + edge.

        ``n_replicas > 1`` (or ``autoscale=True``) builds a
        ``ShardedOverlayServer``; ``autoscale=True`` attaches a
        ``PressureAutoscaler`` capped at ``max_replicas``, which is what
        the backpressure coupling feeds on.  The 10-line quickstart in
        the README uses this.
        """
        from repro.launch.serve import OverlayServer, ShardedOverlayServer
        from repro.sched import PressureAutoscaler
        server_kw = dict(server_kw or {})
        if n_replicas > 1 or autoscale:
            if autoscale:
                server_kw.setdefault("autoscaler", PressureAutoscaler(
                    max_replicas=max_replicas, **(autoscaler_kw or {})))
            srv = ShardedOverlayServer(n_replicas=n_replicas,
                                       bank_capacity=bank_capacity,
                                       **server_kw)
        else:
            srv = OverlayServer(bank_capacity=bank_capacity, **server_kw)
        return cls(srv, **gateway_kw)

    @property
    def server(self):
        """The wrapped engine (through the pump)."""
        return self._pump.server

    @property
    def pump(self) -> AutoPump:
        return self._pump

    def _require_loop(self) -> asyncio.AbstractEventLoop:
        """Bind to the running event loop on first async use and start
        observing pump ticks.  All gateway state is owned by this loop's
        thread from then on."""
        if self._closed:
            raise GatewayClosedError("gateway is closed")
        loop = asyncio.get_running_loop()
        if self._loop is None:
            self._loop = loop
            self._pump.add_tick_listener(self._on_pump_tick)
        elif loop is not self._loop:
            raise GatewayError(
                "gateway is bound to another event loop; build one "
                "gateway per loop")
        return loop

    def connect(self, tenant: str = "default",
                session: str | None = None) -> "GatewayConnection":
        """Open a connection (``async with gw.connect(...) as conn``).

        ``session`` names the reconnectable identity: a connection that
        drops with results still in flight parks its tickets under this
        id, and the next connection opened with the SAME id can
        ``reclaim()`` them.  ``None`` makes the connection anonymous
        (undelivered work is still never lost fleet-side, but nothing
        can claim it back).
        """
        if self._closed:
            raise GatewayClosedError("gateway is closed")
        admission, default = self._admission_spec
        conn = GatewayConnection(
            self, tenant=tenant, session=session,
            admission=AdmissionControl(admission, default,
                                       clock=self.clock))
        self._connections.add(conn)
        self.telemetry.inc("edge.connects")
        self.telemetry.event("connect", tenant=tenant, session=session)
        return conn

    async def aclose(self) -> None:
        """Close the gateway: close every connection (their undelivered
        tickets park under their sessions), stop observing the pump, and
        — if the gateway built the pump — stop the pump thread too.
        Idempotent; queued fleet-side work survives and can be drained
        from the engine directly."""
        if self._closed:
            return
        self._closed = True
        for conn in list(self._connections):
            await conn.close()
        self._pump.remove_tick_listener(self._on_pump_tick)
        while self._edge_waiters:
            w = self._edge_waiters.popleft()
            if not w.future.done():
                self.telemetry.inc("edge.park_cancelled")
                w.future.set_exception(
                    GatewayClosedError("gateway closed while queued at "
                                       "the edge"))
        if self._owns_pump:
            self._pump.close()

    async def __aenter__(self) -> "OverlayGateway":
        self._require_loop()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    # ------------------------------------------------------- edge pressure
    @property
    def _autoscaler(self):
        return getattr(self.server, "autoscaler", None)

    @property
    def scale_up_pending(self) -> bool:
        """The autoscaler has pressure evidence and room to grow."""
        return bool(getattr(self._autoscaler, "scale_up_pending", False))

    @property
    def saturated(self) -> bool:
        """The autoscaler wants to grow but the fleet is at its ceiling."""
        return bool(getattr(self._autoscaler, "saturated", False))

    @property
    def window(self) -> float:
        """Current edge admission window: ``widen_factor`` while a
        scale-up is pending (and the fleet is not saturated), else 1."""
        if self.scale_up_pending and not self.saturated:
            return self.widen_factor
        return 1.0

    @property
    def fleet_pending_tiles(self) -> int:
        return self.server.pending_tiles

    def _edge_bound(self) -> float:
        if self.max_fleet_tiles is None:
            return float("inf")
        return self.max_fleet_tiles * self.window

    def _has_capacity(self, cost: int) -> bool:
        depth = self.fleet_pending_tiles
        self.telemetry.peak("edge.peak_fleet_tiles", depth)
        return depth + cost <= self._edge_bound()

    def _retry_after(self) -> float:
        """Resubmission hint for a shed: one pump poll interval — the
        soonest the pressure reading can change — snapshotted
        defensively.  A stopped/replaced pump, or an unset/invalid
        interval, must not leak ``None``/``inf``/stale garbage into a
        client-facing hint; those fall back to
        :data:`DEFAULT_RETRY_AFTER`."""
        pump = self._pump
        try:
            if getattr(pump, "closed", False):
                return DEFAULT_RETRY_AFTER
            interval = float(pump.poll_interval)
        except (AttributeError, TypeError, ValueError):
            return DEFAULT_RETRY_AFTER
        if not (0.0 < interval < float("inf")):
            return DEFAULT_RETRY_AFTER
        return interval

    # ---------------------------------------------------------- pump bridge
    def _on_pump_tick(self, worked: bool) -> None:
        """Pump-thread side of the bridge: schedule (at most) one _tick
        on the event loop.  Coalesced — a fast pump cannot flood the
        loop's callback queue."""
        loop = self._loop
        if loop is None or self._closed or self._tick_scheduled:
            return
        self._tick_scheduled = True
        try:
            loop.call_soon_threadsafe(self._tick)
        except RuntimeError:        # loop already closed under us
            self._tick_scheduled = False

    def _tick(self) -> None:
        """Event-loop side: apply the autoscaler-coupled admission
        window, resolve every delivered ticket's future, and drain edge
        waiters into freed fleet capacity."""
        self._tick_scheduled = False
        if self._closed or self._draining:
            return
        window = self.window
        if window != 1.0:
            self.telemetry.inc("edge.widened_ticks")
        for conn in self._connections:
            conn.admission.set_window(window)
        self._resolve_delivered()
        self._drain_edge()

    def _resolve_delivered(self) -> None:
        if not self._outstanding:
            return
        ready = self._pump.try_results(list(self._outstanding))
        for ticket, outs in ready.items():
            conn = self._outstanding.pop(ticket)
            conn._deliver(ticket, outs)

    def _drain_edge(self) -> None:
        while self._edge_waiters:
            w = self._edge_waiters[0]
            if w.future.done():         # cancelled while parked
                self._edge_waiters.popleft()
                self.telemetry.inc("edge.park_cancelled")
                continue
            if w.conn.closed:           # dropped while parked: never
                self._edge_waiters.popleft()    # reached the fleet
                self.telemetry.inc("edge.park_cancelled")
                w.future.set_exception(GatewayClosedError(
                    "connection closed while queued at the edge"))
                continue
            if not self._has_capacity(w.cost):
                return
            self._edge_waiters.popleft()
            try:
                ticket = self._fleet_submit(w.conn, w.kernel, w.xs)
            except Exception as e:      # fleet-side admission, bank, ...
                self.telemetry.inc("edge.submit_errors")
                w.future.set_exception(e)
                continue
            w.future.set_result(ticket)

    # --------------------------------------------------------------- submit
    def _fleet_submit(self, conn: "GatewayConnection", kernel, xs) -> int:
        """Hand one admitted request to the pump; registers the ticket.
        Synchronous (no await) so the capacity check that preceded it is
        atomic within the event loop."""
        ticket = self._pump.submit(kernel, xs, tenant=conn.tenant)
        self._outstanding[ticket] = conn
        conn._register(ticket)
        self.telemetry.inc("edge.submitted")
        self.telemetry.peak("edge.peak_fleet_tiles",
                            self.fleet_pending_tiles)
        return ticket

    async def _submit(self, conn: "GatewayConnection", kernel, xs) -> int:
        self._require_loop()
        xs = list(xs)
        tile = getattr(self.server, "tile", 128)
        cost = max(1, -(-int(np.shape(xs[0])[0]) // tile))
        # per-connection admission first: a rate-limited tenant is
        # rejected before it can occupy edge-queue slots
        conn.admission.admit(conn.tenant, cost)
        self.telemetry.inc("edge.attempts")
        if self._edge_waiters or not self._has_capacity(cost):
            if (self.overflow == "shed"
                    or len(self._edge_waiters) >= self.max_edge_waiters):
                self.telemetry.inc("edge.shed")
                self.telemetry.event("shed", tenant=conn.tenant, cost=cost,
                                     depth=self.fleet_pending_tiles)
                raise GatewayOverloadedError(
                    f"fleet depth {self.fleet_pending_tiles} + {cost} "
                    f"tiles exceeds edge bound {self._edge_bound():.0f} "
                    f"(window {self.window:g})",
                    retry_after=self._retry_after())
            waiter = _EdgeWaiter(
                future=asyncio.get_running_loop().create_future(),
                conn=conn, kernel=kernel, xs=xs, cost=cost)
            self._edge_waiters.append(waiter)
            self.telemetry.inc("edge.queued")
            self.telemetry.peak("edge.peak_edge_waiters",
                                len(self._edge_waiters))
            try:
                return await waiter.future
            except asyncio.CancelledError:
                try:
                    self._edge_waiters.remove(waiter)
                except ValueError:
                    pass        # a tick already popped (and counted) it
                else:
                    self.telemetry.inc("edge.park_cancelled")
                raise
        return self._fleet_submit(conn, kernel, xs)

    # ---------------------------------------------------------------- drain
    async def flush(self) -> dict:
        """Pipelined drain of everything fleet-queued, off-loop; pending
        awaits resolve from the same results.  Returns the full
        ``{ticket: outputs}`` dict like the engine's ``flush``."""
        self._require_loop()
        self._draining = True
        try:
            results = await asyncio.get_running_loop().run_in_executor(
                None, self._pump.flush)
        finally:
            self._draining = False
        self._absorb_results(results)
        return results

    async def flush_sync(self) -> dict:
        """The engine's BARRIER drain through the gateway.

        Delegates to ``AutoPump.flush_sync`` (pump excluded for the whole
        span) in an executor thread, so the one-round-at-a-time oracle
        math is untouched by the asyncio layer — what makes the gateway
        testable bit-for-bit against the single-bank oracle.  Results for
        tickets with live awaits resolve those futures too.
        """
        self._require_loop()
        self._draining = True
        try:
            results = await asyncio.get_running_loop().run_in_executor(
                None, self._pump.flush_sync)
        finally:
            self._draining = False
        self._absorb_results(results)
        return results

    def _absorb_results(self, results: dict) -> None:
        """A bulk drain claimed tickets out from under the per-ticket
        futures; complete any live awaits from the drained dict, and
        carry parked-session tickets (their engine-side claim is now
        spent) so a later ``reclaim`` still finds them."""
        parked: set[int] = set()
        for tickets in self._orphan_sessions.values():
            parked.update(tickets)
        for ticket, outs in results.items():
            conn = self._outstanding.pop(ticket, None)
            if conn is not None:
                conn._deliver(ticket, outs)
            elif ticket in parked:
                self._orphan_results[ticket] = outs

    # ------------------------------------------------------------- sessions
    def _park_session(self, conn: "GatewayConnection",
                      tickets: set[int]) -> None:
        """A connection dropped with these tickets undelivered/unclaimed:
        park them under its session (reclaimable) or leave them to the
        fleet's stores (anonymous connection — results are retained
        engine-side either way, never lost)."""
        for t in tickets:
            self._outstanding.pop(t, None)
        if conn.session is not None and tickets:
            self._park_tickets(conn.session, tickets)

    def _park_tickets(self, session: str, tickets) -> None:
        """Add tickets to a session's orphan bucket, LRU-bump it, and
        expire the coldest sessions past ``max_orphan_sessions``."""
        bucket = self._orphan_sessions.get(session)
        if bucket is None:
            bucket = self._orphan_sessions[session] = set()
        bucket.update(tickets)
        self._orphan_sessions.move_to_end(session)
        self._expire_orphans()

    def park_result(self, session: str | None, ticket: int,
                    value) -> None:
        """Park an ALREADY-CLAIMED result under a session so a later
        ``reclaim`` returns it — the engine-side claim-once is spent, so
        the gateway carries the value itself.  The socket transport uses
        this to re-park results a dying connection never acknowledged.
        No-op for anonymous (``session=None``) connections."""
        if session is None:
            return
        self._orphan_results[ticket] = value
        self._park_tickets(session, (ticket,))

    def _expire_orphans(self) -> None:
        """LRU-expire orphan sessions past the cap: a session that never
        reconnects must not grow ``_orphan_sessions``/``_orphan_results``
        without bound.  Expired tickets drop their held results too."""
        cap = self.max_orphan_sessions
        if cap is None:
            return
        while len(self._orphan_sessions) > cap:
            session, tickets = self._orphan_sessions.popitem(last=False)
            held = 0
            for t in tickets:
                if self._orphan_results.pop(t, None) is not None:
                    held += 1
            self.telemetry.inc("edge.orphans_expired")
            self.telemetry.inc("edge.orphan_tickets_expired", len(tickets))
            self.telemetry.event("orphans_expired", session=session,
                                 tickets=len(tickets), held_results=held)

    def orphaned_tickets(self, session: str) -> frozenset[int]:
        """Tickets parked under ``session`` (peek; reclaim claims them)."""
        return frozenset(self._orphan_sessions.get(session, ()))

    # --------------------------------------------------------------- metrics
    def stats(self) -> dict:
        """Edge telemetry + the wrapped engine's stats (one dict)."""
        s = {"edge_attempts": self.n_attempts,
             "edge_submitted": self.n_submitted,
             "edge_shed": self.n_shed,
             "edge_queued": self.n_edge_queued,
             "edge_park_cancelled": self.n_park_cancelled,
             "edge_waiters": len(self._edge_waiters),
             "peak_edge_waiters": self.peak_edge_waiters,
             "peak_fleet_tiles": self.peak_fleet_tiles,
             "max_fleet_tiles": self.max_fleet_tiles,
             "window": self.window,
             "widened_ticks": self.n_widened_ticks,
             "connections": len(self._connections),
             "connects": self.n_connects,
             "disconnects": self.n_disconnects,
             "orphan_sessions": len(self._orphan_sessions),
             "orphaned_tickets": sum(
                 len(v) for v in self._orphan_sessions.values()),
             "orphaned_results_held": len(self._orphan_results),
             "orphans_expired": self.n_orphans_expired,
             "max_orphan_sessions": self.max_orphan_sessions,
             "reclaimed": self.n_reclaimed,
             "outstanding": len(self._outstanding)}
        s["fleet"] = self._pump.stats()
        return s


class GatewayConnection:
    """One client conversation with the gateway.

    Obtained from :meth:`OverlayGateway.connect`; use as an async context
    manager for graceful close.  All methods must run on the gateway's
    event loop.  A connection is cheap (a dict and an admission control)
    — the load generator opens thousands.
    """

    def __init__(self, gateway: OverlayGateway, tenant: str,
                 session: str | None, admission: AdmissionControl):
        self.gateway = gateway
        self.tenant = tenant
        self.session = session
        self.admission = admission
        self.closed = False
        #: live awaits: fleet ticket -> asyncio.Future
        self._futures: dict[int, asyncio.Future] = {}

    # ------------------------------------------------------------- plumbing
    def _check_open(self) -> None:
        if self.closed:
            raise GatewayClosedError(
                f"connection (tenant={self.tenant!r}, "
                f"session={self.session!r}) is closed")

    def _register(self, ticket: int) -> None:
        self._futures[ticket] = \
            asyncio.get_running_loop().create_future()

    def _deliver(self, ticket: int, outs) -> None:
        fut = self._futures.get(ticket)
        if fut is None or fut.done():
            return
        if isinstance(outs, KeyError):
            fut.set_exception(outs)
        else:
            fut.set_result(outs)

    # ---------------------------------------------------------------- client
    async def submit(self, kernel, xs) -> int:
        """Admit + enqueue one request; returns the fleet's global ticket.

        Raises :class:`~repro.sched.admission.AdmissionError` when this
        connection's token bucket cannot cover it,
        :class:`GatewayOverloadedError` when the edge sheds it, and
        suspends (``overflow="wait"``) while the fleet is over its depth
        bound.
        """
        self._check_open()
        return await self.gateway._submit(self, kernel, xs)

    async def result(self, ticket: int):
        """Await one ticket's outputs (claim-once, like the engine)."""
        self._check_open()
        fut = self._futures.get(ticket)
        if fut is None:
            raise KeyError(f"ticket {ticket} is not outstanding on this "
                           f"connection")
        try:
            outs = await fut
        finally:
            # claimed or cancelled: either way this await is spent
            if fut.done() and not fut.cancelled():
                self._futures.pop(ticket, None)
        return outs

    async def results(self):
        """``async for ticket, outs`` in COMPLETION order, streaming.

        Yields every outstanding ticket as the pump delivers it; submits
        made while iterating are picked up; ends when the connection has
        nothing outstanding.
        """
        while self._futures:
            self._check_open()
            done = [t for t, f in self._futures.items() if f.done()]
            if not done:
                await asyncio.wait(list(self._futures.values()),
                                   return_when=asyncio.FIRST_COMPLETED)
                continue
            for t in done:
                fut = self._futures.pop(t)
                yield t, fut.result()

    async def drain(self) -> dict:
        """Await everything outstanding on THIS connection; returns
        ``{ticket: outputs}`` (other connections' work is untouched —
        compare ``gateway.flush``)."""
        out = {}
        async for t, outs in self.results():
            out[t] = outs
        return out

    async def reclaim(self) -> dict:
        """Claim results parked under this connection's session by a
        previous (dropped) connection — exactly once: the first reclaim
        takes the whole set, a second returns ``{}``.  Undelivered
        tickets are awaited; tickets whose replica was drained meanwhile
        are served from the fleet orphan store like any others."""
        self._check_open()
        if self.session is None:
            return {}
        gw = self.gateway
        gw._require_loop()
        tickets = gw._orphan_sessions.pop(self.session, set())
        out = {}
        waiting = []
        for t in sorted(tickets):
            if t in gw._orphan_results:
                # the dropped connection had already claimed this from
                # the engine; the gateway carried it
                out[t] = gw._orphan_results.pop(t)
            else:
                self._register(t)
                gw._outstanding[t] = self
                waiting.append(t)
        if waiting:
            # the pump may already have delivered some (or all) of them
            # while no one was listening; claim those without waiting
            # for the next tick
            gw._resolve_delivered()
        for t in waiting:
            out[t] = await self.result(t)
        gw.telemetry.inc("edge.reclaimed", len(out))
        gw.telemetry.event("reclaim", session=self.session,
                           tickets=len(out))
        return out

    @property
    def outstanding(self) -> frozenset[int]:
        """Tickets submitted on this connection and not yet claimed."""
        return frozenset(self._futures)

    async def close(self) -> None:
        """Graceful disconnect (idempotent): cancel pending awaits; park
        undelivered tickets under the session for reclaim.  Fleet-side
        work keeps flowing — a launched round is never cancelled, its
        results land in the engine's stores."""
        if self.closed:
            return
        self.closed = True
        gw = self.gateway
        gw._connections.discard(self)
        gw.telemetry.inc("edge.disconnects")
        undelivered = set(self._futures)
        for t, fut in self._futures.items():
            if not fut.done():
                fut.cancel()
            elif not fut.cancelled() and fut.exception() is None \
                    and self.session is not None:
                # delivered AND claimed from the engine, but never
                # awaited: the engine's claim-once is spent, so the
                # gateway must carry the value itself until reclaim
                gw._orphan_results[t] = fut.result()
        self._futures.clear()
        for w in list(gw._edge_waiters):
            # parked submits never reached the fleet: cancel, don't park
            if w.conn is self and not w.future.done():
                w.future.cancel()
        gw._park_session(self, undelivered)

    async def __aenter__(self) -> "GatewayConnection":
        self.gateway._require_loop()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()
