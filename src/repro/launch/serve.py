"""Serving launchers: LM prefill/decode loop + the async overlay engine.

LM serving (prefill + greedy decode)::

  python -m repro.launch.serve --arch gemma3-4b --smoke --batch 4 \
      --prompt-len 32 --gen 16

Multi-tenant overlay serving (the paper's one-pipeline-many-kernels claim
at request scale)::

  python -m repro.launch.serve --overlay-demo --bank 4 --requests 64
  python -m repro.launch.serve --overlay-demo --stream --tenants 4

``OverlayServer`` is an ASYNC STREAMING engine over the staged dispatch
pipeline (``Overlay.plan/assemble/execute/collect``, see core/overlay.py):

* ``submit`` returns a ticket immediately; results are retrieved with
  ``result(ticket)``, the ``as_completed()`` iterator (completion order,
  not barrier order), or a bulk ``flush()``.
* Rounds are PIPELINED: while round N executes on device, round N+1's
  host tile stack is assembled and its contexts prefetched into the bank
  (JAX dispatch is async — ``jax.block_until_ready`` happens only at
  result delivery).  ``flush_sync()`` keeps the old drain-the-queue
  barrier loop as the bit-for-bit oracle and benchmark baseline.
* Scheduling policy: per-tenant token-bucket ADMISSION CONTROL (``submit``
  raises ``AdmissionError`` when a tenant exceeds its rate) and
  deficit-round-robin across tenants when forming rounds, so a hot tenant
  with a bank-resident working set cannot starve cold tenants.
* In-flight rounds pin their contexts in the ``ContextBank`` so LRU
  eviction can never reassign a slot under a launched round.

``ShardedOverlayServer`` scales the engine across devices: N replicas
(each an ``OverlayServer`` pinned to one device of
``launch.mesh.make_serving_mesh`` with its own bank) behind a
residency-aware router — a shared ``core.bank.BankDirectory`` routes each
request to the replica already holding its context (entries validated by
residency generation), falls back least-loaded on miss/stale, migrates
hot contexts, and applies admission globally.  Results stay bit-for-bit
identical to the single-bank engine (tests/test_sharded_serving.py).

See docs/SERVING.md for the full guide.
"""

from __future__ import annotations

import argparse
import dataclasses
import math
import sys
import time
from collections import OrderedDict, deque

import jax
import jax.numpy as jnp
import numpy as np

#: tenant label used when ``submit`` is not given one
DEFAULT_TENANT = "default"


class AdmissionError(RuntimeError):
    """A tenant exceeded its token-bucket rate.

    ``retry_after`` is the seconds until the request would be admitted —
    ``math.inf`` when the request's cost exceeds the bucket's burst, i.e.
    it can NEVER be admitted under the current policy (don't retry it;
    split the request or raise the tenant's burst).
    """

    def __init__(self, tenant: str, retry_after: float):
        if math.isinf(retry_after):
            msg = (f"tenant {tenant!r}: request cost exceeds the bucket "
                   f"burst; it can never be admitted under this policy")
        else:
            msg = (f"tenant {tenant!r} over admission rate; "
                   f"retry in {retry_after:.3f}s")
        super().__init__(msg)
        self.tenant = tenant
        self.retry_after = retry_after


class TokenBucket:
    """Token-bucket rate limiter (tokens = dispatch tiles, see SERVING.md).

    ``rate`` tokens accrue per second up to ``burst``; ``try_acquire``
    spends tokens if available.  The clock is injectable so tests can
    advance time deterministically.
    """

    def __init__(self, rate: float, burst: float | None = None,
                 clock=time.monotonic):
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.burst = float(burst if burst is not None else rate)
        self.tokens = self.burst
        self.clock = clock
        self._t = clock()

    def _refill(self) -> None:
        now = self.clock()
        self.tokens = min(self.burst, self.tokens + (now - self._t) * self.rate)
        self._t = now

    def try_acquire(self, cost: float = 1.0) -> bool:
        self._refill()
        if self.tokens >= cost:
            self.tokens -= cost
            return True
        return False

    def retry_after(self, cost: float = 1.0) -> float:
        """Seconds until ``cost`` tokens will be available."""
        self._refill()
        return max(0.0, (cost - self.tokens) / self.rate)


class AdmissionControl:
    """Per-tenant token-bucket admission for one serving front-end.

    ``admission`` maps tenant -> TokenBucket (or a ``(rate, burst)`` spec);
    ``default_admission`` is applied lazily to tenants without an explicit
    bucket.  Shared by ``OverlayServer`` (single bank) and
    ``ShardedOverlayServer`` (where admission must span all replicas — a
    tenant cannot dodge its rate by having its kernels land on different
    replicas, so the buckets live in the router, not per replica).
    """

    #: bucket-count high-water mark before lazily-created default buckets
    #: are pruned — an unbounded tenant-label space must not leak buckets
    MAX_BUCKETS = 4096

    def __init__(self, admission: dict | None = None,
                 default_admission: tuple | None = None,
                 clock=time.monotonic):
        self.clock = clock
        self._buckets: dict[str, TokenBucket] = {}
        for tenant, spec in (admission or {}).items():
            self._buckets[tenant] = (spec if isinstance(spec, TokenBucket)
                                     else TokenBucket(*spec, clock=clock))
        self.default_admission = default_admission
        self._default_buckets: set[str] = set()

    def admit(self, tenant: str, cost: float) -> None:
        """Spend ``cost`` tokens from the tenant's bucket or raise
        :class:`AdmissionError`; tenants with no bucket (and no default
        policy) are always admitted."""
        bucket = self._buckets.get(tenant)
        if bucket is None and self.default_admission is not None:
            bucket = TokenBucket(*self.default_admission, clock=self.clock)
            self._buckets[tenant] = bucket
            self._default_buckets.add(tenant)
            if len(self._buckets) > self.MAX_BUCKETS:
                # a refilled-to-burst default bucket carries no state
                for t in list(self._default_buckets):
                    b = self._buckets[t]
                    b._refill()
                    if t != tenant and b.tokens >= b.burst:
                        del self._buckets[t]
                        self._default_buckets.discard(t)
        if bucket is not None and not bucket.try_acquire(cost):
            retry = (math.inf if cost > bucket.burst
                     else bucket.retry_after(cost))
            raise AdmissionError(tenant, retry)


# ===================================================== overlay request engine
@dataclasses.dataclass
class OverlayRequest:
    """One queued kernel invocation: a batch of iterations of one kernel."""

    ticket: int
    kernel: object            # core.overlay.CompiledKernel
    xs: list                  # per-primary-input 1-D arrays, equal length
    tenant: str = DEFAULT_TENANT
    key: tuple = ()           # context identity (bank.context_key)
    cost: int = 1             # dispatch tiles this request occupies
    t_submit: float = 0.0

    @property
    def name(self) -> str:
        return self.kernel.program.name

    @property
    def batch(self) -> int:
        return int(np.shape(self.xs[0])[0])


@dataclasses.dataclass
class _Flow:
    """Per-tenant FIFO queue + deficit-round-robin state."""

    queue: deque
    deficit: float = 0.0


@dataclasses.dataclass
class _Inflight:
    """A launched-but-undelivered round of the staged pipeline."""

    reqs: list                # [OverlayRequest]
    plan: object              # core.overlay.DispatchPlan (holds the pins)
    ys: object                # device result future, or None (empty round)
    round_no: int


class OverlayServer:
    """Async streaming front-end over the staged dispatch pipeline.

    Lifecycle of a request (see docs/ARCHITECTURE.md for the diagram):

    1. ``submit(kernel, xs, tenant=...)`` — token-bucket admission check,
       then enqueue on the tenant's flow; returns a ticket.
    2. Round formation — deficit-round-robin across tenant flows picks at
       most ``round_kernels`` distinct kernels per round; a tenant may
       spend at most its accumulated deficit (in tiles) per round, so no
       flow monopolises the bank.
    3. Staged launch — ``Overlay.plan`` (pins contexts, assigns slots) →
       ``assemble`` (host tile stack) → ``execute`` (async device call).
       Up to ``max_inflight`` rounds run concurrently: round N+1 is
       planned/assembled while round N executes on device.
    4. Delivery — ``result(ticket)`` / ``as_completed()`` / ``flush()``
       block (``jax.block_until_ready``) only on the round actually being
       delivered; per-ticket latency is recorded at that moment.

    ``flush_sync()`` serves the same queue through the one-round-at-a-time
    barrier loop (launch, wait, deliver, repeat) — the bit-for-bit oracle
    the tests hold the streaming path to, and the baseline the benchmark
    must beat.
    """

    def __init__(self, bank_capacity: int = 8, tile: int = 128,
                 backend: str = "jnp", s_max: int = 16,
                 dtype=jnp.float32, max_outputs: int = 8,
                 max_inflight: int = 2, round_kernels: int | None = None,
                 quantum_tiles: float | None = None,
                 admission: dict | None = None,
                 default_admission: tuple | None = None,
                 clock=time.monotonic, metrics_window: int = 65536,
                 device=None):
        from repro.core.bank import ContextBank
        from repro.core.overlay import Overlay
        #: device this server's bank + rounds are pinned to (None = default
        #: placement); set by ShardedOverlayServer, one device per replica
        self.device = device
        self.overlay = Overlay(s_max=s_max, dtype=dtype, backend=backend,
                               device=device)
        self.bank = ContextBank(bank_capacity, s_max=s_max, dtype=dtype,
                                max_outputs=max_outputs, device=device)
        self.tile = tile
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.max_inflight = max_inflight
        #: distinct kernels per round; <= bank capacity.  Smaller rounds
        #: trade per-launch batching for pipeline overlap (see SERVING.md).
        if round_kernels is not None and round_kernels < 1:
            raise ValueError(
                f"round_kernels must be >= 1 or None (= bank capacity), "
                f"got {round_kernels}")
        self.round_kernels = min(round_kernels or bank_capacity,
                                 bank_capacity)
        #: DRR quantum in tiles; None = unbounded (pure round-robin)
        if quantum_tiles is not None and quantum_tiles <= 0:
            raise ValueError(
                f"quantum_tiles must be > 0 or None (unbounded), got "
                f"{quantum_tiles}; a non-positive quantum can never cover "
                f"a request's tile cost")
        self.quantum_tiles = quantum_tiles
        self.clock = clock
        self.admission = AdmissionControl(admission, default_admission,
                                          clock=clock)
        self._flows: dict[str, _Flow] = {}
        self._rr: deque[str] = deque()      # tenant round-robin order
        self._inflight: deque[_Inflight] = deque()
        self._done: OrderedDict[int, list] = OrderedDict()
        self._records: dict[int, dict] = {}
        #: telemetry of CLAIMED tickets is kept for the last
        #: ``metrics_window`` claims only — a long-lived server must not
        #: grow per-request state forever
        self.metrics_window = metrics_window
        self._claimed: deque[int] = deque()
        self._next_ticket = 0
        self._pending_tiles = 0
        self.n_rounds = 0
        self.n_requests = 0

    # ----------------------------------------------------------------- queue
    def submit(self, kernel, xs, tenant: str = DEFAULT_TENANT) -> int:
        """Admit + enqueue one request; returns its ticket immediately.

        Raises :class:`AdmissionError` (without enqueueing) when the
        tenant's token bucket cannot cover the request's tile cost.
        """
        from repro.core.bank import context_key
        xs = list(xs)
        cost = -(-int(np.shape(xs[0])[0]) // self.tile)
        self.admission.admit(tenant, max(1, cost))
        t = self._next_ticket
        self._next_ticket += 1
        req = OverlayRequest(ticket=t, kernel=kernel, xs=xs, tenant=tenant,
                             key=context_key(kernel.program), cost=cost,
                             t_submit=self.clock())
        flow = self._flows.get(tenant)
        if flow is None:
            flow = self._flows[tenant] = _Flow(queue=deque())
            self._rr.append(tenant)
        flow.queue.append(req)
        self._pending_tiles += req.cost
        self._records[t] = {"tenant": tenant, "t_submit": req.t_submit,
                            "cost": cost, "t_done": None, "round": None}
        return t

    @property
    def pending(self) -> int:
        """Requests submitted but not yet delivered (queued + in flight)."""
        queued = sum(len(f.queue) for f in self._flows.values())
        return queued + sum(len(i.reqs) for i in self._inflight)

    @property
    def pending_tiles(self) -> int:
        """Undelivered work in dispatch tiles — the sharded router's load
        signal for least-loaded fallback and migration decisions.  A
        running counter (submit adds, delivery subtracts): the router
        reads this for every replica on every submit, so it must not
        scan the queues."""
        return self._pending_tiles

    # ------------------------------------------------------- round formation
    def _take_from_flow(self, flow: _Flow, keys: set, cap: int) -> list:
        """DRR service of one flow: whole kernel groups, head-first, until
        the flow's deficit or the round's distinct-kernel budget runs out.

        Untaken requests keep their ARRIVAL order in the queue (never the
        grouped order) — a skipped kernel's old request must reach the
        queue head ahead of newer traffic, or a live stream on one kernel
        would starve a tenant's own requests on another.
        """
        taken: list[OverlayRequest] = []
        taken_ids: set[int] = set()
        by_key: OrderedDict[tuple, list] = OrderedDict()
        for r in flow.queue:
            by_key.setdefault(r.key, []).append(r)
        exhausted = False
        for key, rs in by_key.items():
            if exhausted or (key not in keys and len(keys) >= cap):
                continue
            for r in rs:
                if flow.deficit >= r.cost:
                    flow.deficit -= r.cost
                    keys.add(key)
                    taken.append(r)
                    taken_ids.add(r.ticket)
                else:
                    exhausted = True
                    break
        flow.queue = deque(r for r in flow.queue
                           if r.ticket not in taken_ids)
        if not flow.queue:
            flow.deficit = 0.0          # standard DRR: idle flows reset
        return taken

    def _form_round(self) -> list | None:
        """Pick the next round via deficit round-robin across tenants."""
        # prune drained flows: a long-lived server over an unbounded
        # tenant-label space must not scan every tenant ever seen per
        # round (flows are recreated on the tenant's next submit)
        for tenant in [t for t in self._rr if not self._flows[t].queue]:
            del self._flows[tenant]
            self._rr.remove(tenant)
        if not self._flows:
            return None
        cap = self.round_kernels
        keys: set = set()
        round_reqs: list[OverlayRequest] = []
        while not round_reqs:
            for tenant in list(self._rr):
                flow = self._flows[tenant]
                if not flow.queue:
                    continue
                flow.deficit = (math.inf if self.quantum_tiles is None
                                else flow.deficit + self.quantum_tiles)
                round_reqs.extend(self._take_from_flow(flow, keys, cap))
        self._rr.rotate(-1)             # a different tenant leads next round
        return round_reqs

    # ------------------------------------------------------ staged pipeline
    def _launch_round(self, reqs: list) -> None:
        """plan (pinned) -> assemble -> execute; delivery happens later."""
        from repro.core.bank import BankError
        round_kernels = {r.key: r.kernel for r in reqs}
        needed = sum(1 for k in round_kernels.values() if k not in self.bank)
        # retire in-flight rounds until the round's NEW contexts fit the
        # unpinned portion of the bank; the round's own resident kernels
        # are excluded — they will be pinned, not evicted, so their slots
        # cannot satisfy a new context's demand
        while self._inflight and self.bank.evictable_capacity(
                excluding=round_kernels) < needed:
            self._retire_oldest()
        pairs = [(r.kernel, r.xs) for r in reqs]
        while True:
            try:
                plan = self.overlay.plan(self.bank, pairs, tile=self.tile,
                                         pin=True)
                break
            except BankError:
                # belt-and-braces: plan unwinds its own pins on failure, so
                # retiring one more round and retrying is always safe
                if not self._inflight:
                    raise
                self._retire_oldest()
        batch = self.overlay.assemble(plan)
        ys = self.overlay.execute(self.bank, batch)
        self._inflight.append(_Inflight(reqs=reqs, plan=plan, ys=ys,
                                        round_no=self.n_rounds))
        self.n_rounds += 1

    def _retire_oldest(self) -> list:
        """Deliver the oldest in-flight round; returns its tickets."""
        inf = self._inflight.popleft()
        if inf.ys is not None:
            jax.block_until_ready(inf.ys)
        # host=True: one device readback + one flatten per group output;
        # per-request slicing is numpy views, never device-op dispatch
        outs = self.overlay.collect(inf.plan, inf.ys, host=True)
        now = self.clock()
        tickets = []
        for r, y in zip(inf.reqs, outs):
            self._done[r.ticket] = y
            rec = self._records[r.ticket]
            rec["t_done"] = now
            rec["round"] = inf.round_no
            tickets.append(r.ticket)
        inf.plan.release(self.bank)
        self._pending_tiles -= sum(r.cost for r in inf.reqs)
        self.n_requests += len(inf.reqs)
        return tickets

    def _fill_pipeline(self) -> None:
        while len(self._inflight) < self.max_inflight:
            reqs = self._form_round()
            if reqs is None:
                return
            self._launch_round(reqs)

    def _note_claimed(self, tickets) -> None:
        """Record claims and prune telemetry beyond ``metrics_window``."""
        self._claimed.extend(tickets)
        while len(self._claimed) > self.metrics_window:
            self._records.pop(self._claimed.popleft(), None)

    # -------------------------------------------------------------- retrieve
    def result(self, ticket: int):
        """Block until ``ticket``'s outputs are ready and return them.

        Drives the pipeline as needed; each claim pops the result (a
        ticket can be claimed once, via ``result``/``as_completed``/
        ``flush``).
        """
        if ticket not in self._records:
            raise KeyError(f"unknown ticket {ticket}")
        while ticket not in self._done:
            if self._records[ticket]["t_done"] is not None:
                raise KeyError(f"ticket {ticket} already claimed")
            self._fill_pipeline()
            if not self._inflight:
                raise KeyError(f"ticket {ticket} is not queued (lost?)")
            self._retire_oldest()
        self._note_claimed([ticket])
        return self._done.pop(ticket)

    def as_completed(self):
        """Yield ``(ticket, outputs)`` in COMPLETION order, streaming.

        Rounds are delivered as they finish (arrival order, not the
        submission-barrier order of ``flush``); within a round, tickets
        come back grouped by kernel (round assembly batches per kernel),
        in submission order within each kernel.  New ``submit`` calls
        made while iterating are picked up — iteration ends when the
        server is idle.
        """
        while True:
            if self._done:
                ticket, outs = self._done.popitem(last=False)
                self._note_claimed([ticket])
                yield ticket, outs
                continue
            self._fill_pipeline()
            if not self._inflight:
                return
            self._retire_oldest()

    def flush(self) -> dict[int, list]:
        """Serve everything queued; returns {ticket: outputs}.

        Pipelined drain: up to ``max_inflight`` rounds overlap, so round
        N+1's host assembly and context prefetch hide under round N's
        device execution; the device is never left idle waiting for the
        host between rounds (compare ``flush_sync``).
        """
        while True:
            self._fill_pipeline()
            if not self._inflight:
                break
            self._retire_oldest()
        results = dict(self._done)
        self._done.clear()
        self._note_claimed(results)
        return results

    def flush_sync(self) -> dict[int, list]:
        """Barrier drain: one round at a time, waiting on each.

        Identical round formation and dispatch math to ``flush`` — only
        the overlap is missing, which makes this the bit-for-bit oracle
        for the streaming path and the baseline it must beat.
        """
        # rounds already launched by the pipelined API belong to this
        # drain too: deliver them first (releasing their pins) so no
        # ticket is dropped and no pin outlives its round
        while self._inflight:
            self._retire_oldest()
        results: dict[int, list] = {}
        while (reqs := self._form_round()) is not None:
            outs = self.overlay.dispatch(
                self.bank, [(r.kernel, r.xs) for r in reqs], tile=self.tile)
            jax.block_until_ready([y for ys in outs for y in ys])
            now = self.clock()
            for r, y in zip(reqs, outs):
                results[r.ticket] = y
                self._records[r.ticket].update(t_done=now,
                                               round=self.n_rounds)
            self.n_rounds += 1
            self._pending_tiles -= sum(r.cost for r in reqs)
            self.n_requests += len(reqs)
        results.update(self._done)
        self._done.clear()
        self._note_claimed(results)
        return results

    # --------------------------------------------------------------- metrics
    def latencies(self) -> dict[int, float]:
        """Per-delivered-ticket submit->delivery seconds."""
        return {t: rec["t_done"] - rec["t_submit"]
                for t, rec in self._records.items()
                if rec["t_done"] is not None}

    def latency_percentiles(self, qs=(50, 95, 99)) -> dict[str, float]:
        lats = list(self.latencies().values())
        if not lats:
            return {f"p{q}": float("nan") for q in qs}
        return {f"p{q}": float(np.percentile(lats, q)) for q in qs}

    def record(self, ticket: int) -> dict:
        """Telemetry for one ticket (tenant, cost, submit/done, round)."""
        return dict(self._records[ticket])

    def reset_metrics(self) -> None:
        """Drop delivered-ticket telemetry (e.g. after a warmup drain) so
        percentiles reflect steady state, not executor compilation.

        Records of pending tickets and of delivered-but-unclaimed results
        (still claimable via ``result``/``flush``) are kept.
        """
        self._records = {t: r for t, r in self._records.items()
                         if r["t_done"] is None or t in self._done}
        self._claimed.clear()

    def stats(self) -> dict:
        s = dict(self.bank.stats())
        s.update({"rounds": self.n_rounds, "requests": self.n_requests,
                  "pending": self.pending, "inflight": len(self._inflight),
                  "tenants": len(self._flows)})
        return s


# ==================================================== sharded serving layer
class ShardedOverlayServer:
    """Residency-routed serving over N per-replica context banks.

    The paper keeps ONE time-multiplexed FU pipeline hot by making a
    kernel switch an index; the single-bank ``OverlayServer`` scales that
    to one device.  This layer scales it ACROSS devices the way many-core
    overlays replicate the overlay fabric — except replicas are not
    mirrors: each hosts its own ``ContextBank`` working set (affinity, not
    replication), so aggregate residency grows with the fleet while each
    replica's instruction store stays small.

    * ROUTING — every request is keyed by context content and looked up in
      a shared :class:`~repro.core.bank.BankDirectory`.  A fresh entry
      (validated against the owning bank's residency generation) routes
      the request to the replica already holding its context — a residency
      HIT.  A miss (or a stale entry — the context was evicted since it
      was published) falls back to the least-loaded replica (by pending
      tiles), prefetches the context there, and publishes the new
      residency.
    * MIGRATION — when the owning replica is hot (its pending tiles exceed
      ``migrate_factor`` x the coolest replica's, by at least
      ``migrate_min_tiles``), the context is re-homed: prefetched on the
      cool replica, republished, and new traffic follows it.  The old copy
      ages out of the hot bank via LRU; in-flight rounds there are
      untouched (pins).  A per-key cooldown (``migrate_cooldown`` submits)
      stops a single globally-hot key from thrashing between replicas.
    * ADMISSION — token buckets live HERE, spanning replicas, so a
      tenant's rate cannot be dodged by its kernels landing on different
      replicas.  Per-replica DRR fairness is unchanged underneath.
    * DELIVERY — tickets are global; ``flush``/``as_completed``/``result``
      merge the per-replica pipelines.  The drain interleaves round
      launches across replicas before blocking on any of them, so
      per-device rounds execute concurrently (JAX async dispatch).
      ``flush_sync`` drains replica-by-replica with the barrier loop — the
      oracle path.

    Every replica is a full ``OverlayServer`` pinned to one device of
    ``launch.mesh.make_serving_mesh`` (devices wrap when the fleet is
    larger than the machine — correctness never depends on real device
    count, which is how the differential tests run 2/4/8 replicas in CI).
    """

    def __init__(self, n_replicas: int = 2, bank_capacity: int = 8,
                 tile: int = 128, backend: str = "jnp", s_max: int = 16,
                 dtype=jnp.float32, max_outputs: int = 8,
                 max_inflight: int = 2, round_kernels: int | None = None,
                 quantum_tiles: float | None = None,
                 admission: dict | None = None,
                 default_admission: tuple | None = None,
                 clock=time.monotonic, metrics_window: int = 65536,
                 devices=None, migrate_factor: float = 4.0,
                 migrate_min_tiles: int = 16, migrate_cooldown: int = 32):
        from repro.core.bank import BankDirectory
        from repro.launch.mesh import make_serving_mesh
        self.devices = make_serving_mesh(n_replicas, devices)
        self.n_replicas = len(self.devices)
        self.tile = tile
        # replicas do NOT get admission policies: admission is global
        self.replicas = [
            OverlayServer(bank_capacity=bank_capacity, tile=tile,
                          backend=backend, s_max=s_max, dtype=dtype,
                          max_outputs=max_outputs, max_inflight=max_inflight,
                          round_kernels=round_kernels,
                          quantum_tiles=quantum_tiles, clock=clock,
                          metrics_window=metrics_window, device=d)
            for d in self.devices]
        self.directory = BankDirectory()
        self.admission = AdmissionControl(admission, default_admission,
                                          clock=clock)
        self.clock = clock
        if migrate_factor < 1:
            raise ValueError(
                f"migrate_factor must be >= 1, got {migrate_factor}")
        self.migrate_factor = migrate_factor
        self.migrate_min_tiles = migrate_min_tiles
        self.migrate_cooldown = migrate_cooldown
        self.metrics_window = metrics_window
        self._owner: dict[int, tuple[int, int]] = {}   # global -> (rep, loc)
        self._global: list[dict[int, int]] = [
            {} for _ in range(self.n_replicas)]        # rep: loc -> global
        self._claimed: deque[int] = deque()
        self._migrated_at: dict[tuple, int] = {}
        self._next_ticket = 0
        self._rr = 0                                   # retire fan-in ptr
        self.n_submits = 0
        self.n_route_hits = 0
        self.n_route_misses = 0
        self.n_migrations = 0

    @property
    def banks(self):
        """Per-replica ContextBanks, replica order."""
        return [rep.bank for rep in self.replicas]

    # ----------------------------------------------------------------- route
    def _route(self, kernel) -> int:
        """Pick the serving replica for one request (see class docstring)."""
        from repro.core.bank import BankError, context_key
        loads = [rep.pending_tiles for rep in self.replicas]
        coolest = min(range(self.n_replicas), key=loads.__getitem__)
        owner = self.directory.locate(kernel, self.banks)
        if owner is not None:
            hot = (owner != coolest
                   and loads[owner] - loads[coolest] >= self.migrate_min_tiles
                   and loads[owner] >= self.migrate_factor
                   * max(loads[coolest], 1))
            key = context_key(kernel.program)
            last = self._migrated_at.get(key)
            cooled = (last is None
                      or self.n_submits - last >= self.migrate_cooldown)
            if not (hot and cooled):
                self.n_route_hits += 1
                return owner
            target = coolest
            self._migrated_at[key] = self.n_submits
            self.n_migrations += 1
        else:
            self.n_route_misses += 1
            target = coolest
        # warm the context on its new home and publish the residency; a
        # momentarily all-pinned bank defers the load to the replica's own
        # round plan (which retires rounds until it fits)
        try:
            self.replicas[target].bank.prefetch([kernel])
            self.directory.publish_current(kernel, target,
                                           self.replicas[target].bank)
        except BankError:
            self.directory.drop(kernel)
        return target

    # ----------------------------------------------------------------- queue
    def submit(self, kernel, xs, tenant: str = DEFAULT_TENANT) -> int:
        """Admit globally, route by residency, enqueue on one replica;
        returns a global ticket."""
        xs = list(xs)
        cost = max(1, -(-int(np.shape(xs[0])[0]) // self.tile))
        self.admission.admit(tenant, cost)
        rep = self._route(kernel)
        loc = self.replicas[rep].submit(kernel, xs, tenant=tenant)
        t = self._next_ticket
        self._next_ticket += 1
        self._owner[t] = (rep, loc)
        self._global[rep][loc] = t
        self.n_submits += 1
        return t

    @property
    def pending(self) -> int:
        return sum(rep.pending for rep in self.replicas)

    @property
    def residency_hit_rate(self) -> float:
        """Routed-to-resident-replica fraction (stale hits count as
        misses); NaN before any routing decision."""
        n = self.n_route_hits + self.n_route_misses
        return self.n_route_hits / n if n else float("nan")

    # -------------------------------------------------------------- retrieve
    def _to_global(self, rep: int, local_results: dict) -> dict:
        return {self._global[rep][loc]: ys
                for loc, ys in local_results.items()}

    def _note_claimed(self, tickets) -> None:
        self._claimed.extend(tickets)
        while len(self._claimed) > self.metrics_window:
            t = self._claimed.popleft()
            rep_loc = self._owner.pop(t, None)
            if rep_loc is not None:
                self._global[rep_loc[0]].pop(rep_loc[1], None)

    def result(self, ticket: int):
        """Block until the ticket's outputs are ready (drives only the
        owning replica's pipeline); one claim per ticket."""
        if ticket not in self._owner:
            raise KeyError(f"unknown ticket {ticket}")
        rep, loc = self._owner[ticket]
        out = self.replicas[rep].result(loc)
        self._note_claimed([ticket])
        return out

    def as_completed(self):
        """Yield ``(ticket, outputs)`` in completion order across ALL
        replicas; keeps every replica's pipeline full while iterating and
        retires rounds fan-in round-robin so no replica's results are
        held back behind another's backlog."""
        while True:
            yielded = False
            for rep_id, rep in enumerate(self.replicas):
                while rep._done:
                    loc, outs = rep._done.popitem(last=False)
                    rep._note_claimed([loc])
                    t = self._global[rep_id][loc]
                    self._note_claimed([t])
                    yielded = True
                    yield t, outs
            if yielded:
                continue
            for rep in self.replicas:
                rep._fill_pipeline()
            live = [rep for rep in self.replicas if rep._inflight]
            if not live:
                return
            live[self._rr % len(live)]._retire_oldest()
            self._rr += 1

    def flush(self) -> dict[int, list]:
        """Serve everything queued on every replica; {ticket: outputs}.

        Launches rounds on ALL replicas before blocking on any one of
        them, so the per-device rounds execute concurrently; within each
        replica the usual round pipelining applies.
        """
        while True:
            for rep in self.replicas:
                rep._fill_pipeline()
            live = [rep for rep in self.replicas if rep._inflight]
            if not live:
                break
            for rep in live:
                rep._retire_oldest()
        results: dict[int, list] = {}
        for rep_id, rep in enumerate(self.replicas):
            results.update(self._to_global(rep_id, rep.flush()))
        self._note_claimed(results)
        return results

    def flush_sync(self) -> dict[int, list]:
        """Barrier drain, replica by replica — the sharded oracle path
        (no cross-replica overlap, no intra-replica pipelining)."""
        results: dict[int, list] = {}
        for rep_id, rep in enumerate(self.replicas):
            results.update(self._to_global(rep_id, rep.flush_sync()))
        self._note_claimed(results)
        return results

    # --------------------------------------------------------------- metrics
    def record(self, ticket: int) -> dict:
        """Telemetry for one global ticket (adds the serving replica)."""
        rep, loc = self._owner[ticket]
        rec = self.replicas[rep].record(loc)
        rec["replica"] = rep
        return rec

    def latencies(self) -> dict[int, float]:
        out: dict[int, float] = {}
        for rep_id, rep in enumerate(self.replicas):
            for loc, lat in rep.latencies().items():
                t = self._global[rep_id].get(loc)
                if t is not None:
                    out[t] = lat
        return out

    def latency_percentiles(self, qs=(50, 95, 99)) -> dict[str, float]:
        lats = list(self.latencies().values())
        if not lats:
            return {f"p{q}": float("nan") for q in qs}
        return {f"p{q}": float(np.percentile(lats, q)) for q in qs}

    def reset_metrics(self) -> None:
        """Drop delivered-ticket telemetry AND routing counters (e.g.
        after a warmup drain) so hit rates reflect steady state."""
        for rep in self.replicas:
            rep.reset_metrics()
        # release the claimed tickets' routing maps too — the replicas
        # just dropped those tickets' records, and leaving entries in
        # _owner/_global would leak them for the server's lifetime
        # (delivered-but-unclaimed tickets are not in _claimed and keep
        # their routing)
        while self._claimed:
            t = self._claimed.popleft()
            rep_loc = self._owner.pop(t, None)
            if rep_loc is not None:
                self._global[rep_loc[0]].pop(rep_loc[1], None)
        self.n_route_hits = self.n_route_misses = self.n_migrations = 0
        d = self.directory
        d.n_fresh = d.n_stale = d.n_unknown = 0

    def stats(self) -> dict:
        per = [rep.stats() for rep in self.replicas]
        return {"replicas": self.n_replicas,
                "pending": self.pending,
                "route_hits": self.n_route_hits,
                "route_misses": self.n_route_misses,
                "residency_hit_rate": self.residency_hit_rate,
                "migrations": self.n_migrations,
                "directory": self.directory.stats(),
                "per_replica": per,
                "rounds": sum(p["rounds"] for p in per),
                "requests": sum(p["requests"] for p in per),
                "evictions": sum(p["evictions"] for p in per)}


def overlay_demo(argv_ns) -> int:
    """Mixed-kernel serving demo over the paper's Table II benchmark set.

    Default mode drains with the pipelined ``flush``; ``--stream`` submits
    per-tenant and consumes ``as_completed`` to show completion-order
    delivery plus per-tenant latency percentiles.
    """
    from repro.core.overlay import compile_program
    from repro.core.paper_bench import BENCH_NAMES, benchmark
    from repro.core.vm import dfg_eval

    names = list(BENCH_NAMES) + ["gradient"]
    kernels = {n: compile_program(benchmark(n)) for n in names}
    srv = OverlayServer(bank_capacity=argv_ns.bank, tile=argv_ns.tile,
                        backend=argv_ns.backend,
                        round_kernels=max(1, argv_ns.bank // 2))
    rng = np.random.RandomState(0)
    reqs = []
    for i in range(argv_ns.requests):
        k = kernels[names[i % len(names)]]
        xs = [rng.uniform(-2, 2, (argv_ns.req_batch,)).astype(np.float32)
              for _ in k.dfg.inputs]
        tenant = f"tenant{i % argv_ns.tenants}"
        reqs.append((srv.submit(k, xs, tenant=tenant), k, xs, tenant))
    srv.flush()  # warmup (compiles the executor buckets)
    srv.reset_metrics()
    for _, k, xs, tenant in reqs:
        srv.submit(k, xs, tenant=tenant)
    t0 = time.perf_counter()
    if argv_ns.stream:
        results = {}
        for ticket, outs in srv.as_completed():
            results[ticket] = outs
    else:
        results = srv.flush()
    jax.block_until_ready(list(results.values()))
    dt = time.perf_counter() - t0
    # verify a sample against the DFG oracle
    _, k, xs, _ = reqs[-1]
    ref = dfg_eval(k.dfg, {n: jnp.asarray(v)
                           for n, v in zip(k.dfg.inputs, xs)})
    np.testing.assert_allclose(np.asarray(results[max(results)][0]),
                               np.asarray(ref[k.dfg.outputs[0]]),
                               rtol=1e-5, atol=1e-5)
    st = srv.stats()
    pct = {k_: f"{v * 1e3:.2f}ms"
           for k_, v in srv.latency_percentiles().items()}
    mode = "as_completed stream" if argv_ns.stream else "pipelined flush"
    print(f"served {len(reqs)} mixed requests over {len(names)} kernels "
          f"x {argv_ns.tenants} tenants (bank={argv_ns.bank}, {mode}) "
          f"in {dt * 1e3:.1f} ms = {len(reqs) / dt:,.0f} req/s")
    print(f"delivery latency percentiles: {pct}")
    print(f"server stats: {st}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--overlay-demo", action="store_true",
                    help="serve mixed overlay kernels from a ContextBank")
    ap.add_argument("--bank", type=int, default=4,
                    help="context-bank capacity for --overlay-demo")
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"))
    ap.add_argument("--requests", type=int, default=36)
    ap.add_argument("--req-batch", type=int, default=256)
    ap.add_argument("--tenants", type=int, default=3,
                    help="tenant labels round-robined over --overlay-demo "
                         "requests")
    ap.add_argument("--stream", action="store_true",
                    help="consume results via as_completed instead of flush")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    if args.overlay_demo:
        return overlay_demo(args)
    if args.arch is None:
        ap.error("--arch is required unless --overlay-demo is given")

    from repro.configs import get_config, get_smoke_config
    from repro.models import init_params
    from repro.runtime.steps import make_decode_step, make_prefill_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, G = args.batch, args.prompt_len, args.gen
    cache_len = S + G + cfg.vision_tokens
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg.vocab, jnp.int32)
    batch = {"tokens": prompts}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.zeros(
            (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder is not None:
        batch["frame_embeds"] = jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for i in range(G - 1):
        pos = jnp.asarray(S + cfg.vision_tokens + i, jnp.int32)
        _, tok, caches = decode(params, caches, tok, pos)
        tok = tok[:, None]
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0
    gen = np.asarray(jnp.concatenate(out, 1))
    print(f"prefill: {B}x{S} in {t_prefill * 1e3:.1f} ms "
          f"({B * S / t_prefill:,.0f} tok/s)")
    print(f"decode:  {G - 1} steps in {t_decode * 1e3:.1f} ms "
          f"({B * (G - 1) / max(t_decode, 1e-9):,.0f} tok/s)")
    print("sample token ids:", gen[0][:12].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
