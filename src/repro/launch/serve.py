"""Serving launchers: LM prefill/decode loop + the overlay request engine.

LM serving (prefill + greedy decode)::

  python -m repro.launch.serve --arch gemma3-4b --smoke --batch 4 \
      --prompt-len 32 --gen 16

Multi-tenant overlay serving (the paper's one-pipeline-many-kernels claim
at request scale)::

  python -m repro.launch.serve --overlay-demo --bank 4 --requests 64
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np


# ===================================================== overlay request engine
@dataclasses.dataclass
class OverlayRequest:
    """One queued kernel invocation: a batch of iterations of one kernel."""

    ticket: int
    kernel: object            # core.overlay.CompiledKernel
    xs: list                  # per-primary-input 1-D arrays, equal length

    @property
    def name(self) -> str:
        return self.kernel.program.name

    @property
    def batch(self) -> int:
        return int(np.shape(self.xs[0])[0])


class OverlayServer:
    """Queueing front-end over ``Overlay.dispatch`` + a ``ContextBank``.

    ``submit`` enqueues requests; ``flush`` drains the queue: requests are
    grouped by kernel id, groups are round-robined through the bank in
    rounds of at most ``bank.capacity`` distinct kernels (the ContextBank's
    LRU policy evicts cold contexts when the working set exceeds the bank),
    and each round's mixed-kernel tile stack executes as ONE call into the
    shared executor.  Results come back in submission order.
    """

    def __init__(self, bank_capacity: int = 8, tile: int = 128,
                 backend: str = "jnp", s_max: int = 16,
                 dtype=jnp.float32, max_outputs: int = 8):
        from repro.core.bank import ContextBank
        from repro.core.overlay import Overlay
        self.overlay = Overlay(s_max=s_max, dtype=dtype, backend=backend)
        self.bank = ContextBank(bank_capacity, s_max=s_max, dtype=dtype,
                                max_outputs=max_outputs)
        self.tile = tile
        self._queue: list[OverlayRequest] = []
        self._next_ticket = 0
        self.n_rounds = 0
        self.n_requests = 0

    # ----------------------------------------------------------------- queue
    def submit(self, kernel, xs) -> int:
        """Enqueue one request; returns its ticket (= position key)."""
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append(OverlayRequest(ticket=t, kernel=kernel,
                                          xs=list(xs)))
        return t

    @property
    def pending(self) -> int:
        return len(self._queue)

    # ----------------------------------------------------------------- drain
    def flush(self) -> dict[int, list]:
        """Serve every queued request; returns {ticket: outputs}."""
        if not self._queue:
            return {}
        from repro.core.bank import context_key
        # group by context content (same rule as Overlay.dispatch): two
        # different programs sharing a name are distinct tenants
        groups: OrderedDict[tuple, list[OverlayRequest]] = OrderedDict()
        for r in self._queue:
            groups.setdefault(context_key(r.kernel.program), []).append(r)
        names = list(groups)
        results: dict[int, list] = {}
        cap = self.bank.capacity
        for lo in range(0, len(names), cap):
            round_reqs = [r for n in names[lo:lo + cap] for r in groups[n]]
            outs = self.overlay.dispatch(
                self.bank, [(r.kernel, r.xs) for r in round_reqs],
                tile=self.tile)
            for r, y in zip(round_reqs, outs):
                results[r.ticket] = y
            self.n_rounds += 1
        self.n_requests += len(self._queue)
        self._queue.clear()
        return results

    def stats(self) -> dict:
        s = dict(self.bank.stats())
        s.update({"rounds": self.n_rounds, "requests": self.n_requests,
                  "pending": self.pending})
        return s


def overlay_demo(argv_ns) -> int:
    """Mixed-kernel serving demo over the paper's Table II benchmark set."""
    from repro.core.overlay import compile_program
    from repro.core.paper_bench import BENCH_NAMES, benchmark
    from repro.core.vm import dfg_eval

    names = list(BENCH_NAMES) + ["gradient"]
    kernels = {n: compile_program(benchmark(n)) for n in names}
    srv = OverlayServer(bank_capacity=argv_ns.bank, tile=argv_ns.tile,
                        backend=argv_ns.backend)
    rng = np.random.RandomState(0)
    reqs = []
    for i in range(argv_ns.requests):
        k = kernels[names[i % len(names)]]
        xs = [rng.uniform(-2, 2, (argv_ns.req_batch,)).astype(np.float32)
              for _ in k.dfg.inputs]
        reqs.append((srv.submit(k, xs), k, xs))
    srv.flush()  # warmup (compiles the executor buckets)
    for t, k, xs in reqs:
        srv.submit(k, xs)
    t0 = time.perf_counter()
    results = srv.flush()
    jax.block_until_ready(list(results.values()))
    dt = time.perf_counter() - t0
    # verify a sample against the DFG oracle
    t, k, xs = reqs[-1]
    ref = dfg_eval(k.dfg, {n: jnp.asarray(v)
                           for n, v in zip(k.dfg.inputs, xs)})
    np.testing.assert_allclose(np.asarray(results[max(results)][0]),
                               np.asarray(ref[k.dfg.outputs[0]]),
                               rtol=1e-5, atol=1e-5)
    st = srv.stats()
    print(f"served {len(reqs)} mixed requests over {len(names)} kernels "
          f"(bank={argv_ns.bank}) in {dt * 1e3:.1f} ms "
          f"= {len(reqs) / dt:,.0f} req/s")
    print(f"bank stats: {st}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--overlay-demo", action="store_true",
                    help="serve mixed overlay kernels from a ContextBank")
    ap.add_argument("--bank", type=int, default=4,
                    help="context-bank capacity for --overlay-demo")
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"))
    ap.add_argument("--requests", type=int, default=36)
    ap.add_argument("--req-batch", type=int, default=256)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    if args.overlay_demo:
        return overlay_demo(args)
    if args.arch is None:
        ap.error("--arch is required unless --overlay-demo is given")

    from repro.configs import get_config, get_smoke_config
    from repro.models import init_params
    from repro.runtime.steps import make_decode_step, make_prefill_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, G = args.batch, args.prompt_len, args.gen
    cache_len = S + G + cfg.vision_tokens
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg.vocab, jnp.int32)
    batch = {"tokens": prompts}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.zeros(
            (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder is not None:
        batch["frame_embeds"] = jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for i in range(G - 1):
        pos = jnp.asarray(S + cfg.vision_tokens + i, jnp.int32)
        _, tok, caches = decode(params, caches, tok, pos)
        tok = tok[:, None]
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0
    gen = np.asarray(jnp.concatenate(out, 1))
    print(f"prefill: {B}x{S} in {t_prefill * 1e3:.1f} ms "
          f"({B * S / t_prefill:,.0f} tok/s)")
    print(f"decode:  {G - 1} steps in {t_decode * 1e3:.1f} ms "
          f"({B * (G - 1) / max(t_decode, 1e-9):,.0f} tok/s)")
    print("sample token ids:", gen[0][:12].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
