"""Batched serving launcher: prefill + greedy decode loop.

  python -m repro.launch.serve --arch gemma3-4b --smoke --batch 4 \
      --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_smoke_config
    from repro.models import init_params
    from repro.runtime.steps import make_decode_step, make_prefill_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, G = args.batch, args.prompt_len, args.gen
    cache_len = S + G + cfg.vision_tokens
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg.vocab, jnp.int32)
    batch = {"tokens": prompts}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.zeros(
            (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder is not None:
        batch["frame_embeds"] = jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for i in range(G - 1):
        pos = jnp.asarray(S + cfg.vision_tokens + i, jnp.int32)
        _, tok, caches = decode(params, caches, tok, pos)
        tok = tok[:, None]
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0
    gen = np.asarray(jnp.concatenate(out, 1))
    print(f"prefill: {B}x{S} in {t_prefill * 1e3:.1f} ms "
          f"({B * S / t_prefill:,.0f} tok/s)")
    print(f"decode:  {G - 1} steps in {t_decode * 1e3:.1f} ms "
          f"({B * (G - 1) / max(t_decode, 1e-9):,.0f} tok/s)")
    print("sample token ids:", gen[0][:12].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
