"""Serving launchers: LM prefill/decode loop + the async overlay engine.

LM serving (prefill + greedy decode)::

  python -m repro.launch.serve --arch gemma3-4b --smoke --batch 4 \
      --prompt-len 32 --gen 16

Multi-tenant overlay serving (the paper's one-pipeline-many-kernels claim
at request scale)::

  python -m repro.launch.serve --overlay-demo --bank 4 --requests 64
  python -m repro.launch.serve --overlay-demo --stream --tenants 4

``OverlayServer`` is an ASYNC STREAMING engine over the staged dispatch
pipeline (``Overlay.plan/assemble/execute/collect``, see core/overlay.py):

* ``submit`` returns a ticket immediately; results are retrieved with
  ``result(ticket)``, the ``as_completed()`` iterator (completion order,
  not barrier order), or a bulk ``flush()``.
* Rounds are PIPELINED: while round N executes on device, round N+1's
  host tile stack is assembled and its contexts prefetched into the bank
  (JAX dispatch is async — ``jax.block_until_ready`` happens only at
  result delivery).  ``flush_sync()`` keeps the old drain-the-queue
  barrier loop as the bit-for-bit oracle and benchmark baseline.
* Scheduling DECISIONS are pluggable policies from :mod:`repro.sched`
  (the engine here is only the mechanics — queues, staged launch,
  pinning, tickets): per-tenant token-bucket ADMISSION
  (``sched.admission``), round formation via a ``RoundPolicy``
  (``sched.rounds``: deficit round-robin by default, cross-tenant
  coalescing and latency-adaptive round sizing as drop-ins), and — for
  the sharded fleet — replica ROUTING via a ``RouterPolicy``
  (``sched.routing``: residency affinity, optionally with cross-replica
  work stealing).  ``sched.pump.AutoPump`` wraps either engine with a
  background drain thread so concurrent ``submit`` makes progress
  without an explicit ``flush``.
* In-flight rounds pin their contexts in the ``ContextBank`` so LRU
  eviction can never reassign a slot under a launched round.

``ShardedOverlayServer`` scales the engine across devices: N replicas
(each an ``OverlayServer`` pinned to one device of
``launch.mesh.make_serving_mesh`` with its own bank) behind the router
policy.  The fleet is ELASTIC: ``add_replica``/``drain_replica`` mutate
the replica set under live traffic (drains are loss-free — queued work
evacuates over the steal/adopt path, in-flight rounds retire, delivered
results survive in an orphan store), and an optional
``sched.autoscale.AutoscalePolicy`` drives both from observed queue
pressure.  Results stay bit-for-bit identical to the single-bank engine
(tests/test_sharded_serving.py, tests/test_sched_policies.py,
tests/test_autoscale.py).

See docs/SERVING.md for the engine guide and docs/SCHEDULING.md for the
policy interfaces.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from collections import OrderedDict, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.sched import (AdmissionControl, AdmissionError, AutoPump,
                         DeficitRoundRobin, Flow, OverlayRequest,
                         TokenBucket, WorkRequest, make_round_policy,
                         make_router)
from repro.sched.rounds import DEFAULT_TENANT
from repro.telemetry import InMemorySink, MultiSink, adopt_counters

__all__ = [
    "AdmissionControl", "AdmissionError", "AutoPump", "DEFAULT_TENANT",
    "DeficitRoundRobin", "OverlayRequest", "OverlayServer",
    "ShardedOverlayServer", "TokenBucket", "WorkRequest", "main",
    "overlay_demo", "tenant_latency_summary",
]


#: latency percentiles reported by ``latency_percentiles`` and the
#: per-tenant ``stats()["tenant_latency"]`` tables
LATENCY_QS = (50, 95, 99)


def tenant_latency_summary(samples, qs=LATENCY_QS, slo_s=None) -> dict:
    """Per-tenant latency percentiles + SLO-attainment from raw samples.

    ``samples`` is an iterable of ``(tenant, latency_seconds)`` pairs —
    both engines feed it from their existing per-ticket records, and the
    gateway's shed decisions and the benchmark tables read the SAME
    summary, so there is one source of truth for "how is tenant X doing".
    Returns ``{tenant: {p50, p95, p99, mean, n[, slo_attained, slo_total,
    slo_attainment]}}``.

    ``slo_s`` is a delivery-latency target in seconds — attained means
    ``latency <= slo_s``.  A float applies the same target to every
    tenant; a ``{tenant: seconds}`` dict sets per-tenant SLO classes
    (the slo_study's latency vs bulk tiers) and tenants absent from the
    dict get no SLO fields; None disables SLO accounting entirely.
    """
    by_tenant: dict[str, list] = {}
    for tenant, lat in samples:
        by_tenant.setdefault(tenant, []).append(lat)
    out: dict[str, dict] = {}
    for tenant in sorted(by_tenant):
        lats = by_tenant[tenant]
        row = {f"p{q}": float(np.percentile(lats, q)) for q in qs}
        row["mean"] = float(np.mean(lats))
        row["n"] = len(lats)
        slo = slo_s.get(tenant) if isinstance(slo_s, dict) else slo_s
        if slo is not None:
            attained = sum(1 for lat in lats if lat <= slo)
            row["slo_attained"] = attained
            row["slo_total"] = len(lats)
            row["slo_attainment"] = attained / len(lats)
        out[tenant] = row
    return out


#: the staged-pipeline wall-clock counters surfaced as stats()["stage_walls"]
STAGE_WALL_KEYS = ("plan_s", "assemble_s", "execute_s", "collect_s")


def _stage_walls(telemetry) -> dict:
    """Cumulative per-stage walls of the streaming pipeline (seconds).

    ``plan_s``/``assemble_s`` are pure host work, ``execute_s`` is launch
    dispatch plus the retire-time device wait, ``collect_s`` is the
    readback + slicing.  The ``flush_sync`` barrier oracle goes through
    ``Overlay.dispatch`` and does not contribute.
    """
    return {k: float(telemetry.counter(f"engine.{k}"))
            for k in STAGE_WALL_KEYS}


@dataclasses.dataclass
class _Inflight:
    """A launched-but-undelivered round of the staged pipeline."""

    reqs: list                # [OverlayRequest]
    plan: object              # core.overlay.DispatchPlan (holds the pins)
    ys: object                # device result future, or None (empty round)
    round_no: int
    t_launch: float = 0.0     # engine clock at launch (RoundPolicy.observe)
    work_outs: dict | None = None   # ticket -> WorkRequest fn() output


class OverlayServer:
    """Async streaming front-end over the staged dispatch pipeline.

    Lifecycle of a request (see docs/ARCHITECTURE.md for the diagram):

    1. ``submit(kernel, xs, tenant=...)`` — token-bucket admission check,
       then enqueue on the tenant's flow; returns a ticket.
    2. Round formation — delegated to the injected ``RoundPolicy``
       (default :class:`~repro.sched.rounds.DeficitRoundRobin`, or the
       ``REPRO_ROUND_POLICY`` env knob): at most ``round_kernels``
       distinct kernels per round, policy-specific pacing across tenant
       flows.
    3. Staged launch — ``Overlay.plan`` (pins contexts, assigns slots) →
       ``assemble`` (host tile stack) → ``execute`` (async device call).
       Up to ``max_inflight`` rounds run concurrently: round N+1 is
       planned/assembled while round N executes on device.
    4. Delivery — ``result(ticket)`` / ``as_completed()`` / ``flush()``
       block (``jax.block_until_ready``) only on the round actually being
       delivered; per-ticket latency is recorded, and the round's tile
       count + wall time are fed back to ``RoundPolicy.observe``.

    ``flush_sync()`` serves the same queue through the one-round-at-a-time
    barrier loop (launch, wait, deliver, repeat) — the bit-for-bit oracle
    the tests hold the streaming path to, and the baseline the benchmark
    must beat.
    """

    def __init__(self, bank_capacity: int = 8, tile: int = 128,
                 backend: str = "jnp", s_max: int = 16,
                 dtype=jnp.float32, max_outputs: int = 8,
                 max_inflight: int = 2, round_kernels: int | None = None,
                 quantum_tiles: float | None = None,
                 round_policy=None,
                 admission: dict | None = None,
                 default_admission: tuple | None = None,
                 clock=time.monotonic, metrics_window: int = 65536,
                 device=None, slo_s=None, telemetry=None):
        from repro.core.arena import RoundArena
        from repro.core.bank import ContextBank
        from repro.core.overlay import Overlay
        #: delivery-latency SLO target in seconds (None = no SLO
        #: accounting); a float applies to every tenant, a
        #: ``{tenant: seconds}`` dict sets per-tenant targets (tenants
        #: absent from the dict get no SLO fields).  Drives the
        #: slo_attained/slo_total counters in
        #: ``tenant_latency_percentiles`` and ``stats()``
        self.slo_s = slo_s
        #: the structured telemetry sink (see repro.telemetry) every
        #: engine counter and delivery event flows through; ``stats()``
        #: and the ``n_rounds``/``n_requests``/``n_submits`` properties
        #: are read-throughs over it.  A ShardedOverlayServer hands each
        #: replica ``MultiSink(own, fleet_sink)``.
        self.telemetry = (telemetry if telemetry is not None
                          else InMemorySink(clock=clock))
        #: device this server's bank + rounds are pinned to (None = default
        #: placement); set by ShardedOverlayServer, one device per replica
        self.device = device
        #: zero-copy round pipeline: the overlay assembles into pooled
        #: arena blocks (recycled at ``plan.release`` after delivery, so
        #: pipelined rounds N/N+1 each own their block) and donates the
        #: device tile stack to the executor — the engine consumes each
        #: batch exactly once, which is the donation contract.  The
        #: ``flush_sync`` oracle goes through ``Overlay.dispatch``, which
        #: recycles its own block after launch.
        self.overlay = Overlay(s_max=s_max, dtype=dtype, backend=backend,
                               device=device, arena=RoundArena(),
                               donate=True)
        self.bank = ContextBank(bank_capacity, s_max=s_max, dtype=dtype,
                                max_outputs=max_outputs, device=device)
        self.bank.attach_arena(self.overlay.arena)
        self.tile = tile
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.max_inflight = max_inflight
        #: distinct kernels per round; <= bank capacity.  Smaller rounds
        #: trade per-launch batching for pipeline overlap (see SERVING.md).
        if round_kernels is not None and round_kernels < 1:
            raise ValueError(
                f"round_kernels must be >= 1 or None (= bank capacity), "
                f"got {round_kernels}")
        self.round_kernels = min(round_kernels or bank_capacity,
                                 bank_capacity)
        #: DRR quantum in tiles; None = unbounded (pure round-robin).
        #: Only consulted when no explicit ``round_policy`` is given —
        #: kept as a constructor knob (and validated here) for
        #: compatibility with the pre-sched engine surface.
        self.quantum_tiles = quantum_tiles
        #: the round-formation policy (see repro.sched.rounds).  A string
        #: picks a registered policy by name; None consults the
        #: REPRO_ROUND_POLICY env knob (default deficit round-robin).
        if round_policy is None or isinstance(round_policy, str):
            round_policy = make_round_policy(round_policy,
                                             quantum_tiles=quantum_tiles)
        elif quantum_tiles is not None:
            # an injected policy instance carries its own quantum; the
            # engine-level knob would be silently ignored — refuse loudly
            # rather than drop the fairness bound the caller asked for
            raise ValueError(
                "quantum_tiles was given alongside a round_policy "
                "instance; set the quantum on the policy itself "
                "(engine-level quantum_tiles only configures the "
                "default/named policy)")
        self.round_policy = round_policy
        self.clock = clock
        self.admission = AdmissionControl(admission, default_admission,
                                          clock=clock)
        self._flows: dict[str, Flow] = {}
        self._rr: deque[str] = deque()      # tenant round-robin order
        self._inflight: deque[_Inflight] = deque()
        self._done: OrderedDict[int, list] = OrderedDict()
        self._records: dict[int, dict] = {}
        #: telemetry of CLAIMED tickets is kept for the last
        #: ``metrics_window`` claims only — a long-lived server must not
        #: grow per-request state forever
        self.metrics_window = metrics_window
        self._claimed: deque[int] = deque()
        self._next_ticket = 0
        self._pending_tiles = 0

    # ------------------------------------------------- counters (read-through)
    @property
    def n_submits(self) -> int:
        """Requests accepted by ``submit`` (admission-rejected excluded)."""
        return int(self.telemetry.counter("engine.submits"))

    @property
    def n_rounds(self) -> int:
        """Rounds launched (streaming and sync paths both count)."""
        return int(self.telemetry.counter("engine.rounds"))

    @property
    def n_requests(self) -> int:
        """Requests delivered to the done-store (claimed or not)."""
        return int(self.telemetry.counter("engine.delivered"))

    # ----------------------------------------------------------------- queue
    def submit(self, kernel, xs, tenant: str = DEFAULT_TENANT) -> int:
        """Admit + enqueue one request; returns its ticket immediately.

        Raises :class:`AdmissionError` (without enqueueing) when the
        tenant's token bucket cannot cover the request's tile cost.
        """
        from repro.core.bank import context_key
        xs = list(xs)
        cost = -(-int(np.shape(xs[0])[0]) // self.tile)
        self.admission.admit(tenant, max(1, cost))
        t = self._next_ticket
        self._next_ticket += 1
        req = OverlayRequest(ticket=t, kernel=kernel, xs=xs, tenant=tenant,
                             key=context_key(kernel.program), cost=cost,
                             t_submit=self.clock())
        self._enqueue(req)
        self._records[t] = {"tenant": tenant, "t_submit": req.t_submit,
                            "cost": cost, "t_done": None, "round": None}
        self.telemetry.inc("engine.submits")
        return t

    def submit_work(self, fn, tenant: str = DEFAULT_TENANT, *,
                    cost: int = 1, label: str = "work",
                    key: tuple | None = None) -> int:
        """Admit + enqueue one host-side work item; returns its ticket.

        ``fn`` is a zero-arg callable the engine runs when the round
        policy grants this flow a round slot; its return value is the
        ticket's result (claimed via ``result``/``try_result``/
        ``flush``/``as_completed`` like any kernel request).  ``cost``
        is the tile budget the work charges against the tenant's
        admission bucket and flow deficit — how large the work "looks"
        to the scheduler.  This is how the training tenant rides the
        SAME rounds/tickets/telemetry as serving traffic (see
        ``launch.trainer_tenant``): the scheduler decides when bulk
        work runs, not a side channel.
        """
        cost = max(1, int(cost))
        self.admission.admit(tenant, cost)
        t = self._next_ticket
        self._next_ticket += 1
        req = WorkRequest(ticket=t, kernel=None, xs=[], tenant=tenant,
                          key=key if key is not None
                          else ("__work__", tenant, label),
                          cost=cost, t_submit=self.clock(), fn=fn,
                          label=label)
        self._enqueue(req)
        self._records[t] = {"tenant": tenant, "t_submit": req.t_submit,
                            "cost": cost, "t_done": None, "round": None}
        self.telemetry.inc("engine.submits")
        return t

    def _enqueue(self, req: OverlayRequest) -> None:
        flow = self._flows.get(req.tenant)
        if flow is None:
            flow = self._flows[req.tenant] = Flow(queue=deque())
            self._rr.append(req.tenant)
        flow.queue.append(req)
        self._pending_tiles += req.cost

    @property
    def pending(self) -> int:
        """Requests submitted but not yet delivered (queued + in flight)."""
        queued = sum(len(f.queue) for f in self._flows.values())
        return queued + sum(len(i.reqs) for i in self._inflight)

    @property
    def pending_tiles(self) -> int:
        """Undelivered work in dispatch tiles — the sharded router's load
        signal for least-loaded fallback and migration decisions.  A
        running counter (submit adds, delivery subtracts): the router
        reads this for every replica on every submit, so it must not
        scan the queues."""
        return self._pending_tiles

    @property
    def queued(self) -> int:
        """Requests queued but not yet launched (excludes in flight)."""
        return sum(len(f.queue) for f in self._flows.values())

    @property
    def queued_tiles(self) -> int:
        """Queued-only work in dispatch tiles — what a work-stealing
        router may move (in-flight rounds are never stolen).  Scans the
        queues, so it is read at rebalance time, not per submit."""
        return sum(r.cost for f in self._flows.values() for r in f.queue)

    def queued_by_tenant(self) -> dict[str, int]:
        """Queued-only tiles per tenant (drained flows absent).  The
        training tenant's yield-point probe — "is latency-tier work
        waiting?" — reads this between micro-steps.  Scans the queues,
        so it is for boundary checks, not per-submit hot paths."""
        return {t: sum(r.cost for r in f.queue)
                for t, f in self._flows.items() if f.queue}

    def make_preemptible(self, bulk_tenants=(), bulk_prefix=None):
        """Wrap this engine's round policy in a
        :class:`~repro.sched.preempt.PreemptibleTier` in place and
        return the tier.  Idempotent: repeated calls merge their
        ``bulk_tenants`` into the existing tier.  After this, flows of
        the named tenants (or any tenant matching the bulk prefix) only
        form rounds when every latency-tier flow is idle."""
        from repro.sched.preempt import BULK_PREFIX, PreemptibleTier
        if isinstance(self.round_policy, PreemptibleTier):
            self.round_policy.add_bulk(bulk_tenants)
            return self.round_policy
        self.round_policy = PreemptibleTier(
            self.round_policy, bulk_tenants=bulk_tenants,
            bulk_prefix=bulk_prefix if bulk_prefix is not None
            else BULK_PREFIX)
        return self.round_policy

    # ------------------------------------------------------- round formation
    def _form_round(self) -> list | None:
        """Prune drained flows, then ask the round policy for the next
        round (None = nothing queued)."""
        # prune drained flows: a long-lived server over an unbounded
        # tenant-label space must not scan every tenant ever seen per
        # round (flows are recreated on the tenant's next submit)
        for tenant in [t for t in self._rr if not self._flows[t].queue]:
            del self._flows[tenant]
            self._rr.remove(tenant)
        return self.round_policy.form_round(self._flows, self._rr,
                                            self.round_kernels)

    # ---------------------------------------------------------- work stealing
    def queued_group_keys(self) -> dict:
        """``{context key: kernel}`` over every QUEUED request — the units
        ``steal_queued`` moves.  The stealing router and the elastic
        drain path (``ShardedOverlayServer.drain_replica``) enumerate a
        replica's evacuable work through this."""
        groups: dict = {}
        for flow in self._flows.values():
            for r in flow.queue:
                groups.setdefault(r.key, r.kernel)
        return groups

    def steal_queued(self, key: tuple) -> list[tuple[OverlayRequest, dict]]:
        """Remove every QUEUED request whose context key is ``key`` and
        hand back ``(request, telemetry record)`` pairs, per-tenant
        arrival order preserved.

        The work-stealing router's victim hook: in-flight rounds (and
        their pins) are untouched — only queued work moves.  The caller
        must re-home every pair via ``adopt_queued`` on another replica;
        the tickets in the returned requests are STALE (this engine has
        forgotten them).
        """
        stolen: list[tuple[OverlayRequest, dict]] = []
        for flow in self._flows.values():
            if not any(r.key == key for r in flow.queue):
                continue
            kept: deque = deque()
            for r in flow.queue:
                if r.key == key:
                    stolen.append((r, self._records.pop(r.ticket)))
                else:
                    kept.append(r)
            flow.queue = kept
            if not kept:
                flow.deficit = 0.0      # drained by the steal = idle
        self._pending_tiles -= sum(r.cost for r, _ in stolen)
        return stolen

    def adopt_queued(self, req: OverlayRequest, record: dict) -> int:
        """Enqueue a request stolen from another replica under a fresh
        local ticket; returns it.  The original submit telemetry
        (tenant, cost, t_submit) rides along, so delivery latency spans
        the steal."""
        t = self._next_ticket
        self._next_ticket += 1
        req = dataclasses.replace(req, ticket=t)
        self._enqueue(req)
        self._records[t] = record
        return t

    # ------------------------------------------------------ staged pipeline
    def _run_work(self, work_reqs: list) -> dict:
        """Run a round's work callables (request order) host-side; the
        shared execution point of the streaming and ``flush_sync``
        paths, so a work item's observable order is identical on both.
        Walls land in ``engine.work_s`` (not the device stage walls)."""
        t0 = self.clock()
        work_outs = {r.ticket: r.fn() for r in work_reqs}
        self.telemetry.inc("engine.work_s", self.clock() - t0)
        self.telemetry.inc("engine.work_items", len(work_reqs))
        return work_outs

    def _launch_round(self, reqs: list) -> None:
        """plan (pinned) -> assemble -> execute; delivery happens later.

        Work requests carry no kernel: the device stages skip them, their
        callables run host-side at launch (after the device call is
        dispatched, so host work overlaps device execution), and their
        outputs deliver through the normal ticket path at retire."""
        from repro.core.bank import BankError
        kern_reqs = [r for r in reqs if not isinstance(r, WorkRequest)]
        work_reqs = [r for r in reqs if isinstance(r, WorkRequest)]
        if not kern_reqs:
            work_outs = self._run_work(work_reqs)
            round_no = int(self.telemetry.inc("engine.rounds")) - 1
            self._inflight.append(_Inflight(reqs=reqs, plan=None, ys=None,
                                            round_no=round_no,
                                            t_launch=self.clock(),
                                            work_outs=work_outs))
            return
        round_kernels = {r.key: r.kernel for r in kern_reqs}
        needed = sum(1 for k in round_kernels.values() if k not in self.bank)
        # retire in-flight rounds until the round's NEW contexts fit the
        # unpinned portion of the bank; the round's own resident kernels
        # are excluded — they will be pinned, not evicted, so their slots
        # cannot satisfy a new context's demand
        while self._inflight and self.bank.evictable_capacity(
                excluding=round_kernels) < needed:
            self._retire_oldest()
        pairs = [(r.kernel, r.xs) for r in kern_reqs]
        plan_s = 0.0
        while True:
            t0 = self.clock()
            try:
                plan = self.overlay.plan(self.bank, pairs, tile=self.tile,
                                         pin=True)
                plan_s += self.clock() - t0
                break
            except BankError:
                # belt-and-braces: plan unwinds its own pins on failure, so
                # retiring one more round and retrying is always safe
                plan_s += self.clock() - t0
                if not self._inflight:
                    raise
                self._retire_oldest()
        t1 = self.clock()
        batch = self.overlay.assemble(plan)
        t2 = self.clock()
        ys = self.overlay.execute(self.bank, batch)
        # stage walls (streaming path only; flush_sync goes through the
        # dispatch oracle): plan/assemble are host work, execute here is
        # launch dispatch — the device wait lands in execute_s at retire
        self.telemetry.inc("engine.plan_s", plan_s)
        self.telemetry.inc("engine.assemble_s", t2 - t1)
        self.telemetry.inc("engine.execute_s", self.clock() - t2)
        work_outs = self._run_work(work_reqs) if work_reqs else None
        round_no = int(self.telemetry.inc("engine.rounds")) - 1
        self._inflight.append(_Inflight(reqs=reqs, plan=plan, ys=ys,
                                        round_no=round_no,
                                        t_launch=self.clock(),
                                        work_outs=work_outs))

    def _retire_oldest(self) -> list:
        """Deliver the oldest in-flight round; returns its tickets."""
        inf = self._inflight.popleft()
        t0 = self.clock()
        if inf.ys is not None:
            jax.block_until_ready(inf.ys)
        t1 = self.clock()
        # host=True: live tiles/rows sliced device-side, one readback;
        # per-request slicing is numpy views, never device-op dispatch
        # (pure-work rounds have no plan and skip the device stages)
        outs = (self.overlay.collect(inf.plan, inf.ys, host=True)
                if inf.plan is not None else [])
        now = self.clock()
        self.telemetry.inc("engine.execute_s", t1 - t0)   # device wait
        self.telemetry.inc("engine.collect_s", now - t1)
        tickets = []
        kern_outs = iter(outs)
        for r in inf.reqs:
            y = (inf.work_outs[r.ticket] if isinstance(r, WorkRequest)
                 else next(kern_outs))
            self._done[r.ticket] = y
            rec = self._records[r.ticket]
            rec["t_done"] = now
            rec["round"] = inf.round_no
            tickets.append(r.ticket)
            self.telemetry.event("deliver", tenant=r.tenant, cost=r.cost,
                                 round=inf.round_no,
                                 latency_s=now - rec["t_submit"])
        if inf.plan is not None:
            inf.plan.release(self.bank)
        round_cost = sum(r.cost for r in inf.reqs)
        self._pending_tiles -= round_cost
        self.telemetry.inc("engine.delivered", len(inf.reqs))
        self.telemetry.log_step(inf.round_no, tiles=round_cost,
                                requests=len(inf.reqs),
                                wall_s=now - inf.t_launch)
        # feedback edge: adaptive policies size future rounds off this.
        # Units are per-request ceil tiles (r.cost) — the SAME units the
        # policies budget rounds in (and flush_sync reports), never the
        # plan's merged group tiles, or a budget-vs-observation mismatch
        # would stall DynamicTilePolicy's growth on sub-tile requests
        self.round_policy.observe(round_cost, now - inf.t_launch)
        return tickets

    def _fill_pipeline(self) -> None:
        while len(self._inflight) < self.max_inflight:
            reqs = self._form_round()
            if reqs is None:
                return
            self._launch_round(reqs)

    def pump_once(self) -> bool:
        """One unit of drain work: top up the pipeline, deliver the
        oldest in-flight round.  Returns False when idle (nothing queued,
        nothing in flight) — the ``sched.pump.AutoPump`` loop edge."""
        self._fill_pipeline()
        if not self._inflight:
            return False
        self._retire_oldest()
        return True

    def _note_claimed(self, tickets) -> None:
        """Record claims and prune telemetry beyond ``metrics_window``."""
        self._claimed.extend(tickets)
        while len(self._claimed) > self.metrics_window:
            self._records.pop(self._claimed.popleft(), None)

    # -------------------------------------------------------------- retrieve
    def try_result(self, ticket: int):
        """Non-blocking claim: the ticket's outputs if already delivered,
        else None (still queued or in flight).  Raises KeyError for
        unknown or already-claimed tickets, like ``result``."""
        if ticket in self._done:
            self._note_claimed([ticket])
            return self._done.pop(ticket)
        if ticket not in self._records:
            raise KeyError(f"unknown ticket {ticket}")
        if self._records[ticket]["t_done"] is not None:
            raise KeyError(f"ticket {ticket} already claimed")
        return None

    def result(self, ticket: int):
        """Block until ``ticket``'s outputs are ready and return them.

        Drives the pipeline as needed; each claim pops the result (a
        ticket can be claimed once, via ``result``/``as_completed``/
        ``flush``).
        """
        if ticket not in self._records and ticket not in self._done:
            raise KeyError(f"unknown ticket {ticket}")
        while ticket not in self._done:
            if self._records[ticket]["t_done"] is not None:
                raise KeyError(f"ticket {ticket} already claimed")
            self._fill_pipeline()
            if not self._inflight:
                raise KeyError(f"ticket {ticket} is not queued (lost?)")
            self._retire_oldest()
        self._note_claimed([ticket])
        return self._done.pop(ticket)

    def as_completed(self):
        """Yield ``(ticket, outputs)`` in COMPLETION order, streaming.

        Rounds are delivered as they finish (arrival order, not the
        submission-barrier order of ``flush``); within a round, tickets
        come back grouped by kernel (round assembly batches per kernel),
        in submission order within each kernel.  New ``submit`` calls
        made while iterating are picked up — iteration ends when the
        server is idle.
        """
        while True:
            if self._done:
                ticket, outs = self._done.popitem(last=False)
                self._note_claimed([ticket])
                yield ticket, outs
                continue
            self._fill_pipeline()
            if not self._inflight:
                return
            self._retire_oldest()

    def flush(self) -> dict[int, list]:
        """Serve everything queued; returns {ticket: outputs}.

        Pipelined drain: up to ``max_inflight`` rounds overlap, so round
        N+1's host assembly and context prefetch hide under round N's
        device execution; the device is never left idle waiting for the
        host between rounds (compare ``flush_sync``).
        """
        while True:
            self._fill_pipeline()
            if not self._inflight:
                break
            self._retire_oldest()
        results = dict(self._done)
        self._done.clear()
        self._note_claimed(results)
        return results

    def flush_sync(self) -> dict[int, list]:
        """Barrier drain: one round at a time, waiting on each.

        Identical round formation and dispatch math to ``flush`` — only
        the overlap is missing, which makes this the bit-for-bit oracle
        for the streaming path and the baseline it must beat.
        """
        # rounds already launched by the pipelined API belong to this
        # drain too: deliver them first (releasing their pins) so no
        # ticket is dropped and no pin outlives its round
        while self._inflight:
            self._retire_oldest()
        results: dict[int, list] = {}
        while (reqs := self._form_round()) is not None:
            t_launch = self.clock()
            kern_reqs = [r for r in reqs if not isinstance(r, WorkRequest)]
            work_reqs = [r for r in reqs if isinstance(r, WorkRequest)]
            outs = (self.overlay.dispatch(
                self.bank, [(r.kernel, r.xs) for r in kern_reqs],
                tile=self.tile) if kern_reqs else [])
            jax.block_until_ready([y for ys in outs for y in ys])
            work_outs = self._run_work(work_reqs) if work_reqs else {}
            now = self.clock()
            round_no = int(self.telemetry.inc("engine.rounds")) - 1
            kern_outs = iter(outs)
            for r in reqs:
                y = (work_outs[r.ticket] if isinstance(r, WorkRequest)
                     else next(kern_outs))
                results[r.ticket] = y
                self._records[r.ticket].update(t_done=now, round=round_no)
                self.telemetry.event(
                    "deliver", tenant=r.tenant, cost=r.cost, round=round_no,
                    latency_s=now - self._records[r.ticket]["t_submit"])
            round_cost = sum(r.cost for r in reqs)
            self._pending_tiles -= round_cost
            self.telemetry.inc("engine.delivered", len(reqs))
            self.telemetry.log_step(round_no, tiles=round_cost,
                                    requests=len(reqs),
                                    wall_s=now - t_launch)
            self.round_policy.observe(round_cost, now - t_launch)
        results.update(self._done)
        self._done.clear()
        self._note_claimed(results)
        return results

    # --------------------------------------------------------------- metrics
    def latencies(self) -> dict[int, float]:
        """Per-delivered-ticket submit->delivery seconds."""
        return {t: rec["t_done"] - rec["t_submit"]
                for t, rec in self._records.items()
                if rec["t_done"] is not None}

    def latency_percentiles(self, qs=LATENCY_QS) -> dict[str, float]:
        lats = list(self.latencies().values())
        if not lats:
            return {f"p{q}": float("nan") for q in qs}
        return {f"p{q}": float(np.percentile(lats, q)) for q in qs}

    def tenant_latencies(self):
        """Yield ``(tenant, latency_seconds)`` per delivered ticket."""
        for rec in self._records.values():
            if rec["t_done"] is not None:
                yield rec["tenant"], rec["t_done"] - rec["t_submit"]

    def tenant_latency_percentiles(self, qs=LATENCY_QS) -> dict:
        """Per-tenant p50/p95/p99 + SLO attainment (see
        :func:`tenant_latency_summary`); SLO fields appear when the
        engine was built with ``slo_s``."""
        return tenant_latency_summary(self.tenant_latencies(), qs=qs,
                                      slo_s=self.slo_s)

    def record(self, ticket: int) -> dict:
        """Telemetry for one ticket (tenant, cost, submit/done, round)."""
        return dict(self._records[ticket])

    def reset_metrics(self) -> None:
        """Drop delivered-ticket telemetry (e.g. after a warmup drain) so
        percentiles reflect steady state, not executor compilation.

        Records of pending tickets and of delivered-but-unclaimed results
        (still claimable via ``result``/``flush``) are kept.
        """
        self._records = {t: r for t, r in self._records.items()
                         if r["t_done"] is None or t in self._done}
        self._claimed.clear()

    def stats(self) -> dict:
        s = dict(self.bank.stats())
        s.update({"submits": self.n_submits,
                  "rounds": self.n_rounds, "requests": self.n_requests,
                  "pending": self.pending, "inflight": len(self._inflight),
                  "queued": self.queued, "queued_tiles": self.queued_tiles,
                  "tenants": len(self._flows),
                  "round_policy": type(self.round_policy).__name__,
                  "stage_walls": _stage_walls(self.telemetry),
                  "tenant_latency": self.tenant_latency_percentiles()})
        return s


# ==================================================== sharded serving layer
class ShardedOverlayServer:
    """Policy-routed serving over N per-replica context banks.

    The paper keeps ONE time-multiplexed FU pipeline hot by making a
    kernel switch an index; the single-bank ``OverlayServer`` scales that
    to one device.  This layer scales it ACROSS devices the way many-core
    overlays replicate the overlay fabric — except replicas are not
    mirrors: each hosts its own ``ContextBank`` working set (affinity, not
    replication), so aggregate residency grows with the fleet while each
    replica's instruction store stays small.

    * ROUTING + REBALANCING are delegated to a
      :class:`~repro.sched.routing.RouterPolicy`.  The default
      :class:`~repro.sched.routing.ResidencyRouter` keys every request by
      context content, routes residency hits to the owning replica
      (directory entries validated by residency generation), falls back
      least-loaded on miss/stale, and migrates hot contexts with
      hysteresis + cooldown.  ``steal=True`` swaps in the
      :class:`~repro.sched.routing.WorkStealingRouter`: at drain time an
      idle replica pulls whole queued kernel-groups from the
      most-backlogged replica (context prefetched on the thief first,
      directory republished, in-flight rounds never touched).
    * ADMISSION — token buckets live HERE, spanning replicas, so a
      tenant's rate cannot be dodged by its kernels landing on different
      replicas.  Per-replica round-policy fairness is unchanged
      underneath.
    * DELIVERY — tickets are global; ``flush``/``as_completed``/``result``
      merge the per-replica pipelines.  The drain interleaves round
      launches across replicas before blocking on any of them, so
      per-device rounds execute concurrently (JAX async dispatch).
      ``flush_sync`` drains replica-by-replica with the barrier loop — the
      oracle path (no pipelining, no stealing).

    Every replica is a full ``OverlayServer`` pinned to one device of
    ``launch.mesh.make_serving_mesh`` (devices wrap when the fleet is
    larger than the machine — correctness never depends on real device
    count, which is how the differential tests run 2/4/8 replicas in CI).

    * ELASTICITY — the replica set is mutable under live traffic:
      ``add_replica()`` grows the fleet onto the least-shared physical
      device, ``drain_replica(i)`` decommissions one replica loss-free
      (evacuate queued work via steal/adopt, retire in-flight rounds,
      orphan delivered-but-unclaimed results at the fleet level,
      unpublish + generation-bump its directory entries, compact
      indices).  Passing ``autoscaler=`` (see
      :mod:`repro.sched.autoscale`) automates both from queue pressure,
      observed on every drain pass and autopump tick; ``flush_sync``
      never scales — it stays the oracle.
    """

    def __init__(self, n_replicas: int = 2, bank_capacity: int = 8,
                 tile: int = 128, backend: str = "jnp", s_max: int = 16,
                 dtype=jnp.float32, max_outputs: int = 8,
                 max_inflight: int = 2, round_kernels: int | None = None,
                 quantum_tiles: float | None = None,
                 round_policy=None, router=None, steal: bool = False,
                 admission: dict | None = None,
                 default_admission: tuple | None = None,
                 clock=time.monotonic, metrics_window: int = 65536,
                 devices=None, migrate_factor: float = 4.0,
                 migrate_min_tiles: int = 16, migrate_cooldown: int = 32,
                 steal_min_tiles: int = 4, autoscaler=None,
                 slo_s=None, telemetry=None):
        from repro.launch.mesh import make_serving_mesh
        #: fleet-wide delivery-latency SLO target (seconds, or a
        #: ``{tenant: seconds}`` dict of SLO classes); replicas inherit
        #: it, so per-tenant SLO attainment aggregates cleanly
        self.slo_s = slo_s
        #: the fleet's shared telemetry sink: every replica writes
        #: through ``MultiSink(own, this)``, so fleet aggregates (rounds,
        #: deliveries, evictions) accumulate here across replicas that
        #: have since been drained — no hand-folded ``_retired_*`` state.
        #: The router and autoscaler are re-bound to it too.
        self.telemetry = (telemetry if telemetry is not None
                          else InMemorySink(clock=clock))
        #: candidate devices for replica placement — the pool elastic
        #: scale-ups draw from (add_replica picks its least-shared member)
        self._device_pool = (list(devices) if devices is not None
                             else list(jax.devices()))
        self.devices = make_serving_mesh(n_replicas, self._device_pool)
        self.tile = tile
        # each replica builds its OWN round policy (policies may carry
        # feedback state, e.g. DynamicTilePolicy's adapted budget): a
        # string/None resolves per replica, a zero-arg factory is invoked
        # per replica.  Passing one policy INSTANCE shares it across
        # replicas — fine for stateless pacing, use a factory otherwise.
        def _policy_for_replica():
            return round_policy() if callable(round_policy) else round_policy
        self._policy_factory = _policy_for_replica
        #: constructor knobs every replica shares — kept so elastic
        #: scale-ups (``add_replica``) build replicas identical to the
        #: founding fleet.  Replicas do NOT get admission policies:
        #: admission is global.
        self._replica_kw = dict(
            bank_capacity=bank_capacity, tile=tile, backend=backend,
            s_max=s_max, dtype=dtype, max_outputs=max_outputs,
            max_inflight=max_inflight, round_kernels=round_kernels,
            quantum_tiles=quantum_tiles, clock=clock,
            metrics_window=metrics_window, slo_s=slo_s)
        self.replicas = [
            OverlayServer(round_policy=_policy_for_replica(), device=d,
                          telemetry=self._replica_sink(),
                          **self._replica_kw)
            for d in self.devices]
        #: the routing policy (see repro.sched.routing); ``steal=True``
        #: without an explicit router builds a WorkStealingRouter
        self.router = router if router is not None else make_router(
            steal=steal, migrate_factor=migrate_factor,
            migrate_min_tiles=migrate_min_tiles,
            migrate_cooldown=migrate_cooldown,
            steal_min_tiles=steal_min_tiles)
        #: the fleet-sizing policy (see repro.sched.autoscale); None =
        #: static fleet.  Observed once per drain pass / pump tick.
        self.autoscaler = autoscaler
        # re-bind the router's and autoscaler's sinks onto the fleet's,
        # carrying over anything they counted pre-binding, so one sink
        # holds the whole serving story (guarded: the protocols don't
        # require a telemetry attribute of custom policies)
        for part in (self.router, self.autoscaler):
            sink = getattr(part, "telemetry", None)
            if sink is not None and sink is not self.telemetry:
                adopt_counters(self.telemetry, sink)
                part.telemetry = self.telemetry
        self.admission = AdmissionControl(admission, default_admission,
                                          clock=clock)
        self.clock = clock
        self.metrics_window = metrics_window
        #: (bulk tenant set, bulk prefix) once make_preemptible was
        #: called — future add_replica replicas get the tier installed
        self._bulk_spec: tuple[set, str] | None = None
        self._owner: dict[int, tuple[int, int]] = {}   # global -> (rep, loc)
        self._global: list[dict[int, int]] = [
            {} for _ in self.replicas]                 # rep: loc -> global
        #: results whose replica was decommissioned before the client
        #: claimed them: global ticket -> outputs (and the matching
        #: telemetry records).  Every claim path checks here first.
        self._orphaned: OrderedDict[int, list] = OrderedDict()
        self._orphan_records: dict[int, dict] = {}
        self._claimed: deque[int] = deque()
        self._next_ticket = 0
        self._rr = 0                                   # retire fan-in ptr
        # elastic-fleet telemetry
        self._born = [self.clock() for _ in self.replicas]
        #: high-water fleet size since construction (benchmarks reset it
        #: per measurement window to integrate capacity over time)
        self.peak_replicas = len(self.replicas)

    def _replica_sink(self):
        """A fresh replica's sink: its own store fanned into the fleet's.

        Reads (per-replica ``stats()``) come from the replica's own
        store; every write also lands in the shared fleet sink, which is
        how rounds/deliveries/evictions served by since-retired replicas
        stay in the fleet aggregates after ``drain_replica``.
        """
        return MultiSink(InMemorySink(clock=self._replica_kw["clock"]),
                         self.telemetry)

    # ------------------------------------------------- counters (read-through)
    @property
    def n_submits(self) -> int:
        return int(self.telemetry.counter("fleet.submits"))

    @property
    def n_scale_ups(self) -> int:
        return int(self.telemetry.counter("fleet.scale_ups"))

    @property
    def n_scale_downs(self) -> int:
        return int(self.telemetry.counter("fleet.scale_downs"))

    @property
    def n_evacuated_requests(self) -> int:
        return int(self.telemetry.counter("fleet.evacuated_requests"))

    @property
    def n_evacuated_tiles(self) -> int:
        return int(self.telemetry.counter("fleet.evacuated_tiles"))

    @property
    def n_replicas_retired(self) -> int:
        return int(self.telemetry.counter("fleet.replicas_retired"))

    @property
    def retired_lifetime_s(self) -> float:
        return float(self.telemetry.counter("fleet.retired_lifetime_s"))

    @property
    def n_replicas(self) -> int:
        """Live replica count (mutates under elastic autoscaling)."""
        return len(self.replicas)

    @property
    def banks(self):
        """Per-replica ContextBanks, replica order."""
        return [rep.bank for rep in self.replicas]

    # --------------------------------------------- router-facing delegation
    @property
    def directory(self):
        """The router's shared BankDirectory (residency cache)."""
        return self.router.directory

    @property
    def n_route_hits(self) -> int:
        return self.router.n_hits

    @property
    def n_route_misses(self) -> int:
        return self.router.n_misses

    @property
    def n_migrations(self) -> int:
        return self.router.n_migrations

    @property
    def n_steals(self) -> int:
        return getattr(self.router, "n_steals", 0)

    @property
    def residency_hit_rate(self) -> float:
        """Routed-to-resident-replica fraction (stale hits count as
        misses); NaN before any routing decision."""
        return self.router.hit_rate

    def adopt_stolen(self, victim: int, thief: int, stolen) -> None:
        """Re-home stolen queued requests' global tickets — the router's
        bookkeeping hook after ``replicas[victim].steal_queued``.  Each
        request gets a fresh local ticket on the thief; its global ticket
        (what the client holds) follows it."""
        for req, rec in stolen:
            g = self._global[victim].pop(req.ticket)
            loc = self.replicas[thief].adopt_queued(req, rec)
            self._owner[g] = (thief, loc)
            self._global[thief][loc] = g

    def move_group(self, victim: int, thief: int, key: tuple,
                   kernel) -> list:
        """Move one queued kernel-group from ``victim`` to ``thief``;
        returns the moved requests (possibly empty).

        THE single implementation of the cross-replica move sequence —
        ``WorkStealingRouter.rebalance`` and ``drain_replica`` both call
        it — so the ordering invariant lives in one place: the thief's
        bank prefetches the context FIRST (a ``BankError`` propagates
        with nothing moved — the caller picks another thief or skips),
        the directory is republished so follow-up traffic chases the
        work, then the queued requests leave the victim and are adopted
        under fresh thief tickets with their global tickets re-homed.
        In-flight rounds and pins are never touched.

        Work-request groups (``kernel is None`` — host-side work has no
        context) skip the prefetch/republish steps: they are moved by
        queue surgery alone, which is how ``drain_replica`` evacuates a
        training tenant's queued micro-rounds loss-free.
        """
        thief_rep = self.replicas[thief]
        if kernel is not None:
            thief_rep.bank.prefetch([kernel])
            self.directory.republish_current(kernel, thief, thief_rep.bank)
        stolen = self.replicas[victim].steal_queued(key)
        self.adopt_stolen(victim, thief, stolen)
        return [req for req, _ in stolen]

    # ------------------------------------------------------- elastic fleet
    def add_replica(self, device=None) -> int:
        """Grow the fleet by one replica; returns its index.

        The new replica is a full ``OverlayServer`` built with the
        founding fleet's knobs (its own round policy instance, its own
        device-committed ``ContextBank``), placed on ``device`` or — the
        autoscaling default — on the physical device currently hosting
        the FEWEST replicas (``launch.mesh.least_shared_device``), so
        grown capacity is real parallelism before it is time-slicing.
        The router needs no registration: an empty bank simply never
        validates a directory entry, and the least-loaded fallback (plus
        a stealing router's ``rebalance``) starts feeding the newcomer
        immediately.
        """
        from repro.launch.mesh import least_shared_device
        if device is None:
            device = least_shared_device(self._device_pool, self.devices)
        rep = OverlayServer(round_policy=self._policy_factory(),
                            device=device, telemetry=self._replica_sink(),
                            **self._replica_kw)
        if self._bulk_spec is not None:
            rep.make_preemptible(self._bulk_spec[0],
                                 bulk_prefix=self._bulk_spec[1])
        self.replicas.append(rep)
        self.devices.append(device)
        self._global.append({})
        self._born.append(self.clock())
        self.peak_replicas = max(self.peak_replicas, len(self.replicas))
        self.telemetry.inc("fleet.scale_ups")
        self.telemetry.event("scale_up", replica=len(self.replicas) - 1,
                             device=str(device), fleet=len(self.replicas))
        return len(self.replicas) - 1

    def drain_replica(self, i: int) -> dict:
        """Loss-free decommission of replica ``i``; returns telemetry.

        The drain lifecycle (see docs/SCHEDULING.md#autoscaling):

        1. EVACUATE queued work: every queued kernel-group moves to the
           least-loaded surviving replica over the existing steal/adopt
           path — context prefetched on the target FIRST, directory
           republished, global tickets re-homed (``adopt_stolen``), so
           clients notice nothing.  A momentarily all-pinned target
           retires one of its in-flight rounds and the evacuation
           retries.
        2. RETIRE in-flight rounds: delivered through the normal path,
           releasing their pins — pins are never broken, launched rounds
           always complete on the device that planned them.
        3. ORPHAN delivered-but-unclaimed results (and the replica's
           ticket telemetry) into a fleet-level store; every claim path
           (``result``/``try_result``/``as_completed``/``flush``) checks
           it first, so tickets survive their replica.
        4. UNPUBLISH the replica's ``BankDirectory`` entries and retire
           its bank (generation bump): any stale residency snapshot now
           fails validation and falls back to the miss path instead of
           resolving to a decommissioned replica.
        5. DECOMMISSION: the replica leaves the fleet and indices
           compact (directory + ticket maps renumbered).

        Raises ``ValueError`` for the last replica (a fleet of zero can
        serve nothing; ``AutoscalePolicy.min_replicas`` should prevent
        this upstream) and ``IndexError`` for an unknown index.
        """
        from repro.core.bank import BankError
        if not 0 <= i < len(self.replicas):
            raise IndexError(
                f"drain_replica: no replica {i} (fleet has "
                f"{len(self.replicas)})")
        if len(self.replicas) <= 1:
            raise ValueError("drain_replica: cannot drain the last replica")
        rep = self.replicas[i]
        evac_requests = evac_tiles = 0
        while rep.queued:
            # one scan per pass: queued_group_keys walks every queued
            # request, so iterate the whole group map rather than
            # rebuilding it per group (the outer while normally runs
            # once — it only re-enters if a move legitimately left work)
            for key, kernel in list(rep.queued_group_keys().items()):
                while True:
                    order = sorted(
                        (j for j in range(len(self.replicas)) if j != i),
                        key=lambda j: self.replicas[j].pending_tiles)
                    moved = None
                    for j in order:
                        try:
                            moved = self.move_group(i, j, key, kernel)
                            break
                        except BankError:
                            continue
                    if moved is not None:
                        break
                    # every surviving bank is momentarily all pinned:
                    # retire the least-loaded survivor's oldest round
                    # (released pins free slots) and retry — pins only
                    # exist while rounds are in flight, so this always
                    # makes progress
                    survivor = self.replicas[order[0]]
                    if not survivor._inflight:
                        raise BankError(
                            "drain_replica: no surviving replica can "
                            "host the evacuated context")
                    survivor._retire_oldest()
                evac_requests += len(moved)
                evac_tiles += sum(r.cost for r in moved)
        while rep._inflight:
            rep._retire_oldest()
        orphaned_now = len(rep._done)
        for loc, outs in rep._done.items():
            self._orphaned[self._global[i][loc]] = outs
        rep._done.clear()
        for loc, record in rep._records.items():
            g = self._global[i].get(loc)
            if g is not None:      # claimed + pruned records have no global
                self._orphan_records[g] = record
        for g in self._global[i].values():
            self._owner.pop(g, None)
        self.directory.remove_replica(i)
        rep.bank.retire()
        # the replica's rounds/deliveries already live in the shared
        # fleet sink (every replica writes through MultiSink(own, fleet))
        # so fleet stats() keeps them for free; bank evictions are the
        # one per-replica counter that does NOT flow through the engine
        # sink — fold them here before the bank goes away
        self.telemetry.inc("fleet.retired_evictions", rep.bank.n_evictions)
        self.replicas.pop(i)
        self.devices.pop(i)
        self._global.pop(i)
        lifetime = self.clock() - self._born.pop(i)
        self.telemetry.inc("fleet.scale_downs")
        self.telemetry.inc("fleet.replicas_retired")
        self.telemetry.inc("fleet.retired_lifetime_s", lifetime)
        self.telemetry.inc("fleet.evacuated_requests", evac_requests)
        self.telemetry.inc("fleet.evacuated_tiles", evac_tiles)
        self.telemetry.inc("fleet.orphaned_results", orphaned_now)
        self.telemetry.event("scale_down", replica=i, lifetime_s=lifetime,
                             evacuated_requests=evac_requests,
                             evacuated_tiles=evac_tiles,
                             orphaned_results=orphaned_now,
                             fleet=len(self.replicas))
        self._owner = {t: ((r - 1, loc) if r > i else (r, loc))
                       for t, (r, loc) in self._owner.items()}
        return {"replica": i, "evacuated_requests": evac_requests,
                "evacuated_tiles": evac_tiles,
                "orphaned_results": orphaned_now,
                "lifetime_s": lifetime}

    def autoscale_once(self) -> int:
        """Observe the autoscaler and apply its decisions; returns how
        many actions were applied.  Called from every drain pass and the
        pump tick; a no-op without an autoscaler.  The shell re-checks
        its own invariants (never below one replica, index still live),
        so a policy bug degrades to a skipped action."""
        if self.autoscaler is None:
            return 0
        # "down" indices refer to the fleet AS OBSERVED: applying an
        # earlier action compacts indices, so resolve each index to its
        # replica object first and re-look it up at apply time — a later
        # action from the same snapshot can never target the wrong
        # replica, and one already drained degrades to a skipped action
        snapshot = list(self.replicas)
        # the shell-side runaway guard: honor the policy's own declared
        # ceiling (PressureAutoscaler always carries one), so a buggy
        # observe() that returns "up" forever degrades to skipped
        # actions instead of growing the fleet to OOM under a pump tick
        limit = getattr(self.autoscaler, "max_replicas", None)
        applied = 0
        for kind, idx in self.autoscaler.observe(self):
            if kind == "up":
                if limit is not None and len(self.replicas) >= limit:
                    continue
                self.add_replica()
                applied += 1
            elif (kind == "down" and idx is not None
                    and 0 <= idx < len(snapshot)):
                try:
                    live = self.replicas.index(snapshot[idx])
                except ValueError:
                    continue
                if len(self.replicas) > 1:
                    self.drain_replica(live)
                    applied += 1
        return applied

    # ----------------------------------------------------------------- queue
    def submit(self, kernel, xs, tenant: str = DEFAULT_TENANT) -> int:
        """Admit globally, route via the router policy, enqueue on one
        replica; returns a global ticket."""
        xs = list(xs)
        cost = max(1, -(-int(np.shape(xs[0])[0]) // self.tile))
        self.admission.admit(tenant, cost)
        rep = self.router.route(kernel, self)
        loc = self.replicas[rep].submit(kernel, xs, tenant=tenant)
        t = self._next_ticket
        self._next_ticket += 1
        self._owner[t] = (rep, loc)
        self._global[rep][loc] = t
        self.telemetry.inc("fleet.submits")
        return t

    def submit_work(self, fn, tenant: str = DEFAULT_TENANT, *,
                    cost: int = 1, label: str = "work",
                    key: tuple | None = None) -> int:
        """Admit globally, enqueue host-side work on the least-loaded
        replica (work has no context residency to chase); returns a
        global ticket.  See ``OverlayServer.submit_work``."""
        cost = max(1, int(cost))
        self.admission.admit(tenant, cost)
        rep = min(range(len(self.replicas)),
                  key=lambda i: self.replicas[i].pending_tiles)
        loc = self.replicas[rep].submit_work(fn, tenant=tenant, cost=cost,
                                             label=label, key=key)
        t = self._next_ticket
        self._next_ticket += 1
        self._owner[t] = (rep, loc)
        self._global[rep][loc] = t
        self.telemetry.inc("fleet.submits")
        return t

    def queued_by_tenant(self) -> dict[str, int]:
        """Fleet-wide queued-only tiles per tenant (see
        ``OverlayServer.queued_by_tenant``)."""
        out: dict[str, int] = {}
        for rep in self.replicas:
            for tenant, tiles in rep.queued_by_tenant().items():
                out[tenant] = out.get(tenant, 0) + tiles
        return out

    def make_preemptible(self, bulk_tenants=(), bulk_prefix=None):
        """Install the preemptible bulk tier on EVERY replica's round
        policy (idempotent; replicas added later inherit it).  Returns
        the per-replica tiers, replica order."""
        from repro.sched.preempt import BULK_PREFIX
        prefix = bulk_prefix if bulk_prefix is not None else BULK_PREFIX
        if self._bulk_spec is None:
            self._bulk_spec = (set(bulk_tenants), prefix)
        else:
            self._bulk_spec[0].update(bulk_tenants)
        return [rep.make_preemptible(self._bulk_spec[0],
                                     bulk_prefix=self._bulk_spec[1])
                for rep in self.replicas]

    @property
    def pending(self) -> int:
        return sum(rep.pending for rep in self.replicas)

    @property
    def pending_tiles(self) -> int:
        """Fleet-wide undelivered work in dispatch tiles — the gateway's
        edge-backpressure signal (the depth its ``max_fleet_tiles`` bound
        is enforced against)."""
        return sum(rep.pending_tiles for rep in list(self.replicas))

    # -------------------------------------------------------------- retrieve
    def _to_global(self, rep: int, local_results: dict) -> dict:
        return {self._global[rep][loc]: ys
                for loc, ys in local_results.items()}

    def _forget(self, ticket: int) -> None:
        """Drop one claimed ticket's bookkeeping: its routing maps, or —
        for a ticket whose replica was decommissioned — its orphan
        telemetry.  The single forget path shared by the metrics-window
        prune and ``reset_metrics``."""
        rep_loc = self._owner.pop(ticket, None)
        if rep_loc is not None:
            self._global[rep_loc[0]].pop(rep_loc[1], None)
        else:
            self._orphan_records.pop(ticket, None)

    def _note_claimed(self, tickets) -> None:
        self.telemetry.inc("fleet.claims", len(tickets))
        self._claimed.extend(tickets)
        while len(self._claimed) > self.metrics_window:
            self._forget(self._claimed.popleft())

    def _claim_orphan(self, ticket: int):
        """Claim/inspect a ticket whose replica was decommissioned:
        returns its outputs, raises KeyError if already claimed, or
        returns None when the ticket is not an orphan at all."""
        if ticket in self._orphaned:
            self.telemetry.inc("fleet.orphan_claims")
            self._note_claimed([ticket])
            return self._orphaned.pop(ticket)
        if ticket in self._orphan_records:
            # record survives, result gone: it was claimed already
            raise KeyError(f"ticket {ticket} already claimed")
        return None

    def try_result(self, ticket: int):
        """Non-blocking claim across the fleet (see
        ``OverlayServer.try_result``)."""
        if ticket in self._orphaned or ticket in self._orphan_records:
            return self._claim_orphan(ticket)
        if ticket not in self._owner:
            raise KeyError(f"unknown ticket {ticket}")
        rep, loc = self._owner[ticket]
        out = self.replicas[rep].try_result(loc)
        if out is not None:
            self._note_claimed([ticket])
        return out

    def result(self, ticket: int):
        """Block until the ticket's outputs are ready (drives only the
        owning replica's pipeline); one claim per ticket.  A ticket whose
        replica was drained is served from the fleet's orphan store (the
        drain delivered it) or from its adoptive replica (the drain
        evacuated it) — the client never sees the difference."""
        if ticket in self._orphaned or ticket in self._orphan_records:
            return self._claim_orphan(ticket)
        if ticket not in self._owner:
            raise KeyError(f"unknown ticket {ticket}")
        rep, loc = self._owner[ticket]
        out = self.replicas[rep].result(loc)
        self._note_claimed([ticket])
        return out

    def as_completed(self):
        """Yield ``(ticket, outputs)`` in completion order across ALL
        replicas; keeps every replica's pipeline full while iterating
        (observing the autoscaler and rebalancing queued work first) and
        retires rounds fan-in round-robin so no replica's results are
        held back behind another's backlog.  Results orphaned by a
        replica drain are yielded like any other completion."""
        while True:
            yielded = False
            while self._orphaned:
                t, outs = self._orphaned.popitem(last=False)
                self.telemetry.inc("fleet.orphan_claims")
                self._note_claimed([t])
                yielded = True
                yield t, outs
            for rep_id, rep in enumerate(self.replicas):
                while rep._done:
                    loc, outs = rep._done.popitem(last=False)
                    rep._note_claimed([loc])
                    t = self._global[rep_id][loc]
                    self._note_claimed([t])
                    yielded = True
                    yield t, outs
            if yielded:
                continue
            self.autoscale_once()
            self.router.rebalance(self)
            for rep in self.replicas:
                rep._fill_pipeline()
            live = [rep for rep in self.replicas if rep._inflight]
            if not live:
                if self._orphaned:      # a scale-down orphaned results
                    continue
                return
            live[self._rr % len(live)]._retire_oldest()
            self._rr += 1

    def pump_once(self) -> bool:
        """One unit of fleet drain work for ``sched.pump.AutoPump``:
        observe the autoscaler (this tick is how BACKGROUND serving
        scales — including idle ticks, which is where scale-downs come
        from), rebalance queued work (stealing routers), top up every
        replica's pipeline, deliver one round (fan-in round-robin).
        Returns True when any round was delivered or the fleet changed
        size, so the pump keeps ticking through a scaling burst."""
        scaled = self.autoscale_once()
        self.router.rebalance(self)
        for rep in self.replicas:
            rep._fill_pipeline()
        live = [rep for rep in self.replicas if rep._inflight]
        if not live:
            return scaled > 0
        live[self._rr % len(live)]._retire_oldest()
        self._rr += 1
        return True

    def flush(self) -> dict[int, list]:
        """Serve everything queued on every replica; {ticket: outputs}.

        Launches rounds on ALL replicas before blocking on any one of
        them, so the per-device rounds execute concurrently; within each
        replica the usual round pipelining applies.  A stealing router
        rebalances queued work each pass, so an idle replica picks up a
        backlogged replica's queue instead of going dark.  The
        autoscaler is observed once per pass, so the replica set may
        GROW or SHRINK mid-flush: the pass re-reads the fleet after
        every mutation, a drained replica's queued work re-homes through
        the same steal/adopt path, and its delivered results join the
        returned dict via the orphan store — no ticket is lost to a
        resize.
        """
        while True:
            self.autoscale_once()
            self.router.rebalance(self)
            for rep in self.replicas:
                rep._fill_pipeline()
            live = [rep for rep in self.replicas if rep._inflight]
            if not live:
                break
            for rep in live:
                rep._retire_oldest()
        results: dict[int, list] = {}
        for rep_id, rep in enumerate(self.replicas):
            results.update(self._to_global(rep_id, rep.flush()))
        self.telemetry.inc("fleet.orphan_claims", len(self._orphaned))
        results.update(self._orphaned)
        self._orphaned.clear()
        self._note_claimed(results)
        return results

    def flush_sync(self) -> dict[int, list]:
        """Barrier drain, replica by replica — the sharded oracle path
        (no cross-replica overlap, no intra-replica pipelining, no
        stealing, no autoscaling).  Results already orphaned by an
        earlier drain are still returned — the oracle claims everything
        undelivered, it just never mutates the fleet itself."""
        results: dict[int, list] = {}
        for rep_id, rep in enumerate(self.replicas):
            results.update(self._to_global(rep_id, rep.flush_sync()))
        self.telemetry.inc("fleet.orphan_claims", len(self._orphaned))
        results.update(self._orphaned)
        self._orphaned.clear()
        self._note_claimed(results)
        return results

    # --------------------------------------------------------------- metrics
    def record(self, ticket: int) -> dict:
        """Telemetry for one global ticket (adds the serving replica;
        ``replica=None`` for tickets whose replica was decommissioned)."""
        rep_loc = self._owner.get(ticket)
        if rep_loc is None:
            rec = dict(self._orphan_records[ticket])
            rec["replica"] = None
            return rec
        rep, loc = rep_loc
        rec = self.replicas[rep].record(loc)
        rec["replica"] = rep
        return rec

    def latencies(self) -> dict[int, float]:
        out: dict[int, float] = {}
        for rep_id, rep in enumerate(self.replicas):
            for loc, lat in rep.latencies().items():
                t = self._global[rep_id].get(loc)
                if t is not None:
                    out[t] = lat
        for t, rec in self._orphan_records.items():
            if rec["t_done"] is not None:
                out[t] = rec["t_done"] - rec["t_submit"]
        return out

    def latency_percentiles(self, qs=LATENCY_QS) -> dict[str, float]:
        lats = list(self.latencies().values())
        if not lats:
            return {f"p{q}": float("nan") for q in qs}
        return {f"p{q}": float(np.percentile(lats, q)) for q in qs}

    def tenant_latencies(self):
        """Yield ``(tenant, latency_seconds)`` per delivered ticket,
        fleet-wide: every live replica's records plus the orphan records
        of tickets whose replica was decommissioned — a drained replica's
        served traffic still counts against its tenants' SLOs."""
        for rep in self.replicas:
            yield from rep.tenant_latencies()
        for rec in self._orphan_records.values():
            if rec["t_done"] is not None:
                yield rec["tenant"], rec["t_done"] - rec["t_submit"]

    def tenant_latency_percentiles(self, qs=LATENCY_QS) -> dict:
        """Fleet-wide per-tenant p50/p95/p99 + SLO attainment (one source
        of truth shared by the gateway's shed decisions and the benchmark
        tables — see :func:`tenant_latency_summary`)."""
        return tenant_latency_summary(self.tenant_latencies(), qs=qs,
                                      slo_s=self.slo_s)

    def reset_metrics(self) -> None:
        """Drop delivered-ticket telemetry AND routing counters (e.g.
        after a warmup drain) so hit rates reflect steady state."""
        for rep in self.replicas:
            rep.reset_metrics()
        # release the claimed tickets' routing maps too — the replicas
        # just dropped those tickets' records, and leaving entries in
        # _owner/_global would leak them for the server's lifetime
        # (delivered-but-unclaimed tickets are not in _claimed and keep
        # their routing)
        while self._claimed:
            self._forget(self._claimed.popleft())
        self.router.reset_metrics()
        # scaling counters are per-study telemetry like hit rates; the
        # autoscaler's own decision counters reset with them (its control
        # state — streaks, cooldown — is not a metric and survives)
        self.telemetry.reset(names=(
            "fleet.scale_ups", "fleet.scale_downs",
            "fleet.evacuated_requests", "fleet.evacuated_tiles"))
        if self.autoscaler is not None:
            self.autoscaler.reset_metrics()

    def stats(self) -> dict:
        per = [rep.stats() for rep in self.replicas]
        # rounds/requests aggregate from the SHARED sink, not the live
        # replicas: every replica writes through MultiSink(own, fleet),
        # so work served by since-drained replicas is already in there
        s = {"replicas": self.n_replicas,
             "submits": self.n_submits,
             "pending": self.pending,
             "queue_depth": [p["queued"] for p in per],
             "queued_tiles": [p["queued_tiles"] for p in per],
             "per_replica": per,
             "rounds": int(self.telemetry.counter("engine.rounds")),
             "requests": int(self.telemetry.counter("engine.delivered")),
             "evictions": (sum(p["evictions"] for p in per)
                           + int(self.telemetry.counter(
                               "fleet.retired_evictions"))),
             "scale_ups": self.n_scale_ups,
             "scale_downs": self.n_scale_downs,
             "evacuated_requests": self.n_evacuated_requests,
             "evacuated_tiles": self.n_evacuated_tiles,
             "replicas_retired": self.n_replicas_retired,
             "retired_lifetime_s": self.retired_lifetime_s,
             "peak_replicas": self.peak_replicas,
             "orphaned_results": len(self._orphaned),
             "orphan_claims": int(
                 self.telemetry.counter("fleet.orphan_claims")),
             "claims": int(self.telemetry.counter("fleet.claims")),
             # replicas write through MultiSink(own, fleet), so these
             # walls aggregate the whole fleet incl. drained replicas
             "stage_walls": _stage_walls(self.telemetry),
             "tenant_latency": self.tenant_latency_percentiles()}
        s.update(self.router.stats())
        if self.autoscaler is not None:
            s.update(self.autoscaler.stats())
        return s


def overlay_demo(argv_ns) -> int:
    """Mixed-kernel serving demo over the paper's Table II benchmark set.

    Default mode drains with the pipelined ``flush``; ``--stream`` submits
    per-tenant and consumes ``as_completed`` to show completion-order
    delivery plus per-tenant latency percentiles.  ``--policy`` swaps the
    round-formation policy (see repro.sched.rounds).
    """
    from repro.core.overlay import compile_program
    from repro.core.paper_bench import BENCH_NAMES, benchmark
    from repro.core.vm import dfg_eval

    names = list(BENCH_NAMES) + ["gradient"]
    kernels = {n: compile_program(benchmark(n)) for n in names}
    srv = OverlayServer(bank_capacity=argv_ns.bank, tile=argv_ns.tile,
                        backend=argv_ns.backend,
                        round_kernels=max(1, argv_ns.bank // 2),
                        round_policy=argv_ns.policy)
    rng = np.random.RandomState(0)
    reqs = []
    for i in range(argv_ns.requests):
        k = kernels[names[i % len(names)]]
        xs = [rng.uniform(-2, 2, (argv_ns.req_batch,)).astype(np.float32)
              for _ in k.dfg.inputs]
        tenant = f"tenant{i % argv_ns.tenants}"
        reqs.append((srv.submit(k, xs, tenant=tenant), k, xs, tenant))
    srv.flush()  # warmup (compiles the executor buckets)
    srv.reset_metrics()
    for _, k, xs, tenant in reqs:
        srv.submit(k, xs, tenant=tenant)
    t0 = time.perf_counter()
    if argv_ns.stream:
        results = {}
        for ticket, outs in srv.as_completed():
            results[ticket] = outs
    else:
        results = srv.flush()
    jax.block_until_ready(list(results.values()))
    dt = time.perf_counter() - t0
    # verify a sample against the DFG oracle
    _, k, xs, _ = reqs[-1]
    ref = dfg_eval(k.dfg, {n: jnp.asarray(v)
                           for n, v in zip(k.dfg.inputs, xs)})
    np.testing.assert_allclose(np.asarray(results[max(results)][0]),
                               np.asarray(ref[k.dfg.outputs[0]]),
                               rtol=1e-5, atol=1e-5)
    st = srv.stats()
    pct = {k_: f"{v * 1e3:.2f}ms"
           for k_, v in srv.latency_percentiles().items()}
    mode = "as_completed stream" if argv_ns.stream else "pipelined flush"
    print(f"served {len(reqs)} mixed requests over {len(names)} kernels "
          f"x {argv_ns.tenants} tenants (bank={argv_ns.bank}, {mode}, "
          f"policy={st['round_policy']}) "
          f"in {dt * 1e3:.1f} ms = {len(reqs) / dt:,.0f} req/s")
    print(f"delivery latency percentiles: {pct}")
    print(f"server stats: {st}")
    return 0


def main(argv=None):
    from repro.sched.rounds import ROUND_POLICIES
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--overlay-demo", action="store_true",
                    help="serve mixed overlay kernels from a ContextBank")
    ap.add_argument("--bank", type=int, default=4,
                    help="context-bank capacity for --overlay-demo")
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"))
    ap.add_argument("--policy", default=None,
                    choices=sorted(ROUND_POLICIES),
                    help="round-formation policy for --overlay-demo "
                         "(default: REPRO_ROUND_POLICY env or drr)")
    ap.add_argument("--requests", type=int, default=36)
    ap.add_argument("--req-batch", type=int, default=256)
    ap.add_argument("--tenants", type=int, default=3,
                    help="tenant labels round-robined over --overlay-demo "
                         "requests")
    ap.add_argument("--stream", action="store_true",
                    help="consume results via as_completed instead of flush")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    if args.overlay_demo:
        return overlay_demo(args)
    if args.arch is None:
        ap.error("--arch is required unless --overlay-demo is given")

    from repro.configs import get_config, get_smoke_config
    from repro.models import init_params
    from repro.runtime.steps import make_decode_step, make_prefill_step

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, G = args.batch, args.prompt_len, args.gen
    cache_len = S + G + cfg.vision_tokens
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg.vocab, jnp.int32)
    batch = {"tokens": prompts}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.zeros(
            (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder is not None:
        batch["frame_embeds"] = jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for i in range(G - 1):
        pos = jnp.asarray(S + cfg.vision_tokens + i, jnp.int32)
        _, tok, caches = decode(params, caches, tok, pos)
        tok = tok[:, None]
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0
    gen = np.asarray(jnp.concatenate(out, 1))
    print(f"prefill: {B}x{S} in {t_prefill * 1e3:.1f} ms "
          f"({B * S / t_prefill:,.0f} tok/s)")
    print(f"decode:  {G - 1} steps in {t_decode * 1e3:.1f} ms "
          f"({B * (G - 1) / max(t_decode, 1e-9):,.0f} tok/s)")
    print("sample token ids:", gen[0][:12].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
