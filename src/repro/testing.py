"""Property-testing compat layer: hypothesis when available, else fallback.

The test suite uses a small subset of the hypothesis API (``given``,
``settings``, ``strategies.integers/sampled_from/data``).  Hypothesis is an
*optional* dev dependency (see requirements-dev.txt): when it is installed
this module re-exports the real thing; otherwise a deterministic
seeded-random fallback with the same call surface runs a fixed number of
examples per test, so the tier-1 suite collects and runs everywhere.
"""

from __future__ import annotations

import functools
import random

try:  # pragma: no cover - exercised implicitly when hypothesis is present
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 25

    class _Strategy:
        """A value source drawing from a seeded ``random.Random``."""

        def __init__(self, draw):
            self._draw = draw

        def example_with(self, rng: random.Random):
            return self._draw(rng)

    class _DataObject:
        """Runtime stand-in for hypothesis' interactive ``data()`` draws."""

        def __init__(self, rng: random.Random):
            self._rng = rng

        def draw(self, strategy: _Strategy):
            return strategy.example_with(self._rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            pool = list(seq)
            return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

        @staticmethod
        def data() -> _Strategy:
            return _Strategy(lambda rng: _DataObject(rng))

    st = _Strategies()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        """Outer decorator: records the example budget on the runner."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            # NB: no functools.wraps — copying fn's signature would make
            # pytest treat the drawn parameters as fixtures.
            def runner(*args, **kwargs):
                n = getattr(runner, "_max_examples", _DEFAULT_EXAMPLES)
                for i in range(n):
                    rng = random.Random(0xC0FFEE ^ (i * 0x9E3779B9))
                    drawn = [s.example_with(rng) for s in arg_strats]
                    drawn_kw = {k: s.example_with(rng)
                                for k, s in kw_strats.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner.hypothesis_fallback = True
            return runner

        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
