"""Elastic fleet autoscaling: grow/drain serving replicas under load.

The paper's area result comes from refusing to provision one FU per
operation: a small pool of time-multiplexed FUs absorbs the whole kernel
because switching is cheap.  A serving fleet provisioned for peak makes
the same mistake one level up — N device-pinned replicas stay alive
through every lull.  An :class:`AutoscalePolicy` closes the loop: it
watches the fleet's queue pressure and tells the shell
(``launch.serve.ShardedOverlayServer``) when to ``add_replica()`` and
when to ``drain_replica(i)``, so the replica count tracks offered load
the way the overlay's FU count tracks the DFG, not the op count.

The policy only DECIDES; the shell owns the mechanics (construct a
replica on the least-shared device, evacuate a draining replica's queued
work over the steal/adopt path, retire its in-flight rounds, unpublish
its directory entries).  Decisions are observed from every drain loop —
``flush`` passes, ``as_completed`` passes, and the ``sched.pump.AutoPump``
tick — so scaling happens both under an explicit drain and in background
serving.  ``flush_sync`` never scales: it stays the bit-for-bit oracle.

:class:`PressureAutoscaler` is the shipped policy — hysteresis on queue
pressure with a cooldown, the classic control shape:

* **up** when the fleet's mean queued tiles per replica has exceeded
  ``up_tiles`` for ``up_rounds`` CONSECUTIVE observations (a one-round
  blip never pays a replica construction);
* **down** when some replica has had zero pending tiles (nothing queued,
  nothing in flight) for ``down_rounds`` consecutive observations — the
  longest-idle replica drains first;
* at most one action per observation, at least ``cooldown_s`` seconds
  (on an injectable ``clock``) between actions, and the replica count
  clamped to ``[min_replicas, max_replicas]``.

See docs/SCHEDULING.md#autoscaling for knobs, the drain lifecycle, and
the custom-policy guide; ``benchmarks/multi_tenant.py --autoscale`` for
the bursty-arrival study the hysteresis defaults were shaped on.
"""

from __future__ import annotations

import time
from typing import Protocol, runtime_checkable

#: an autoscale decision: ("up", None) or ("down", replica_index)
Action = tuple


@runtime_checkable
class AutoscalePolicy(Protocol):
    """What the sharded shell needs from an autoscaling policy.

    ``observe`` is called once per drain pass / pump tick with the fleet
    (``ShardedOverlayServer``) and returns the actions to apply NOW —
    ``("up", None)`` to add a replica, ``("down", i)`` to drain replica
    ``i`` — or an empty list.  The shell applies them immediately via
    ``add_replica``/``drain_replica`` and re-checks its own invariants
    (it never drains the last replica), so a policy bug degrades to a
    no-op, not a lost ticket.
    """

    def observe(self, fleet) -> list[Action]: ...

    def stats(self) -> dict: ...

    def reset_metrics(self) -> None: ...


class PressureAutoscaler:
    """Hysteresis-with-cooldown autoscaling on fleet queue pressure.

    Scale-up pressure is the fleet-wide MEAN queued tiles per replica
    (``OverlayServer.queued_tiles``): queued-only work is what another
    replica could actually absorb (in-flight rounds are committed to
    their device), and the mean keeps the threshold meaningful as the
    fleet grows — the same backlog over twice the replicas is half the
    pressure.  Scale-down watches ``pending_tiles`` (queued AND in
    flight): a replica is only idle when nothing it owns is undelivered.

    Both directions require the condition to hold for a consecutive run
    of observations (``up_rounds`` / ``down_rounds``) — the hysteresis —
    and every applied action arms a shared ``cooldown_s`` timer, so the
    fleet cannot thrash grow/drain around a threshold.  An observation
    that breaks the run resets its streak to zero.

    The clock is injectable (tests drive cooldown deterministically);
    per-replica idle streaks are keyed on the replica OBJECT, so index
    compaction after a drain cannot misattribute another replica's
    history.
    """

    def __init__(self, up_tiles: float = 32.0, up_rounds: int = 3,
                 down_rounds: int = 8, cooldown_s: float = 0.0,
                 min_replicas: int = 1, max_replicas: int = 8,
                 clock=time.monotonic, telemetry=None):
        if up_tiles <= 0:
            raise ValueError(f"up_tiles must be > 0, got {up_tiles}")
        if up_rounds < 1 or down_rounds < 1:
            raise ValueError(
                f"up_rounds/down_rounds must be >= 1, got "
                f"{up_rounds}/{down_rounds}")
        if cooldown_s < 0:
            raise ValueError(f"cooldown_s must be >= 0, got {cooldown_s}")
        if not 1 <= min_replicas <= max_replicas:
            raise ValueError(
                f"need 1 <= min_replicas <= max_replicas, got "
                f"{min_replicas}/{max_replicas}")
        self.up_tiles = up_tiles
        self.up_rounds = up_rounds
        self.down_rounds = down_rounds
        self.cooldown_s = cooldown_s
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.clock = clock
        self._hot_streak = 0
        self._idle: dict = {}           # replica object -> idle-obs streak
        self._last_action: float | None = None
        self._last_n = 0                # fleet size at last observation
        from repro.telemetry import InMemorySink
        #: structured sink the decision counters live in; an attaching
        #: fleet re-binds this to its shared sink (see repro.telemetry)
        self.telemetry = (telemetry if telemetry is not None
                          else InMemorySink(clock=clock))

    @property
    def n_observations(self) -> int:
        return int(self.telemetry.counter("autoscaler.observations"))

    @property
    def n_up_decisions(self) -> int:
        return int(self.telemetry.counter("autoscaler.up_decisions"))

    @property
    def n_down_decisions(self) -> int:
        return int(self.telemetry.counter("autoscaler.down_decisions"))

    @property
    def n_saturated_observations(self) -> int:
        return int(self.telemetry.counter(
            "autoscaler.saturated_observations"))

    # ------------------------------------------------------- edge coupling
    @property
    def scale_up_pending(self) -> bool:
        """Pressure has been observed and the fleet can still grow.

        The serving gateway's backpressure coupling reads this: capacity
        is (probably) coming, so the edge should WIDEN its admission
        window — queue a little more instead of shedding — and revert
        the widening once the scale-up lands (the hot streak resets to
        zero on the ``up`` decision, so this flips back automatically).
        """
        return self._hot_streak >= 1 and self._last_n < self.max_replicas

    @property
    def saturated(self) -> bool:
        """The policy wants to grow but the fleet is at ``max_replicas``.

        No more capacity is coming: the edge must shed (or park work in
        its own queue) instead of pushing depth into the fleet.  True
        when the hot streak has fully ripened (>= ``up_rounds``) while
        the fleet sits at its ceiling — exactly the state in which
        ``observe`` would have returned ``("up", None)`` but could not.
        """
        return (self._hot_streak >= self.up_rounds
                and self._last_n >= self.max_replicas)

    # ------------------------------------------------------------- observe
    def observe(self, fleet) -> list[Action]:
        replicas = list(fleet.replicas)
        n = len(replicas)
        self._last_n = n
        self.telemetry.inc("autoscaler.observations")
        # streaks update on EVERY observation — the cooldown gates actions,
        # not evidence, so pressure seen during cooldown still counts
        pressure = sum(rep.queued_tiles for rep in replicas) / max(1, n)
        self._hot_streak = self._hot_streak + 1 if pressure >= self.up_tiles \
            else 0
        live = set(id(rep) for rep in replicas)
        self._idle = {r: c for r, c in self._idle.items()
                      if id(r) in live}
        for rep in replicas:
            self._idle[rep] = (self._idle.get(rep, 0) + 1
                               if rep.pending_tiles == 0 else 0)
        if self.saturated:
            self.telemetry.inc("autoscaler.saturated_observations")
        if (self._last_action is not None
                and self.clock() - self._last_action < self.cooldown_s):
            return []
        if self._hot_streak >= self.up_rounds and n < self.max_replicas:
            self._hot_streak = 0
            self._last_action = self.clock()
            self.telemetry.inc("autoscaler.up_decisions")
            self.telemetry.event("autoscale_up", pressure=pressure, fleet=n)
            return [("up", None)]
        if n > self.min_replicas:
            ripe = [(self._idle.get(rep, 0), i)
                    for i, rep in enumerate(replicas)
                    if self._idle.get(rep, 0) >= self.down_rounds]
            if ripe:
                _, i = max(ripe)
                self._idle.pop(replicas[i], None)
                self._last_action = self.clock()
                self.telemetry.inc("autoscaler.down_decisions")
                self.telemetry.event("autoscale_down", replica=i, fleet=n)
                return [("down", i)]
        return []

    # ------------------------------------------------------------- metrics
    def stats(self) -> dict:
        return {"autoscaler": type(self).__name__,
                "up_tiles": self.up_tiles,
                "up_rounds": self.up_rounds,
                "down_rounds": self.down_rounds,
                "cooldown_s": self.cooldown_s,
                "min_replicas": self.min_replicas,
                "max_replicas": self.max_replicas,
                "observations": self.n_observations,
                "up_decisions": self.n_up_decisions,
                "down_decisions": self.n_down_decisions,
                "hot_streak": self._hot_streak,
                "scale_up_pending": self.scale_up_pending,
                "saturated": self.saturated,
                "saturated_observations": self.n_saturated_observations}

    def reset_metrics(self) -> None:
        """Drop decision counters; streaks and the cooldown timer are
        control state, not metrics, and are kept."""
        self.telemetry.reset(prefix="autoscaler.")
