"""Pluggable scheduling subsystem for the overlay serving engines.

The paper's core trade is time-multiplexing one FU array across kernels
via cheap context switches; the serving engines make the analogous trade
in software.  This package separates every scheduling DECISION from the
engine MECHANICS (``launch.serve`` keeps the staged pipeline, pinning,
ticket bookkeeping), the way JIT-assembly overlays separate the compute
fabric from placement policy:

* :mod:`repro.sched.admission` — per-tenant token-bucket admission
  (``TokenBucket``, ``AdmissionControl``, ``AdmissionError``);
* :mod:`repro.sched.autoscale` — elastic fleet sizing
  (``AutoscalePolicy`` protocol: ``PressureAutoscaler`` grows/drains
  replicas from observed queue pressure);
* :mod:`repro.sched.rounds` — round formation (``RoundPolicy`` protocol:
  ``DeficitRoundRobin``, ``CoalescingPolicy``, ``DynamicTilePolicy``);
* :mod:`repro.sched.routing` — replica selection for the sharded fleet
  (``RouterPolicy`` protocol: ``ResidencyRouter``, ``WorkStealingRouter``);
* :mod:`repro.sched.pump` — ``AutoPump``, a background drain thread so
  concurrent ``submit`` makes progress without an explicit ``flush``.

See docs/SCHEDULING.md for the policy-author guide.
"""

from repro.sched.admission import (AdmissionControl, AdmissionError,
                                   TokenBucket)
from repro.sched.autoscale import AutoscalePolicy, PressureAutoscaler
from repro.sched.preempt import BULK_PREFIX, PreemptibleTier
from repro.sched.pump import AutoPump
from repro.sched.rounds import (ROUND_POLICIES, CoalescingPolicy,
                                DeficitRoundRobin, DynamicTilePolicy, Flow,
                                OverlayRequest, RoundPolicy, WorkRequest,
                                make_round_policy)
from repro.sched.routing import (ResidencyRouter, RouterPolicy,
                                 WorkStealingRouter, make_router)

__all__ = [
    "AdmissionControl", "AdmissionError", "TokenBucket",
    "AutoscalePolicy", "PressureAutoscaler",
    "AutoPump",
    "BULK_PREFIX", "PreemptibleTier",
    "ROUND_POLICIES", "RoundPolicy", "DeficitRoundRobin",
    "CoalescingPolicy", "DynamicTilePolicy", "Flow", "OverlayRequest",
    "WorkRequest", "make_round_policy",
    "RouterPolicy", "ResidencyRouter", "WorkStealingRouter", "make_router",
]
