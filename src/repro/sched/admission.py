"""Per-tenant token-bucket admission control.

Extracted from ``launch.serve`` so admission is a scheduling policy like
round formation and routing, not engine plumbing.  ``OverlayServer``
applies one :class:`AdmissionControl` per engine; the sharded fleet
applies one GLOBALLY (in the router layer), so a tenant cannot dodge its
rate by having its kernels land on different replicas.  Token costs are
dispatch tiles (``ceil(batch / tile)``) — see docs/SERVING.md.
"""

from __future__ import annotations

import math
import time


class AdmissionError(RuntimeError):
    """A tenant exceeded its token-bucket rate.

    ``retry_after`` is the seconds until the request would be admitted —
    ``math.inf`` when the request's cost exceeds the bucket's burst, i.e.
    it can NEVER be admitted under the current policy (don't retry it;
    split the request or raise the tenant's burst).
    """

    def __init__(self, tenant: str, retry_after: float):
        if math.isinf(retry_after):
            msg = (f"tenant {tenant!r}: request cost exceeds the bucket "
                   f"burst; it can never be admitted under this policy")
        else:
            msg = (f"tenant {tenant!r} over admission rate; "
                   f"retry in {retry_after:.3f}s")
        super().__init__(msg)
        self.tenant = tenant
        self.retry_after = retry_after


class TokenBucket:
    """Token-bucket rate limiter (tokens = dispatch tiles, see SERVING.md).

    ``rate`` tokens accrue per second up to ``burst``; ``try_acquire``
    spends tokens if available.  The clock is injectable so tests can
    advance time deterministically.
    """

    def __init__(self, rate: float, burst: float | None = None,
                 clock=time.monotonic):
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.burst = float(burst if burst is not None else rate)
        self.tokens = self.burst
        self.clock = clock
        self._t = clock()

    def _refill(self) -> None:
        now = self.clock()
        self.tokens = min(self.burst, self.tokens + (now - self._t) * self.rate)
        self._t = now

    def try_acquire(self, cost: float = 1.0) -> bool:
        self._refill()
        if self.tokens >= cost:
            self.tokens -= cost
            return True
        return False

    def retry_after(self, cost: float = 1.0) -> float:
        """Seconds until ``cost`` tokens will be available."""
        self._refill()
        return max(0.0, (cost - self.tokens) / self.rate)


class AdmissionControl:
    """Per-tenant token-bucket admission for one serving front-end.

    ``admission`` maps tenant -> TokenBucket (or a ``(rate, burst)`` spec);
    ``default_admission`` is applied lazily to tenants without an explicit
    bucket.  Shared by ``OverlayServer`` (single bank) and
    ``ShardedOverlayServer`` (where admission must span all replicas — a
    tenant cannot dodge its rate by having its kernels land on different
    replicas, so the buckets live in the router, not per replica).
    """

    #: bucket-count high-water mark before lazily-created default buckets
    #: are pruned — an unbounded tenant-label space must not leak buckets
    MAX_BUCKETS = 4096

    def __init__(self, admission: dict | None = None,
                 default_admission: tuple | None = None,
                 clock=time.monotonic):
        self.clock = clock
        self._buckets: dict[str, TokenBucket] = {}
        for tenant, spec in (admission or {}).items():
            self._buckets[tenant] = (spec if isinstance(spec, TokenBucket)
                                     else TokenBucket(*spec, clock=clock))
        self.default_admission = default_admission
        self._default_buckets: set[str] = set()
        #: admission WINDOW: a multiplicative widening of every bucket,
        #: applied at admit time (effective cost = cost / window).  The
        #: serving gateway raises it above 1.0 while the autoscaler has a
        #: scale-up pending — capacity is coming, so the edge may admit
        #: more than steady-state rate — and reverts it to 1.0 when the
        #: scale-up lands (see launch/gateway.py).  Bucket state is
        #: untouched, so reverting is instant and carries no debt.
        self.window = 1.0

    def set_window(self, window: float) -> None:
        """Set the admission window (1.0 = nominal; > 1 admits more)."""
        if window <= 0:
            raise ValueError(f"window must be > 0, got {window}")
        self.window = float(window)

    def admit(self, tenant: str, cost: float) -> None:
        """Spend ``cost`` tokens from the tenant's bucket or raise
        :class:`AdmissionError`; tenants with no bucket (and no default
        policy) are always admitted.  ``cost`` is scaled by the current
        admission ``window`` before it meets the bucket."""
        cost = cost / self.window
        bucket = self._buckets.get(tenant)
        if bucket is None and self.default_admission is not None:
            bucket = TokenBucket(*self.default_admission, clock=self.clock)
            self._buckets[tenant] = bucket
            self._default_buckets.add(tenant)
            if len(self._buckets) > self.MAX_BUCKETS:
                # a refilled-to-burst default bucket carries no state
                for t in list(self._default_buckets):
                    b = self._buckets[t]
                    b._refill()
                    if t != tenant and b.tokens >= b.burst:
                        del self._buckets[t]
                        self._default_buckets.discard(t)
        if bucket is not None and not bucket.try_acquire(cost):
            retry = (math.inf if cost > bucket.burst
                     else bucket.retry_after(cost))
            raise AdmissionError(tenant, retry)
