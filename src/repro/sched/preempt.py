"""Preemptible bulk tier: latency traffic provably starves bulk work.

The paper's overlay keeps ONE FU pipeline busy by time-multiplexing it
across kernels; PR 7's ``tenant_quanta`` made the software analogue's
shares tunable, but a quantum only PACES a backlogged tenant — a bulk
flow with credit still lands its tiles in the same round as latency
traffic.  Co-scheduling training under serving needs a harder promise:
bulk work may only occupy round slots the latency tier left idle.

:class:`PreemptibleTier` wraps ANY :class:`~repro.sched.rounds.RoundPolicy`
(so the same guarantee holds under ``drr``/``coalesce``/``dynamic``) and
adds exactly one decision on top:

* if any LATENCY flow has queued work, the round is formed from the
  latency flows alone (the inner policy sees only them — its pacing,
  coalescing, and tile budgeting apply unchanged within the tier);
* only when every latency flow is idle does the bulk tier get a round,
  again formed by the inner policy over the bulk flows alone.

Tiers never mix in one round, which is what makes the starvation bound
STRUCTURAL rather than statistical: a saturated latency tier drives the
bulk tier's throughput to exactly zero (``n_bulk_rounds`` stays flat),
while a saturated bulk tier cannot delay a latency arrival by more than
the one bulk round already in flight.  Preemption GRANULARITY on the
work inside a bulk round is the submitter's job — see
``launch.trainer_tenant.TrainingTenant``, which slices training into
micro-rounds and checks for latency arrivals between micro-steps (the
yield-point contract in docs/SCHEDULING.md).

A tenant is bulk when its name is in ``bulk_tenants`` or starts with
``bulk_prefix`` (default ``"bulk:"`` — the convention the training
tenant and the SLO study both follow).
"""

from __future__ import annotations

from collections import deque

from repro.sched.rounds import Flow, make_round_policy

#: tenant-name prefix that marks a flow as bulk-tier by convention
BULK_PREFIX = "bulk:"


class PreemptibleTier:
    """Two-tier round formation: bulk flows only run when latency is idle.

    ``inner`` is the policy that forms rounds WITHIN a tier — an
    instance, a registered name (``"drr"``/``"coalesce"``/``"dynamic"``),
    or None for the ``REPRO_ROUND_POLICY``/default resolution.  All
    inner-policy state (deficits, AIMD budgets, coalescing) behaves as
    if each tier were its own engine.

    ``tenant_quanta`` on the inner policy still applies within the bulk
    tier, bounding training's share against OTHER bulk tenants; across
    tiers no quantum is needed — the tier split is absolute.
    """

    def __init__(self, inner=None, *, bulk_tenants=(),
                 bulk_prefix: str = BULK_PREFIX,
                 quantum_tiles: float | None = None):
        if inner is None or isinstance(inner, str):
            inner = make_round_policy(inner, quantum_tiles=quantum_tiles)
        elif quantum_tiles is not None:
            raise ValueError(
                "quantum_tiles was given alongside an inner policy "
                "instance; set the quantum on the policy itself")
        if isinstance(inner, PreemptibleTier):
            raise ValueError("PreemptibleTier cannot wrap itself")
        self.inner = inner
        self.bulk_tenants = set(bulk_tenants)
        self.bulk_prefix = bulk_prefix
        #: rounds formed per tier (the starvation test's structural probe)
        self.n_latency_rounds = 0
        self.n_bulk_rounds = 0

    def add_bulk(self, tenants) -> None:
        """Mark more tenants as bulk-tier (idempotent)."""
        self.bulk_tenants.update(tenants)

    def is_bulk(self, tenant: str) -> bool:
        return (tenant in self.bulk_tenants
                or str(tenant).startswith(self.bulk_prefix))

    # ------------------------------------------------------------ policy API
    def _tier_round(self, rr: deque, round_kernels: int,
                    tier: dict[str, Flow]) -> list | None:
        """Form one round from ``tier``'s flows via the inner policy.

        The inner policy sees a tier-local service order and rotates it;
        the OUTER ``rr`` is rotated here so cross-round fairness within
        a tier advances exactly as it would without the wrapper.
        """
        sub_rr = deque(t for t in rr if t in tier)
        reqs = self.inner.form_round(tier, sub_rr, round_kernels)
        rr.rotate(-1)
        return reqs

    def form_round(self, flows: dict[str, Flow], rr: deque,
                   round_kernels: int) -> list | None:
        if not flows:
            return None
        latency = {t: f for t, f in flows.items()
                   if not self.is_bulk(t) and f.queue}
        if latency:
            self.n_latency_rounds += 1
            return self._tier_round(rr, round_kernels, latency)
        bulk = {t: f for t, f in flows.items()
                if self.is_bulk(t) and f.queue}
        if not bulk:
            return None
        self.n_bulk_rounds += 1
        return self._tier_round(rr, round_kernels, bulk)

    def observe(self, n_tiles: int, wall_s: float) -> None:
        self.inner.observe(n_tiles, wall_s)

    # -------------------------------------------------------------- metrics
    def quantum_for(self, tenant: str):
        """Delegate SLO-class lookups to the inner policy (present on the
        DRR family; absent inner policies report None)."""
        fn = getattr(self.inner, "quantum_for", None)
        return fn(tenant) if fn is not None else None

    def stats(self) -> dict:
        return {"tier_policy": type(self.inner).__name__,
                "bulk_tenants": sorted(self.bulk_tenants),
                "bulk_prefix": self.bulk_prefix,
                "latency_rounds": self.n_latency_rounds,
                "bulk_rounds": self.n_bulk_rounds}
