"""Replica-selection policies for the sharded serving fleet.

``launch.serve.ShardedOverlayServer`` owns the replicas (one
``OverlayServer`` + ``ContextBank`` per device) and the global
ticket/delivery bookkeeping; a :class:`RouterPolicy` owns the placement
DECISIONS: which replica serves a submit (``route``) and whether queued
work should move between replicas while draining (``rebalance``).

* :class:`ResidencyRouter` — the original residency-affinity router,
  extracted from the engine: directory-validated residency hits,
  least-loaded fallback on miss/stale, hot-context migration with
  hysteresis + cooldown.  ``rebalance`` is a no-op: residency-only
  routing never moves queued work.
* :class:`WorkStealingRouter` — same routing, plus cross-replica work
  stealing at drain time: an idle replica (no queued tiles) pulls whole
  queued kernel-groups from the most-backlogged replica.  The context is
  prefetched on the thief BEFORE the group moves (a thief whose bank is
  momentarily all pinned skips the steal — pin-safety is preserved, only
  QUEUED requests ever move, never in-flight rounds), and the directory
  entry is republished to the thief so follow-up traffic lands there.

The unit of stealing is the kernel-group (every queued request sharing
one context key) because the context is the unit of residency: moving a
whole group costs ONE context load on the thief and keeps the per-launch
batching intact.  A backlog that is a single giant group cannot be split
by this router — that is the paper's trade restated: work moves at
context granularity, not instruction granularity.

See docs/SCHEDULING.md#routing for knobs and the stealing study.
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable


@runtime_checkable
class RouterPolicy(Protocol):
    """What the sharded engine needs from a routing policy.

    ``fleet`` is the ``ShardedOverlayServer`` (replicas, banks, adoption
    hooks).  ``route`` returns the replica index that should enqueue the
    submit; ``rebalance`` may move queued requests between replicas (via
    ``fleet.move_group``, the shared steal/evacuation sequence) and
    returns how many groups moved.
    """

    def route(self, kernel, fleet) -> int: ...

    def rebalance(self, fleet) -> int: ...

    def stats(self) -> dict: ...

    def reset_metrics(self) -> None: ...


class ResidencyRouter:
    """Residency-affinity routing over a shared ``BankDirectory``.

    Routing policy (extracted verbatim from the pre-sched engine):

    1. a directory entry validated against the owning bank's residency
       generation routes the request to the replica already holding its
       context — a residency HIT;
    2. a miss/stale entry falls back to the least-loaded replica (by
       pending tiles), prefetches the context there, and publishes the
       new residency;
    3. when the owner is hot (pending tiles >= ``migrate_factor`` x the
       coolest replica's, by at least ``migrate_min_tiles``) the context
       is re-homed to the coolest replica; ``migrate_cooldown`` (routed
       submits per key) stops a globally-hot key from thrashing.
    """

    def __init__(self, directory=None, migrate_factor: float = 4.0,
                 migrate_min_tiles: int = 16, migrate_cooldown: int = 32,
                 telemetry=None):
        from repro.core.bank import BankDirectory
        from repro.telemetry import InMemorySink
        if migrate_factor < 1:
            raise ValueError(
                f"migrate_factor must be >= 1, got {migrate_factor}")
        self.directory = directory if directory is not None else BankDirectory()
        self.migrate_factor = migrate_factor
        self.migrate_min_tiles = migrate_min_tiles
        self.migrate_cooldown = migrate_cooldown
        self._migrated_at: dict[tuple, int] = {}
        self.n_routed = 0           # cooldown clock: routed submits —
        #                             control state, NOT a metric (resets
        #                             would warp migration cooldowns)
        #: structured sink the routing counters live in; the fleet
        #: re-binds this to its shared sink (see repro.telemetry)
        self.telemetry = telemetry if telemetry is not None else InMemorySink()

    @property
    def n_hits(self) -> int:
        return int(self.telemetry.counter("router.hits"))

    @property
    def n_misses(self) -> int:
        return int(self.telemetry.counter("router.misses"))

    @property
    def n_migrations(self) -> int:
        return int(self.telemetry.counter("router.migrations"))

    # ------------------------------------------------------------- route
    def route(self, kernel, fleet) -> int:
        """Pick the serving replica for one request (see class docstring)."""
        from repro.core.bank import BankError, context_key
        replicas = fleet.replicas
        banks = fleet.banks
        loads = [rep.pending_tiles for rep in replicas]
        coolest = min(range(len(replicas)), key=loads.__getitem__)
        owner = self.directory.locate(kernel, banks)
        if owner is not None:
            hot = (owner != coolest
                   and loads[owner] - loads[coolest] >= self.migrate_min_tiles
                   and loads[owner] >= self.migrate_factor
                   * max(loads[coolest], 1))
            key = context_key(kernel.program)
            last = self._migrated_at.get(key)
            cooled = (last is None
                      or self.n_routed - last >= self.migrate_cooldown)
            if not (hot and cooled):
                self.telemetry.inc("router.hits")
                self.n_routed += 1
                return owner
            target = coolest
            self._migrated_at[key] = self.n_routed
            self.telemetry.inc("router.migrations")
            self.telemetry.event("migrate", key=repr(key), frm=owner,
                                 to=coolest)
        else:
            self.telemetry.inc("router.misses")
            target = coolest
        # warm the context on its new home and publish the residency; a
        # momentarily all-pinned bank defers the load to the replica's own
        # round plan (which retires rounds until it fits)
        try:
            replicas[target].bank.prefetch([kernel])
            self.directory.publish_current(kernel, target,
                                           replicas[target].bank)
        except BankError:
            self.directory.drop(kernel)
        self.n_routed += 1
        return target

    # --------------------------------------------------------- rebalance
    def rebalance(self, fleet) -> int:
        """Residency-only routing never moves queued work."""
        return 0

    # ----------------------------------------------------------- metrics
    @property
    def hit_rate(self) -> float:
        """Routed-to-resident-replica fraction (stale hits count as
        misses); NaN before any routing decision."""
        n = self.n_hits + self.n_misses
        return self.n_hits / n if n else float("nan")

    def stats(self) -> dict:
        return {"router": type(self).__name__,
                "route_hits": self.n_hits,
                "route_misses": self.n_misses,
                "residency_hit_rate": self.hit_rate,
                "migrations": self.n_migrations,
                "steals": 0,
                "directory": self.directory.stats()}

    def reset_metrics(self) -> None:
        self.telemetry.reset(names=("router.hits", "router.misses",
                                    "router.migrations"))
        d = self.directory
        d.n_fresh = d.n_stale = d.n_unknown = 0
        d.n_republished = d.n_unpublished = 0


class WorkStealingRouter(ResidencyRouter):
    """Residency routing + idle-replica work stealing at drain time.

    ``rebalance`` (called by the fleet's drain loops and the autopump)
    repeatedly moves the most-backlogged replica's largest queued
    kernel-group to an idle replica while:

    * some replica has zero queued tiles (the thief),
    * the victim's queued backlog is at least ``steal_min_tiles``, and
    * the victim holds >= 2 distinct queued groups OR the group is small
      enough (<= half the backlog) that moving it actually balances —
      relocating a lone monolithic group would only churn residency.

    The steal sequence preserves every engine invariant: the thief's bank
    prefetches the context FIRST (failure = skip, never a broken round),
    only queued requests move (in-flight rounds and their pins are
    untouched), per-tenant arrival order is preserved on the thief, and
    the directory is republished so follow-up submits chase the work.
    """

    def __init__(self, directory=None, migrate_factor: float = 4.0,
                 migrate_min_tiles: int = 16, migrate_cooldown: int = 32,
                 steal_min_tiles: int = 4, telemetry=None):
        super().__init__(directory, migrate_factor, migrate_min_tiles,
                         migrate_cooldown, telemetry=telemetry)
        if steal_min_tiles < 1:
            raise ValueError(
                f"steal_min_tiles must be >= 1, got {steal_min_tiles}")
        self.steal_min_tiles = steal_min_tiles

    @property
    def n_steals(self) -> int:
        return int(self.telemetry.counter("router.steals"))

    @property
    def n_stolen_requests(self) -> int:
        return int(self.telemetry.counter("router.stolen_requests"))

    def _pick_group(self, victim) -> tuple | None:
        """The victim's best queued kernel-group to move: largest by
        tiles, subject to the balance guard.  Returns (key, kernel,
        tiles) or None."""
        groups: dict[tuple, list] = {}
        total = 0
        for flow in victim._flows.values():
            for r in flow.queue:
                groups.setdefault(r.key, []).append(r)
                total += r.cost
        if not groups:
            return None
        sized = sorted(((sum(r.cost for r in rs), key, rs[0].kernel)
                        for key, rs in groups.items()), reverse=True,
                       key=lambda g: g[0])
        for tiles, key, kern in sized:
            if len(groups) >= 2 or tiles * 2 <= total:
                return key, kern, tiles
        return None

    def rebalance(self, fleet) -> int:
        from repro.core.bank import BankError
        moved = 0
        # bounded sweep: each pass moves one group; a pass that cannot
        # find (idle thief, rich victim, movable group) ends the sweep
        for _ in range(4 * len(fleet.replicas)):
            queued = [rep.queued_tiles for rep in fleet.replicas]
            idle = [i for i, q in enumerate(queued) if q == 0]
            if not idle:
                break
            victim = max(range(len(queued)), key=queued.__getitem__)
            if queued[victim] < self.steal_min_tiles:
                break
            picked = self._pick_group(fleet.replicas[victim])
            if picked is None:
                break
            key, kernel, _tiles = picked
            # the work goes to the idle replica whose PHYSICAL device is
            # least loaded (replicas may wrap onto shared devices — two
            # idle replicas on one device are one execution resource, so
            # piling stolen groups onto both buys nothing), ties broken
            # by the replica's own pending tiles
            dev_load: dict = {}
            devices = getattr(fleet, "devices", None)
            if devices is not None:
                for rep, dev in zip(fleet.replicas, devices):
                    dev_load[dev.id] = (dev_load.get(dev.id, 0)
                                        + rep.pending_tiles)
            thief = min(idle, key=lambda i: (
                dev_load.get(devices[i].id, 0) if devices is not None else 0,
                fleet.replicas[i].pending_tiles))
            try:
                # fleet.move_group is the one implementation of the move
                # sequence (prefetch on the thief BEFORE anything moves —
                # a momentarily all-pinned thief bank raises and the
                # sweep ends, never stranding requests on a replica that
                # cannot host their context — then directory republish,
                # then steal + adopt); drain_replica evacuates through
                # the same path
                stolen = fleet.move_group(victim, thief, key, kernel)
            except BankError:
                break
            if not stolen:
                break
            self.telemetry.inc("router.steals")
            self.telemetry.inc("router.stolen_requests", len(stolen))
            self.telemetry.event("steal", victim=victim, thief=thief,
                                 requests=len(stolen))
            moved += 1
        return moved

    def stats(self) -> dict:
        s = super().stats()
        s["steals"] = self.n_steals
        s["stolen_requests"] = self.n_stolen_requests
        return s

    def reset_metrics(self) -> None:
        super().reset_metrics()
        self.telemetry.reset(names=("router.steals",
                                    "router.stolen_requests"))


def make_router(steal: bool = False, **kw):
    """Build the fleet's default router: residency-only, or + stealing."""
    return WorkStealingRouter(**kw) if steal else ResidencyRouter(
        **{k: v for k, v in kw.items() if k != "steal_min_tiles"})
