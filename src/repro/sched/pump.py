"""AutoPump: a background drain thread for the serving engines.

Without the pump, a queued request only makes progress when some caller
drives the engine (``flush``/``result``/``as_completed``).  ``AutoPump``
wraps a server — ``OverlayServer`` or ``ShardedOverlayServer`` — and
runs its drain loop (``server.pump_once``) on a daemon thread, so
``submit`` from concurrent clients is served without an explicit drain
call: the front-end the ROADMAP's "background flush thread" item asked
for.

Concurrency model — one lock, coarse granularity:

* The engines are NOT thread-safe; every pump entry point (``submit``,
  ``result``, ``flush``, ``flush_sync``, ...) and every pump iteration
  holds ONE reentrant lock, so engine state is only ever mutated by one
  thread at a time.  Granularity is a single ``pump_once`` step (launch
  or retire one round), so a concurrent ``submit`` waits at most one
  round's device time — rounds, not drains, are the unit of contention.
* In-flight rounds stay bounded by the server's own ``max_inflight``
  (``pump_once`` fills the pipeline through the same path ``flush``
  uses); the pump adds no new queue depth anywhere.
* ``flush_sync()`` through the pump takes the lock for the whole
  barrier drain — with the pump excluded, it is the engine's
  one-round-at-a-time loop, bit for bit: the oracle stays exact.
* ``close()`` (or leaving the ``with`` block) stops the thread cleanly;
  queued work is NOT dropped — it is simply no longer pumped and can be
  drained explicitly afterwards.
* ELASTIC fleets autoscale on the pump tick: the sharded engine's
  ``pump_once`` observes its ``AutoscalePolicy`` every call — including
  IDLE calls, which the pump keeps issuing at ``poll_interval`` while
  parked.  Those idle ticks are where background scale-DOWNS come from
  (an idle replica's streak can only accrue if someone keeps observing),
  and ``pump_once`` returns True for a tick that only resized the fleet,
  so the pump stays hot through a scaling burst instead of sleeping
  mid-resize.

Waiters (``result``/``wait_idle``) sleep on a condition variable that
the pump notifies after every delivered round; if the pump is closed
under them or its thread dies (engine bug), waiters raise instead of
hanging forever (already-delivered results are still claimable first).
"""

from __future__ import annotations

import threading
import time


class AutoPump:
    """Background drain thread over one serving engine.

    ``server`` must expose the engine surface this package gives both
    engines: ``submit`` / ``pump_once`` / ``try_result`` / ``flush`` /
    ``flush_sync`` / ``pending`` / ``stats``.

    ::

        with AutoPump(OverlayServer(bank_capacity=8)) as pump:
            t = pump.submit(kernel, xs, tenant="alice")
            outs = pump.result(t)          # pump delivers in background
    """

    def __init__(self, server, poll_interval: float = 0.005,
                 telemetry=None):
        from repro.telemetry import InMemorySink
        if poll_interval <= 0:
            raise ValueError(
                f"poll_interval must be > 0, got {poll_interval}")
        self.server = server
        self.poll_interval = poll_interval
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._wake = threading.Event()
        self._stop = threading.Event()
        #: the structured sink the pump counters live in — by default
        #: the WRAPPED SERVER's sink, so one store carries engine and
        #: pump telemetry together (see repro.telemetry)
        self.telemetry = (telemetry if telemetry is not None
                          else getattr(server, "telemetry", None)
                          or InMemorySink())
        #: tick observers, called AFTER every pump iteration (worked or
        #: idle) from the pump thread with the lock RELEASED — see
        #: ``add_tick_listener``
        self._listeners: list = []
        self._thread = threading.Thread(target=self._run,
                                        name="overlay-autopump", daemon=True)
        self._thread.start()

    @property
    def n_pump_rounds(self) -> int:
        """Productive pump iterations (a round delivered / fleet resized)."""
        return int(self.telemetry.counter("pump.rounds"))

    @property
    def n_listener_errors(self) -> int:
        """Tick listeners that raised (counted, skipped, never fatal)."""
        return int(self.telemetry.counter("pump.listener_errors"))

    # ------------------------------------------------------------ observers
    def add_tick_listener(self, fn) -> None:
        """Register ``fn(worked: bool)`` to run after every pump iteration.

        Called from the PUMP THREAD with the engine lock released, on
        both productive ticks (a round delivered / the fleet resized) and
        idle ticks — idle ticks are how an observer sees pressure DROP,
        so edge backpressure (the asyncio gateway) can relax without
        waiting for new traffic.  Listeners must be cheap and must not
        re-enter the pump's blocking API; hand off to another thread or
        event loop (``loop.call_soon_threadsafe``) instead.  A listener
        that raises is counted (``n_listener_errors``) and skipped, never
        allowed to kill the pump thread.
        """
        self._listeners.append(fn)

    def remove_tick_listener(self, fn) -> None:
        """Unregister a tick listener (no-op when not registered)."""
        try:
            self._listeners.remove(fn)
        except ValueError:
            pass

    def _notify_listeners(self, worked: bool) -> None:
        for fn in list(self._listeners):
            try:
                fn(worked)
            except Exception:
                self.telemetry.inc("pump.listener_errors")

    # ------------------------------------------------------------ pump loop
    def _run(self) -> None:
        while not self._stop.is_set():
            with self._cond:
                worked = self.server.pump_once()
                self.telemetry.inc("pump.ticks")
                if worked:
                    self.telemetry.inc("pump.rounds")
                    self._cond.notify_all()
                else:
                    self.telemetry.inc("pump.idle_ticks")
            self._notify_listeners(worked)
            if not worked:
                # idle: sleep until a submit wakes us (or the poll tick —
                # belt and braces for externally-enqueued work)
                self._wake.wait(self.poll_interval)
                self._wake.clear()

    def _check_alive(self) -> None:
        """A waiter whose pump can no longer deliver must raise, not spin:
        closed pump (the owner shut it down under the waiter) and dead
        thread (engine bug) both end the wait."""
        if self._stop.is_set():
            raise RuntimeError(
                "autopump is closed; drain the server explicitly "
                "(flush/flush_sync) to claim remaining work")
        if not self._thread.is_alive():
            raise RuntimeError(
                "autopump thread died; server state may be inconsistent")

    # ------------------------------------------------------------- clients
    def submit(self, kernel, xs, tenant=None) -> int:
        """Thread-safe ``server.submit``; the pump serves it in background."""
        kw = {} if tenant is None else {"tenant": tenant}
        with self._lock:
            ticket = self.server.submit(kernel, xs, **kw)
        self._wake.set()
        return ticket

    def submit_work(self, fn, tenant=None, **kw) -> int:
        """Thread-safe ``server.submit_work``; the pump runs ``fn`` on
        its own thread when the round policy grants the flow a slot.
        NOTE the pump holds the engine lock for a whole pump tick, so a
        work callable observes concurrent latency submits only at
        round boundaries — bulk submitters should keep work items small
        (the training tenant's micro-round contract)."""
        if tenant is not None:
            kw["tenant"] = tenant
        with self._lock:
            ticket = self.server.submit_work(fn, **kw)
        self._wake.set()
        return ticket

    def try_result(self, ticket: int):
        """Non-blocking thread-safe claim (see ``server.try_result``)."""
        with self._lock:
            return self.server.try_result(ticket)

    def try_results(self, tickets) -> dict:
        """Batch non-blocking claim under ONE lock acquisition.

        Returns ``{ticket: outputs}`` for every ticket already delivered;
        still-pending tickets are simply absent.  A ticket ``try_result``
        would raise for (unknown, or already claimed) maps to the
        ``KeyError`` instance instead of raising, so one bad ticket
        cannot mask the rest of the batch — the asyncio gateway fans
        these back out to per-ticket awaiters.
        """
        out: dict = {}
        with self._lock:
            for t in tickets:
                try:
                    r = self.server.try_result(t)
                except KeyError as e:
                    out[t] = e
                    continue
                if r is not None:
                    out[t] = r
        return out

    def result(self, ticket: int, timeout: float | None = None):
        """Block until the pump delivers ``ticket``; claim-once semantics.

        Unlike ``server.result``, this never drives the pipeline from the
        calling thread — it waits for the background pump, so any number
        of client threads can block here concurrently.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                out = self.server.try_result(ticket)
                if out is not None:
                    return out
                self._check_alive()
                wait = (self.poll_interval if deadline is None
                        else min(self.poll_interval,
                                 deadline - time.monotonic()))
                if deadline is not None and wait <= 0:
                    raise TimeoutError(
                        f"ticket {ticket} not delivered within {timeout}s")
                self._wake.set()
                self._cond.wait(wait)

    def wait_idle(self, timeout: float | None = None) -> None:
        """Block until the server has no undelivered work."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self.server.pending:
                self._check_alive()
                wait = (self.poll_interval if deadline is None
                        else min(self.poll_interval,
                                 deadline - time.monotonic()))
                if deadline is not None and wait <= 0:
                    raise TimeoutError(
                        f"server not idle within {timeout}s "
                        f"({self.server.pending} pending)")
                self._wake.set()
                self._cond.wait(wait)

    def flush(self) -> dict:
        """Pipelined drain of everything queued (pump excluded meanwhile)."""
        with self._lock:
            return self.server.flush()

    def flush_sync(self) -> dict:
        """The engine's barrier drain, pump excluded for its whole span —
        the bit-for-bit oracle is unchanged by pumping."""
        with self._lock:
            return self.server.flush_sync()

    @property
    def pending(self) -> int:
        with self._lock:
            return self.server.pending

    def stats(self) -> dict:
        with self._lock:
            s = dict(self.server.stats())
        s["pump_rounds"] = self.n_pump_rounds
        s["pump_alive"] = self._thread.is_alive()
        s["pump_listeners"] = len(self._listeners)
        s["pump_listener_errors"] = self.n_listener_errors
        return s

    # ------------------------------------------------------------ shutdown
    @property
    def closed(self) -> bool:
        """True once `close()` was requested; the drain thread is
        stopping (or stopped) and ``poll_interval`` no longer predicts
        anything — edge layers fall back to their own retry hints."""
        return self._stop.is_set()

    def close(self, timeout: float = 5.0) -> None:
        """Stop the pump thread (idempotent).  Queued work is kept — drain
        it explicitly (``flush``/``flush_sync``) if needed."""
        self._stop.set()
        self._wake.set()
        with self._cond:
            self._cond.notify_all()
        self._thread.join(timeout)
        if self._thread.is_alive():        # pragma: no cover - hung device
            raise RuntimeError("autopump thread did not stop")

    def __enter__(self) -> "AutoPump":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
