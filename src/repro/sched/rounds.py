"""Round-formation policies: which queued requests form the next round.

The engine (``launch.serve.OverlayServer``) owns the queues — one
:class:`Flow` per tenant plus a round-robin order — and the staged
launch/retire mechanics.  A :class:`RoundPolicy` owns only the DECISION:
given the flows, pick the next round's requests.  Policies mutate the
flow queues/deficits in place (requests they take leave the queues) and
may keep feedback state fed by :meth:`RoundPolicy.observe`.

Shipped policies:

* :class:`DeficitRoundRobin` — the engine's original scheduler, extracted
  bit for bit (tests/test_sched_policies.py replays a recorded golden
  trace and asserts identical rounds + identical result bytes).  Classic
  DRR semantics: a flow's deficit grows by ``quantum_tiles`` per
  scheduling pass, whole head-of-queue kernel groups are taken while the
  deficit covers their tile cost, and the deficit resets ONLY when the
  flow goes idle — a backlogged flow that could not afford its head this
  round keeps its credit, so a request costing more than one quantum is
  always eventually served (the classic-DRR starvation bound).
* :class:`CoalescingPolicy` — DRR base round, then same-kernel requests
  from other tenants' queues are merged into the round's existing kernel
  groups (deficit-free, up to ``coalesce_tiles`` extra tiles).  Trades
  strict per-tenant pacing for launch batching: one device launch covers
  more of the fleet-wide demand for a hot kernel.
* :class:`DynamicTilePolicy` — DRR with an adaptive per-round tile
  budget (AIMD on observed round latency): rounds shrink when delivery
  latency overshoots ``target_latency_s`` and grow while there is
  headroom, trading launch batching against tail latency automatically.

``make_round_policy`` builds a policy by name; the ``REPRO_ROUND_POLICY``
environment knob selects the default for engines that were not handed an
explicit policy (this is how CI runs the serving suite under every
policy).  See docs/SCHEDULING.md for the policy-author guide.
"""

from __future__ import annotations

import dataclasses
import math
import os
from collections import OrderedDict, deque
from typing import Protocol, runtime_checkable

import numpy as np

#: tenant label used when ``submit`` is not given one
DEFAULT_TENANT = "default"

#: environment knob: default round policy name for engines constructed
#: without an explicit ``round_policy`` (CI's policy matrix sets this)
POLICY_ENV = "REPRO_ROUND_POLICY"


@dataclasses.dataclass
class OverlayRequest:
    """One queued kernel invocation: a batch of iterations of one kernel."""

    ticket: int
    kernel: object            # core.overlay.CompiledKernel
    xs: list                  # per-primary-input 1-D arrays, equal length
    tenant: str = DEFAULT_TENANT
    key: tuple = ()           # context identity (bank.context_key)
    cost: int = 1             # dispatch tiles this request occupies
    t_submit: float = 0.0

    @property
    def name(self) -> str:
        return self.kernel.program.name

    @property
    def batch(self) -> int:
        return int(np.shape(self.xs[0])[0])


@dataclasses.dataclass
class WorkRequest(OverlayRequest):
    """One queued host-side work item (e.g. a training micro-round).

    A work request rides the SAME flows, rounds, tickets, and telemetry
    as kernel requests — that is the whole point: the scheduler decides
    when bulk work runs, not a side channel.  It carries no kernel
    (``kernel is None``) and no inputs; instead the engine calls ``fn()``
    at round launch and delivers its return value through the ticket.
    ``cost`` is the tile budget the work charges against its flow's
    deficit (how big the work "looks" to the round policy), and ``key``
    groups consecutive work items the way a context key groups kernel
    requests (steal/evacuation move whole key groups).
    """

    fn: object = None             # zero-arg callable, run at round launch
    label: str = "work"

    @property
    def name(self) -> str:        # no kernel.program to read the name off
        return self.label

    @property
    def batch(self) -> int:       # no primary inputs; tile math uses cost
        return 0


@dataclasses.dataclass
class Flow:
    """Per-tenant FIFO queue + deficit-round-robin state."""

    queue: deque
    deficit: float = 0.0


@runtime_checkable
class RoundPolicy(Protocol):
    """What the engine needs from a round-formation policy.

    ``form_round`` may mutate ``flows`` (take requests, adjust deficits)
    and ``rr`` (rotate the service order); the engine guarantees every
    flow in ``rr`` exists in ``flows`` and prunes drained flows between
    calls.  Returning ``None`` means nothing is queued.  ``observe`` is
    the feedback edge: the engine reports every retired round's tile
    cost — the sum of its requests' ``cost`` fields, the SAME units
    policies budget rounds in — and wall-clock seconds (launch ->
    delivery, on the engine's injectable clock).  Both drain paths
    (pipelined and ``flush_sync``) report identical units.
    """

    def form_round(self, flows: dict[str, Flow], rr: deque,
                   round_kernels: int) -> list | None: ...

    def observe(self, n_tiles: int, wall_s: float) -> None: ...


class DeficitRoundRobin:
    """Deficit round-robin across tenant flows (the engine's original
    scheduler, extracted).

    ``quantum_tiles`` is the per-pass deficit increment in dispatch
    tiles; ``None`` means unbounded (pure round-robin over tenants).

    ``tenant_quanta`` maps individual tenants to their OWN per-pass
    quantum (tiles, or ``None`` for unbounded), overriding
    ``quantum_tiles`` flow by flow — the SLO-class mechanism the
    slo_study sweeps: a latency tier gets a large quantum (its requests
    clear in the next round), a preemptible bulk tier gets a small one
    (its backlog trickles through without crowding the round).  Tenants
    absent from the map use ``quantum_tiles``.
    """

    def __init__(self, quantum_tiles: float | None = None,
                 tenant_quanta: dict | None = None):
        if quantum_tiles is not None and quantum_tiles <= 0:
            raise ValueError(
                f"quantum_tiles must be > 0 or None (unbounded), got "
                f"{quantum_tiles}; a non-positive quantum can never cover "
                f"a request's tile cost")
        self.quantum_tiles = quantum_tiles
        self.tenant_quanta = dict(tenant_quanta or {})
        for tenant, q in self.tenant_quanta.items():
            if q is not None and q <= 0:
                raise ValueError(
                    f"tenant_quanta[{tenant!r}] must be > 0 or None "
                    f"(unbounded), got {q}")

    def quantum_for(self, tenant: str) -> float | None:
        """The per-pass deficit increment for one tenant's flow."""
        return self.tenant_quanta.get(tenant, self.quantum_tiles)

    # ------------------------------------------------------------- hooks
    def _max_round_tiles(self) -> float:
        """Per-round tile budget; ``inf`` = unbounded (pure DRR).
        :class:`DynamicTilePolicy` overrides this with its adaptive
        target."""
        return math.inf

    def observe(self, n_tiles: int, wall_s: float) -> None:
        """Feedback no-op for static policies."""

    # ----------------------------------------------------------- service
    def _serve_flow(self, flow: Flow, keys: set, cap: int,
                    used: int) -> tuple[list, int]:
        """DRR service of one flow: whole kernel groups, head-first, until
        the flow's deficit, the round's distinct-kernel budget, or the
        round's tile budget runs out.  Returns ``(taken, used)`` where
        ``used`` is the round's running tile total.

        Untaken requests keep their ARRIVAL order in the queue (never the
        grouped order) — a skipped kernel's old request must reach the
        queue head ahead of newer traffic, or a live stream on one kernel
        would starve a tenant's own requests on another.

        Classic-DRR deficit semantics: the deficit resets ONLY when the
        flow drains (goes idle).  A backlogged flow — queued work it
        could not afford this round — keeps its accumulated credit, so a
        request costing more than one quantum is served once enough
        rounds have passed instead of starving forever
        (tests/test_sched_policies.py::test_deficit_preserved_for_backlogged_flow).
        """
        limit = self._max_round_tiles()
        taken: list[OverlayRequest] = []
        taken_ids: set[int] = set()
        by_key: OrderedDict[tuple, list] = OrderedDict()
        for r in flow.queue:
            by_key.setdefault(r.key, []).append(r)
        exhausted = False
        for key, rs in by_key.items():
            if exhausted or (key not in keys and len(keys) >= cap):
                continue
            for r in rs:
                if used and used + r.cost > limit:
                    # round full: stop WITHOUT charging the flow — its
                    # deficit (and queue order) carry to the next round
                    exhausted = True
                    break
                if flow.deficit >= r.cost:
                    flow.deficit -= r.cost
                    keys.add(key)
                    taken.append(r)
                    taken_ids.add(r.ticket)
                    used += r.cost
                else:
                    exhausted = True
                    break
        flow.queue = deque(r for r in flow.queue
                           if r.ticket not in taken_ids)
        if not flow.queue:
            flow.deficit = 0.0          # classic DRR: only idle flows reset
        return taken, used

    def form_round(self, flows: dict[str, Flow], rr: deque,
                   round_kernels: int) -> list | None:
        """Pick the next round via deficit round-robin across tenants."""
        if not flows:
            return None
        keys: set = set()
        round_reqs: list[OverlayRequest] = []
        used = 0
        while not round_reqs:
            for tenant in list(rr):
                flow = flows[tenant]
                if not flow.queue:
                    continue
                quantum = self.quantum_for(tenant)
                flow.deficit = (math.inf if quantum is None
                                else flow.deficit + quantum)
                taken, used = self._serve_flow(flow, keys, round_kernels,
                                               used)
                round_reqs.extend(taken)
        rr.rotate(-1)             # a different tenant leads next round
        return round_reqs


class CoalescingPolicy(DeficitRoundRobin):
    """DRR base round + cross-tenant same-kernel coalescing.

    After the base DRR pass, requests elsewhere in the queues whose
    context key already appears in the round are pulled in deficit-free,
    up to ``coalesce_tiles`` extra tiles per round.  The merged group
    rides the SAME device launch (round assembly batches per kernel), so
    fleet-wide demand for a hot kernel is served in fewer, fuller
    launches.  The trade: per-tenant pacing is looser (coalesced requests
    bypass their flow's deficit) and within-kernel delivery order can mix
    tenants' submission order.
    """

    def __init__(self, quantum_tiles: float | None = None,
                 coalesce_tiles: int = 32,
                 tenant_quanta: dict | None = None):
        super().__init__(quantum_tiles, tenant_quanta=tenant_quanta)
        if coalesce_tiles < 0:
            raise ValueError(
                f"coalesce_tiles must be >= 0, got {coalesce_tiles}")
        self.coalesce_tiles = coalesce_tiles
        self.n_coalesced = 0

    def form_round(self, flows: dict[str, Flow], rr: deque,
                   round_kernels: int) -> list | None:
        round_reqs = super().form_round(flows, rr, round_kernels)
        if round_reqs is None or not self.coalesce_tiles:
            return round_reqs
        keys = {r.key for r in round_reqs}
        budget = self.coalesce_tiles
        for tenant in list(rr):
            if budget <= 0:
                break
            flow = flows.get(tenant)
            if flow is None or not flow.queue:
                continue
            taken_ids: set[int] = set()
            for r in flow.queue:
                if r.key not in keys:
                    continue
                if r.cost > budget:
                    # stop scanning this flow: pulling a NEWER request
                    # past an unaffordable older one would invert the
                    # tenant's arrival order (the same invariant
                    # _serve_flow keeps for skipped kernels)
                    break
                budget -= r.cost
                taken_ids.add(r.ticket)
                round_reqs.append(r)
            if taken_ids:
                self.n_coalesced += len(taken_ids)
                flow.queue = deque(r for r in flow.queue
                                   if r.ticket not in taken_ids)
                if not flow.queue:
                    flow.deficit = 0.0
        return round_reqs


class DynamicTilePolicy(DeficitRoundRobin):
    """DRR with an adaptive per-round tile budget (AIMD on latency).

    The engine reports every retired round's live tiles and wall-clock
    via :meth:`observe`.  When a round's latency overshoots
    ``target_latency_s`` the budget shrinks multiplicatively
    (``shrink``); when latency sits below half the target AND the round
    actually filled most of the budget (low latency on a near-empty
    round says nothing), it grows (``grow``), clamped to
    ``[min_tiles, max_tiles]``.  Small budgets mean more, shallower
    rounds — more pipeline overlap and tighter tails; large budgets mean
    fuller launches — better batching.  This policy walks that trade-off
    (the DRR-quantum/``round_kernels`` study in the ROADMAP) instead of
    freezing it at construction.
    """

    def __init__(self, quantum_tiles: float | None = None,
                 target_latency_s: float = 0.05, init_tiles: int = 32,
                 min_tiles: int = 4, max_tiles: int = 4096,
                 grow: float = 1.25, shrink: float = 0.5,
                 tenant_quanta: dict | None = None):
        super().__init__(quantum_tiles, tenant_quanta=tenant_quanta)
        if target_latency_s <= 0:
            raise ValueError(
                f"target_latency_s must be > 0, got {target_latency_s}")
        if not (0 < min_tiles <= init_tiles <= max_tiles):
            raise ValueError(
                f"need 0 < min_tiles <= init_tiles <= max_tiles, got "
                f"{min_tiles}/{init_tiles}/{max_tiles}")
        if grow <= 1.0 or not (0.0 < shrink < 1.0):
            raise ValueError(
                f"need grow > 1 and 0 < shrink < 1, got {grow}/{shrink}")
        self.target_latency_s = target_latency_s
        self.min_tiles = min_tiles
        self.max_tiles = max_tiles
        self.grow = grow
        self.shrink = shrink
        #: current per-round tile budget (the adapted knob)
        self.round_tiles = float(init_tiles)
        self.n_grown = 0
        self.n_shrunk = 0

    def _max_round_tiles(self) -> float:
        return self.round_tiles

    def observe(self, n_tiles: int, wall_s: float) -> None:
        if wall_s > self.target_latency_s:
            self.round_tiles = max(float(self.min_tiles),
                                   self.round_tiles * self.shrink)
            self.n_shrunk += 1
        elif (wall_s < self.target_latency_s / 2
              and n_tiles >= 0.75 * self.round_tiles):
            self.round_tiles = min(float(self.max_tiles),
                                   self.round_tiles * self.grow)
            self.n_grown += 1


#: name -> class, for ``make_round_policy`` and the CLI/CI knobs
ROUND_POLICIES: dict[str, type] = {
    "drr": DeficitRoundRobin,
    "coalesce": CoalescingPolicy,
    "dynamic": DynamicTilePolicy,
}


def make_round_policy(name: str | None = None,
                      quantum_tiles: float | None = None, **kw):
    """Build a round policy by name.

    ``name=None`` consults the ``REPRO_ROUND_POLICY`` environment knob
    (default ``"drr"``) — engines constructed without an explicit policy
    go through here, which is how the CI policy matrix swaps the
    scheduler under the whole serving suite without touching the tests.
    """
    name = name or os.environ.get(POLICY_ENV) or "drr"
    try:
        cls = ROUND_POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown round policy {name!r}; choose from "
            f"{sorted(ROUND_POLICIES)}") from None
    return cls(quantum_tiles=quantum_tiles, **kw)
