"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""

from repro.models import BlockSpec, ModelConfig, StackSpec

ARCH = "phi3.5-moe-42b-a6.6b"
FAMILY = "moe"
SKIP_SHAPES = {"long_500k": "full attention (quadratic); needs "
                            "sub-quadratic attention per assignment"}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
        vocab=32064, head_dim=128,
        n_experts=16, top_k=2, expert_d_ff=6400,
        stacks=(StackSpec(32, (BlockSpec("attn", moe=True),)),),
        full_attention=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, head_dim=16,
        n_experts=4, top_k=2, expert_d_ff=64,
        stacks=(StackSpec(2, (BlockSpec("attn", moe=True),)),),
        full_attention=True,
    )
