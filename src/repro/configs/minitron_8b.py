"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron.  [arXiv:2407.14679; hf]
"""

from repro.models import ModelConfig, dense_stacks

ARCH = "minitron-8b"
FAMILY = "dense"
SKIP_SHAPES = {"long_500k": "full attention (quadratic); needs "
                            "sub-quadratic attention per assignment"}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        d_model=4096, n_heads=32, n_kv_heads=8, d_ff=16384,
        vocab=256000, head_dim=128,
        stacks=dense_stacks(32),
        full_attention=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, head_dim=16,
        stacks=dense_stacks(2),
        full_attention=True,
    )
