"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""

from repro.models import BlockSpec, ModelConfig, StackSpec

ARCH = "qwen2-moe-a2.7b"
FAMILY = "moe"
SKIP_SHAPES = {"long_500k": "full attention (quadratic); needs "
                            "sub-quadratic attention per assignment"}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
        vocab=151936, head_dim=128,
        n_experts=60, top_k=4, expert_d_ff=1408,
        n_shared_experts=4, shared_expert_d_ff=4 * 1408,
        stacks=(StackSpec(24, (BlockSpec("attn", moe=True),)),),
        full_attention=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
        vocab=256, head_dim=16,
        n_experts=6, top_k=2, expert_d_ff=32,
        n_shared_experts=2, shared_expert_d_ff=64,
        stacks=(StackSpec(2, (BlockSpec("attn", moe=True),)),),
        full_attention=True,
    )
