"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2 backbone.  [arXiv:2404.16821; hf]

The InternViT frontend is a STUB: input_specs() provides precomputed patch
embeddings [B, vision_tokens, d_model] prepended to the text sequence.
"""

from repro.models import ModelConfig, dense_stacks

ARCH = "internvl2-26b"
FAMILY = "vlm"
SKIP_SHAPES = {"long_500k": "full attention (quadratic); needs "
                            "sub-quadratic attention per assignment"}
VISION_TOKENS = 1024


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
        vocab=92553, head_dim=128,
        stacks=dense_stacks(48),
        vision_tokens=VISION_TOKENS,
        full_attention=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, head_dim=16,
        stacks=dense_stacks(2),
        vision_tokens=8,
        full_attention=True,
    )
