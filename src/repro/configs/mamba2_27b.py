"""mamba2-2.7b [ssm]: 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

O(1) decode state => long_500k runs trivially.
"""

from repro.models import BlockSpec, ModelConfig, SSMDims, StackSpec

ARCH = "mamba2-2.7b"
FAMILY = "ssm"
SKIP_SHAPES: dict[str, str] = {}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        d_model=2560, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab=50280, head_dim=1,
        ssm=SSMDims(d_model=2560, d_state=128, d_conv=4, expand=2,
                    head_dim=64, n_groups=1),
        stacks=(StackSpec(64, (BlockSpec("mamba"),)),),
        full_attention=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        d_model=64, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab=256, head_dim=1,
        ssm=SSMDims(d_model=64, d_state=16, d_conv=4, expand=2,
                    head_dim=16, n_groups=1),
        stacks=(StackSpec(3, (BlockSpec("mamba"),)),),
        full_attention=False,
    )
