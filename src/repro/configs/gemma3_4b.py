"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144, 5:1 local(sliding-window 1024):global interleave, 128k ctx.
[hf:google/gemma-3-1b-pt; unverified]

long_500k runs: 28/34 layers are sliding-window (O(S*w)); the 6 global
layers decode against a sequence-sharded KV cache.
"""

from repro.models import BlockSpec, ModelConfig, StackSpec

ARCH = "gemma3-4b"
FAMILY = "dense"
SKIP_SHAPES: dict[str, str] = {}
WINDOW = 1024


def config() -> ModelConfig:
    local = BlockSpec("attn", window=WINDOW)
    glob = BlockSpec("attn")
    return ModelConfig(
        name=ARCH,
        d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
        vocab=262144, head_dim=256,
        rope_theta=1_000_000.0,
        stacks=(
            StackSpec(5, (local,) * 5 + (glob,)),   # 30 layers
            StackSpec(1, (local,) * 4),             # 34 total
        ),
        full_attention=False,   # majority sliding-window
    )


def smoke_config() -> ModelConfig:
    local = BlockSpec("attn", window=16)
    glob = BlockSpec("attn")
    return ModelConfig(
        name=ARCH + "-smoke",
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, head_dim=32,
        stacks=(StackSpec(1, (local, local, glob)),
                StackSpec(1, (local,))),
        full_attention=False,
    )
