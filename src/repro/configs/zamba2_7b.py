"""zamba2-7b [hybrid]: 81 Mamba2 layers + a SHARED attention block applied
every 6th position (weights time-multiplexed across 13 call sites — the
paper's TM-FU idea at the weight level).  [arXiv:2411.15242; unverified]

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64.
"""

from repro.models import BlockSpec, ModelConfig, SSMDims, StackSpec

ARCH = "zamba2-7b"
FAMILY = "hybrid"
SKIP_SHAPES: dict[str, str] = {}   # sub-quadratic: long_500k runs


def config() -> ModelConfig:
    shared_attn = BlockSpec("attn", shared=True)
    mamba = BlockSpec("mamba")
    return ModelConfig(
        name=ARCH,
        d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
        vocab=32000, head_dim=112,
        ssm=SSMDims(d_model=3584, d_state=64, d_conv=4, expand=2,
                    head_dim=64, n_groups=1),
        stacks=(
            StackSpec(13, (shared_attn,) + (mamba,) * 6),  # 78 mamba
            StackSpec(1, (mamba,) * 3),                    # 81 total
        ),
        full_attention=False,
    )


def smoke_config() -> ModelConfig:
    shared_attn = BlockSpec("attn", shared=True)
    mamba = BlockSpec("mamba")
    return ModelConfig(
        name=ARCH + "-smoke",
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, head_dim=16,
        ssm=SSMDims(d_model=64, d_state=16, d_conv=4, expand=2,
                    head_dim=16, n_groups=1),
        stacks=(StackSpec(2, (shared_attn,) + (mamba,) * 2),
                StackSpec(1, (mamba,))),
        full_attention=False,
    )
