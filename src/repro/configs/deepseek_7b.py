"""deepseek-7b [dense]: 30L d_model=4096 32H (GQA kv=32) d_ff=11008
vocab=102400 — llama architecture.  [arXiv:2401.02954; hf]
"""

from repro.models import ModelConfig, dense_stacks

ARCH = "deepseek-7b"
FAMILY = "dense"
SKIP_SHAPES = {"long_500k": "full attention (quadratic); needs "
                            "sub-quadratic attention per assignment"}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008,
        vocab=102400, head_dim=128,
        stacks=dense_stacks(30),
        full_attention=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, head_dim=16,
        stacks=dense_stacks(2),
        full_attention=True,
    )
