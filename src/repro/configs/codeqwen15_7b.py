"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (GQA kv=32) d_ff=13440
vocab=92416 — qwen1.5 architecture.  [hf:Qwen/CodeQwen1.5-7B; hf]
"""

from repro.models import ModelConfig, dense_stacks

ARCH = "codeqwen1.5-7b"
FAMILY = "dense"
SKIP_SHAPES = {"long_500k": "full attention (quadratic); needs "
                            "sub-quadratic attention per assignment"}


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
        vocab=92416, head_dim=128,
        stacks=dense_stacks(32),
        full_attention=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke",
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, head_dim=16,
        stacks=dense_stacks(2),
        full_attention=True,
    )
