"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H (kv=8) d_ff=2048
vocab=51865.  Enc-dec; the conv frontend is a STUB — input_specs() provides
precomputed frame embeddings [B, S, d_model].  [arXiv:2212.04356; unverified]
"""

from repro.models import (BlockSpec, EncoderSpec, ModelConfig, StackSpec)

ARCH = "whisper-base"
FAMILY = "audio"
SKIP_SHAPES = {"long_500k": "full attention enc-dec (quadratic); needs "
                            "sub-quadratic attention per assignment"}


def config() -> ModelConfig:
    enc = BlockSpec("attn", causal=False, use_rope=False)
    dec = BlockSpec("attn", causal=True, use_rope=False, cross=True)
    return ModelConfig(
        name=ARCH,
        d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
        vocab=51865, head_dim=64,
        stacks=(StackSpec(6, (dec,)),),
        encoder=EncoderSpec(stacks=(StackSpec(6, (enc,)),), frame_dim=512),
        use_abs_pos=True,
        full_attention=True,
    )


def smoke_config() -> ModelConfig:
    enc = BlockSpec("attn", causal=False, use_rope=False)
    dec = BlockSpec("attn", causal=True, use_rope=False, cross=True)
    return ModelConfig(
        name=ARCH + "-smoke",
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, head_dim=16,
        stacks=(StackSpec(2, (dec,)),),
        encoder=EncoderSpec(stacks=(StackSpec(2, (enc,)),), frame_dim=64),
        use_abs_pos=True,
        full_attention=True,
    )
