"""Architecture registry: ``--arch <id>`` resolves here.

Each module defines the exact published config (``config()``), a reduced
``smoke_config()`` of the same family for CPU tests, ``FAMILY``, and
``SKIP_SHAPES`` (shape -> reason) for cells the assignment excludes.
"""

from __future__ import annotations

import importlib

_MODULES = {
    "zamba2-7b": "repro.configs.zamba2_7b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe",
    "whisper-base": "repro.configs.whisper_base",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "codeqwen1.5-7b": "repro.configs.codeqwen15_7b",
    "minitron-8b": "repro.configs.minitron_8b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "mamba2-2.7b": "repro.configs.mamba2_27b",
}

ARCHS = tuple(_MODULES)

#: assigned input shapes: name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return importlib.import_module(_MODULES[arch])


def get_config(arch: str):
    return _mod(arch).config()


def get_smoke_config(arch: str):
    return _mod(arch).smoke_config()


def get_family(arch: str) -> str:
    return _mod(arch).FAMILY


def skip_reason(arch: str, shape: str) -> str | None:
    return _mod(arch).SKIP_SHAPES.get(shape)


def cells():
    """All 40 (arch, shape) cells with skip annotations."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            out.append((a, s, skip_reason(a, s)))
    return out
