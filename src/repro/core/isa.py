"""Instruction encoding — the paper's no-decoder 32-bit word + 40-bit context.

Paper Section III-A: a 32-bit instruction = 21-bit DSP48E1 configuration +
two 5-bit source operand addresses; context words are 40 bits = 32-bit
instruction + 8-bit FU tag, daisy-chained through the FU instruction ports.

TPU adaptation: there is no DSP48E1 to configure, so the "configuration"
field carries (opcode, dest-slot, const-index) which the TMFU kernel/VM
dispatches on directly with a branch table — no decode stage, matching the
paper's no-decoder philosophy.  Packing (32 bits):

    [31:27] opcode (5)   [26:22] dest slot (5)
    [21:17] srcA RF addr (5)     [16:12] srcB RF addr / const idx (5)
    [11: 0] dsp_cfg (12) — emulated DSP48E1 OPMODE/ALUMODE/INMODE image

Constants are pre-loaded into a small per-FU constant table at context-load
time (the RF is writable at init; paper Section III-A), addressed by the
srcB field for *C ops.  Context stream = one 40-bit word per instruction +
one per constant.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dfg import CONST_OPS, Op
from repro.core.schedule import Schedule

#: register-file / instruction-memory depth (paper: 32-entry RAM32M)
RF_DEPTH = 32
IM_DEPTH = 32
#: per-FU constant-table depth (carved from the top of the RF address space)
CONST_DEPTH = 8
#: bytes per 40-bit context word
CONTEXT_WORD_BYTES = 5

# Emulated DSP48E1 configuration images per opcode (OPMODE[6:0] ++ ALUMODE
# [3:0] ++ INMODE-ish bit).  Values chosen to match the DSP48E1 user guide's
# add/sub/mul opmodes; they are carried verbatim so the instruction word is
# bit-faithful even though the TPU backend dispatches on the opcode field.
_DSP_CFG = {
    Op.BYP:  0b000_0011_0000_0,
    Op.ADD:  0b000_0011_0011_0,
    Op.SUB:  0b011_0011_0011_0,
    Op.MUL:  0b000_0101_0101_1,
    Op.ADDC: 0b000_0011_0011_0,
    Op.SUBC: 0b011_0011_0011_0,
    Op.RSUBC: 0b011_0011_0011_1,
    Op.MULC: 0b000_0101_0101_1,
    Op.SQR:  0b000_0101_0101_1,
    Op.MAX:  0b010_0011_0011_0,
    Op.MIN:  0b010_0011_0011_1,
    Op.ABS:  0b010_0011_0000_0,
    Op.NEG:  0b011_0011_0000_0,
    Op.AND:  0b000_1111_0000_0,
    Op.OR:   0b000_1111_0001_0,
    Op.XOR:  0b000_1111_0010_0,
    Op.OUT:  0b000_0011_0000_0,
    Op.NOP:  0,
}


def pack_word(op: Op, dest: int, src_a: int, src_b: int) -> int:
    assert 0 <= dest < 32 and 0 <= src_a < 32 and 0 <= src_b < 32
    return (int(op) << 27) | (dest << 22) | (src_a << 17) | (src_b << 12) \
        | _DSP_CFG[op]


def unpack_word(w: int) -> tuple[Op, int, int, int]:
    return (Op((w >> 27) & 0x1F), (w >> 22) & 0x1F,
            (w >> 17) & 0x1F, (w >> 12) & 0x1F)


@dataclasses.dataclass
class StageImage:
    """Encoded instruction memory + constant table of one FU."""

    stage: int
    words: np.ndarray       # [n_instr] uint32
    consts: np.ndarray      # [n_consts] float32 (context-loaded)
    n_loads: int


@dataclasses.dataclass
class Program:
    """A fully encoded overlay kernel context ('the bitstream analogue')."""

    name: str
    images: tuple[StageImage, ...]
    n_inputs: int
    n_outputs: int
    ii: int

    @property
    def context_words(self) -> int:
        return sum(len(i.words) + len(i.consts) for i in self.images)

    @property
    def context_bytes(self) -> int:
        """Paper Section V: 65..410 B over the benchmark set."""
        return self.context_words * CONTEXT_WORD_BYTES

    def context_switch_cycles(self) -> int:
        """One daisy-chained 40-bit word per cycle (paper: worst case 82)."""
        return self.context_words

    def context_switch_us(self, f_mhz: float = 300.0) -> float:
        return self.context_switch_cycles() / f_mhz


class EncodeError(ValueError):
    pass


def encode(sched: Schedule) -> Program:
    """Encode a Schedule into per-FU instruction images.

    RF layout per FU: loads occupy addresses [0, n_loads); constants are
    addressed through the srcB field into the per-FU constant table.
    Results stream to the next FU in instruction order, so an instruction's
    dest slot is its position in the output stream.
    """
    images = []
    for prog in sched.stages:
        if prog.n_instrs > IM_DEPTH:
            raise EncodeError(
                f"{sched.dfg.name}: stage {prog.stage} needs "
                f"{prog.n_instrs} instruction slots > {IM_DEPTH}")
        if prog.n_loads > RF_DEPTH - CONST_DEPTH:
            raise EncodeError(
                f"{sched.dfg.name}: stage {prog.stage} streams "
                f"{prog.n_loads} words > RF capacity")
        addr = {v: i for i, v in enumerate(prog.loads)}
        consts: list[float] = []
        words = []
        for slot, ins in enumerate(prog.instrs):
            a = addr[ins.args[0]]
            if ins.op in CONST_OPS:
                consts.append(float(ins.imm))
                if len(consts) > CONST_DEPTH:
                    raise EncodeError(
                        f"{sched.dfg.name}: stage {prog.stage} needs "
                        f"{len(consts)} constants > {CONST_DEPTH}")
                b = len(consts) - 1
            elif len(ins.args) > 1:
                b = addr[ins.args[1]]
            else:
                b = a  # unary/SQR/BYP: srcB mirrors srcA (paper: 'SQR (R0 R0)')
            words.append(pack_word(ins.op, slot, a, b))
        images.append(StageImage(
            stage=prog.stage,
            words=np.asarray(words, dtype=np.uint32),
            consts=np.asarray(consts, dtype=np.float32),
            n_loads=prog.n_loads))
    return Program(name=sched.dfg.name, images=tuple(images),
                   n_inputs=len(sched.dfg.inputs),
                   n_outputs=len(sched.dfg.outputs), ii=sched.ii)
