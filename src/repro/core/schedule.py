"""Operation scheduling onto the linear time-multiplexed FU pipeline.

Implements the paper's scheduling methodology (Section IV, Table I):

  * ASAP staging — every op at ASAP level *s* executes on FU *s* (1-indexed),
    so #FUs = graph depth and the interconnect is a direct FU->FU link.
  * Bypass insertion — a value produced at level *p* and consumed at level
    *c* > *p*+1 occupies one BYP instruction slot in each intermediate FU
    (the linear interconnect is non-programmable, so data can only move one
    stage per pass).  Primary outputs produced before the last stage are
    bypassed to the end so they exit via the output FIFO.
  * Initiation interval —

        II = max_s(loads_s + instrs_s) + 2

    where loads_s is the number of words streamed into FU_s's register file
    per iteration (outputs of FU_{s-1}; primary inputs for FU_1), instrs_s
    counts arithmetic + bypass instructions, and the +2 covers the data
    output cycle and the pipeline flush (paper Section III: gradient II =
    5 loads + 4 ops + 1 out + 1 flush = 11).

  * Single-FU II = inputs + ops + 1 (paper: gradient on one FU = 5 + 11 + 1
    = 17); spatial overlay needs #FUs = op nodes with II = 1.

The cycle-accurate trace generator reproduces Table I: FU_s begins loading
two cycles after FU_{s-1} issues its first arithmetic op (the DSP block's
3-stage internal pipeline => result available 2 cycles after issue).
"""

from __future__ import annotations

import dataclasses

from repro.core.dfg import DFG, Node, Op

#: DSP48E1 internal pipeline: result available issue+DSP_LATENCY-1 cycles
#: later (paper: SUB issued cycle 6 arrives at FU1 on cycle 8).
DSP_LATENCY = 3
#: data-output + pipeline-flush cycles charged to the bottleneck stage.
FLUSH_CYCLES = 2


@dataclasses.dataclass(frozen=True)
class Instr:
    """One FU instruction slot (pre-encoding; see isa.py for bit packing)."""

    op: Op
    dest: str                 # value name this slot produces
    args: tuple[str, ...]     # value names read from the local RF
    imm: float | int | None = None
    node: str | None = None   # originating DFG node (None for BYP)


@dataclasses.dataclass
class StageProgram:
    """The instruction memory contents of one FU."""

    stage: int                       # 1-indexed FU position
    loads: tuple[str, ...]           # values streamed into the RF, in order
    instrs: tuple[Instr, ...]        # arithmetic first, then bypasses

    @property
    def n_loads(self) -> int:
        return len(self.loads)

    @property
    def n_instrs(self) -> int:
        return len(self.instrs)

    @property
    def cycles(self) -> int:
        return self.n_loads + self.n_instrs


@dataclasses.dataclass
class Schedule:
    """A DFG mapped onto the linear TM-FU pipeline."""

    dfg: DFG
    stages: tuple[StageProgram, ...]

    # ------------------------------------------------------------ paper model
    @property
    def n_fus(self) -> int:
        return len(self.stages)

    @property
    def ii(self) -> int:
        return max(s.cycles for s in self.stages) + FLUSH_CYCLES

    @property
    def single_fu_ii(self) -> int:
        return len(self.dfg.inputs) + self.dfg.n_ops + 1

    @property
    def spatial_fus(self) -> int:
        return self.dfg.n_ops

    @property
    def eopc(self) -> float:
        """Effective operations per cycle = op_nodes / II (Table II)."""
        return round(self.dfg.n_ops / self.ii, 1)

    @property
    def total_instrs(self) -> int:
        return sum(s.n_instrs for s in self.stages)

    @property
    def max_stage_instrs(self) -> int:
        return max(s.n_instrs for s in self.stages)

    def table2_row(self) -> dict:
        st = self.dfg.stats()
        st.update({"II": self.ii, "eOPC": self.eopc})
        return st

    # --------------------------------------------------------------- trace
    def cycle_trace(self, n_iters: int = 3) -> list[tuple[int, dict[int, str]]]:
        """Cycle-accurate steady-state trace (reproduces Table I).

        Returns [(cycle, {fu_index: activity})]; fu_index is 0-based like the
        paper's FU0..FU3.  Each FU repeats its (load*, op*) pattern with
        period II; FU_{s+1} starts loading DSP_LATENCY-1 cycles after FU_s
        issues its first instruction.
        """
        ii = self.ii
        first_load = []
        t = 1
        for s, prog in enumerate(self.stages):
            first_load.append(t)
            # next stage's first datum arrives when this stage's first op
            # completes the DSP pipeline
            t = t + prog.n_loads + (DSP_LATENCY - 1)
        horizon = first_load[-1] + self.stages[-1].cycles + (n_iters - 1) * ii
        rows: list[tuple[int, dict[int, str]]] = []
        for cyc in range(1, horizon + 1):
            acts: dict[int, str] = {}
            for s, prog in enumerate(self.stages):
                rel = cyc - first_load[s]
                if rel < 0:
                    continue
                ph = rel % ii
                if (cyc - first_load[s]) // ii >= n_iters:
                    continue
                if ph < prog.n_loads:
                    acts[s] = f"Load R{ph}"
                elif ph < prog.cycles:
                    ins = prog.instrs[ph - prog.n_loads]
                    regs = " ".join(
                        f"R{prog.loads.index(a)}" if a in prog.loads else a
                        for a in (ins.args if ins.op is not Op.SQR
                                  else (ins.args[0], ins.args[0])))
                    acts[s] = f"{ins.op.name} ({regs})"
            if acts:
                rows.append((cyc, acts))
        return rows


class ScheduleError(ValueError):
    pass


def schedule(dfg: DFG) -> Schedule:
    """ASAP-schedule ``dfg`` onto the linear TM-FU pipeline."""
    levels = dfg.asap_levels()
    depth = dfg.depth
    if depth == 0:
        raise ScheduleError(f"{dfg.name}: empty DFG")

    # ops per stage (stage s hosts ASAP level s)
    ops_at: dict[int, list[Node]] = {s: [] for s in range(1, depth + 1)}
    for n in dfg.topo_order():
        node = dfg.nodes[n]
        ops_at[levels[n]].append(node)

    # last level at which each value is consumed (outputs live to the end)
    last_use: dict[str, int] = {}
    for n, node in dfg.nodes.items():
        for a in node.args:
            last_use[a] = max(last_use.get(a, 0), levels[n])
    for o in dfg.outputs:
        last_use[o] = depth + 1  # must reach the output FIFO

    # walk the pipeline inserting bypasses: ``live`` is the ordered set of
    # values streamed into stage s (= outputs of stage s-1 / primary inputs)
    stages: list[StageProgram] = []
    live: list[str] = list(dfg.inputs)
    for s in range(1, depth + 1):
        instrs: list[Instr] = [
            Instr(op=node.op, dest=node.name, args=node.args, imm=node.imm,
                  node=node.name)
            for node in ops_at[s]
        ]
        produced = {i.dest for i in instrs}
        # bypass every live value still needed beyond this stage
        for v in live:
            if last_use.get(v, 0) > s and v not in produced:
                instrs.append(Instr(op=Op.BYP, dest=v, args=(v,)))
        stages.append(StageProgram(stage=s, loads=tuple(live),
                                   instrs=tuple(instrs)))
        # the hardware streams EVERY instruction result to the next stage in
        # instruction order; DFG validation guarantees none of them is dead.
        for i_ in instrs:
            if last_use.get(i_.dest, 0) <= s and s < depth:
                raise ScheduleError(
                    f"{dfg.name}: dead value {i_.dest!r} at stage {s}")
        live = [i.dest for i in instrs] if s < depth else \
            [i.dest for i in instrs if last_use.get(i.dest, 0) > s]

    # everything still live after the last stage must be a primary output
    extra = [v for v in live if v not in dfg.outputs]
    if extra:
        raise ScheduleError(f"{dfg.name}: values fall off the pipeline: {extra}")
    return Schedule(dfg=dfg, stages=tuple(stages))
