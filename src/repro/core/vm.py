"""Pure-JAX overlay virtual machine.

Executes an encoded overlay ``Program`` (isa.py) with *instructions as
data*: the executor is traced/compiled ONCE for a (max-stages, RF depth,
batch-tile) family, and a kernel change is a context switch — new int32
instruction words + constant tables are streamed in, nothing is recompiled.
This is the TPU analogue of the paper's daisy-chained 40-bit context load
(Section III-A) vs. the vendor-tool / partial-reconfiguration flow.

Semantics mirror the hardware: a linear cascade of stages (lax.scan = the
direct FU->FU interconnect); within a stage, a fori_loop time-multiplexes
the FU over its instruction memory; the register file holds the words
streamed from the previous stage; results stream out in instruction order.

The datapath is vectorized over a batch of independent kernel iterations
(the VPU-lane equivalent of replicating pipelines, paper Fig. 4).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import isa
from repro.core.dfg import CONST_OPS, DFG, Op
from repro.core.isa import IM_DEPTH, RF_DEPTH, Program

#: default maximum pipeline length: two cascaded 8-FU pipelines (paper V)
S_MAX = 16


def _branches(dtype):
    """Branch table indexed by Op — the no-decoder dispatch."""
    def _bitwise(fn):
        def g(a, b, imm):
            if jnp.issubdtype(dtype, jnp.floating):
                it = jnp.int32 if dtype.itemsize == 4 else jnp.int16
                ia = jax.lax.bitcast_convert_type(a, it)
                ib = jax.lax.bitcast_convert_type(b, it)
                return jax.lax.bitcast_convert_type(fn(ia, ib), dtype)
            return fn(a, b)
        return g

    return [
        lambda a, b, imm: a,                      # BYP
        lambda a, b, imm: a + b,                  # ADD
        lambda a, b, imm: a - b,                  # SUB
        lambda a, b, imm: a * b,                  # MUL
        lambda a, b, imm: a + imm,                # ADDC
        lambda a, b, imm: a - imm,                # SUBC
        lambda a, b, imm: imm - a,                # RSUBC
        lambda a, b, imm: a * imm,                # MULC
        lambda a, b, imm: a * a,                  # SQR
        lambda a, b, imm: jnp.maximum(a, b),      # MAX
        lambda a, b, imm: jnp.minimum(a, b),      # MIN
        lambda a, b, imm: jnp.abs(a),             # ABS
        lambda a, b, imm: -a,                     # NEG
        _bitwise(jnp.bitwise_and),                # AND
        _bitwise(jnp.bitwise_or),                 # OR
        _bitwise(jnp.bitwise_xor),                # XOR
        lambda a, b, imm: a,                      # OUT
        lambda a, b, imm: jnp.zeros_like(a),      # NOP
    ]


@dataclasses.dataclass(frozen=True)
class Context:
    """Device-resident overlay context (the '40-bit word stream' image)."""

    op: jax.Array      # [S_MAX, IM_DEPTH] int32
    src_a: jax.Array   # [S_MAX, IM_DEPTH] int32
    src_b: jax.Array   # [S_MAX, IM_DEPTH] int32
    imm: jax.Array     # [S_MAX, IM_DEPTH] dtype (const table, pre-gathered)
    out_idx: jax.Array  # [n_outputs] int32 — RF slots of the primary outputs
    n_inputs: int
    n_outputs: int
    context_bytes: int

    def tree(self):
        return (self.op, self.src_a, self.src_b, self.imm)


def make_context(program: Program, s_max: int = S_MAX,
                 dtype=jnp.float32) -> Context:
    """Encode a Program into dense executor arrays (context switch image)."""
    S = len(program.images)
    if S > s_max:
        raise ValueError(f"{program.name}: {S} stages > s_max={s_max}")
    # identity padding: BYP slot i -> rf[i]; pads both unused instruction
    # slots inside live stages (beyond that stage's stream) and whole stages.
    op = np.full((s_max, IM_DEPTH), int(Op.BYP), np.int32)
    a_ = np.tile(np.arange(IM_DEPTH, dtype=np.int32), (s_max, 1))
    b_ = a_.copy()
    imm = np.zeros((s_max, IM_DEPTH), np.float64)
    for s, img in enumerate(program.images):
        for slot, w in enumerate(img.words):
            o, dest, sa, sb = isa.unpack_word(int(w))
            assert dest == slot
            op[s, slot] = int(o)
            a_[s, slot] = sa
            if o in CONST_OPS:
                imm[s, slot] = float(img.consts[sb])
                b_[s, slot] = sa
            else:
                b_[s, slot] = sb
    # primary outputs: slots in the final stage's output stream
    final = program.images[-1]
    # stream order == instruction order; outputs are the last-stage dests
    # whose value names are the DFG outputs — recover via dest slots:
    # encode() guarantees dest slot == instruction position.
    out_idx = _output_slots(program)
    return Context(op=jnp.asarray(op), src_a=jnp.asarray(a_),
                   src_b=jnp.asarray(b_), imm=jnp.asarray(imm, dtype=dtype),
                   out_idx=jnp.asarray(out_idx, dtype=jnp.int32),
                   n_inputs=program.n_inputs, n_outputs=program.n_outputs,
                   context_bytes=program.context_bytes)


def _output_slots(program: Program) -> np.ndarray:
    # The Program does not carry value names; the schedule guarantees the
    # final stage's stream contains the outputs. We record output slots at
    # encode time via a side table attached by overlay.compile_program.
    slots = getattr(program, "_output_slots", None)
    if slots is None:
        # default: the last n_outputs instructions of the final stage
        n = len(program.images[-1].words)
        return np.arange(n - program.n_outputs, n, dtype=np.int32)
    return np.asarray(slots, dtype=np.int32)


def _vm_exec(ctx_tree, out_idx, x):
    """Shared executor core: x [rf_depth, batch] -> outputs [n_out, batch]."""
    op, src_a, src_b, imm = ctx_tree
    branches = _branches(x.dtype)

    def stage_fn(rf, stage):
        s_op, s_a, s_b, s_imm = stage

        def instr(i, out):
            va = rf[s_a[i]]
            vb = rf[s_b[i]]
            res = jax.lax.switch(s_op[i], branches, va, vb, s_imm[i])
            return out.at[i].set(res)

        out = jax.lax.fori_loop(0, op.shape[1], instr,
                                jnp.zeros_like(rf), unroll=True)
        return out, None

    rf, _ = jax.lax.scan(stage_fn, x, (op, src_a, src_b, imm))
    return rf[out_idx]


@partial(jax.jit, static_argnames=("rf_depth",))
def vm_exec(ctx_tree, out_idx, x, rf_depth: int = RF_DEPTH):
    """Run the overlay: x [rf_depth, batch] -> outputs [n_out, batch].

    ``x`` carries the primary inputs in slots [0, n_inputs); the caller pads.
    Compiled once per (shape, dtype); ctx_tree is data.
    """
    return _vm_exec(ctx_tree, out_idx, x)


def _vm_exec_multi(bank_tree, out_idx_bank, ctx_ids, x):
    def one(cid, xg):
        tree = tuple(leaf[cid] for leaf in bank_tree)
        return _vm_exec(tree, out_idx_bank[cid], xg)

    return jax.vmap(one)(ctx_ids, x)


@partial(jax.jit, static_argnames=("rf_depth",))
def vm_exec_multi(bank_tree, out_idx_bank, ctx_ids, x,
                  rf_depth: int = RF_DEPTH):
    """Multi-tenant executor: one compiled program serves a whole bank.

    ``bank_tree`` leaves are the ContextBank's stacked [N, S_MAX, IM_DEPTH]
    instruction arrays; ``out_idx_bank`` is [N, max_outputs] int32;
    ``ctx_ids`` is [G] int32 selecting a resident context per tile and ``x``
    is [G, rf_depth, tile].  Context selection is a pure gather on a traced
    id — a mixed-kernel batch runs through ONE executable, the serving-scale
    analogue of the paper's daisy-chained context stream (no re-place/route,
    no XLA retrace; the switch cost is an index).

    Returns [G, max_outputs, tile]; callers slice each tile's rows down to
    the selected kernel's n_outputs.
    """
    return _vm_exec_multi(bank_tree, out_idx_bank, ctx_ids, x)


@partial(jax.jit, static_argnames=("rf_depth",), donate_argnums=(3,))
def vm_exec_multi_donated(bank_tree, out_idx_bank, ctx_ids, x,
                          rf_depth: int = RF_DEPTH):
    """``vm_exec_multi`` with the tile stack DONATED to the executable.

    Same trace, separate jit cache: ``x`` (the round's [G, rf_depth, tile]
    staging transfer — by far the largest per-round allocation) is handed
    to XLA for reuse/free at launch instead of surviving until the round
    retires.  Caller contract: ``x`` is dead after this call — reading it
    again raises.  The serving engines consume each batch exactly once,
    so they opt in via ``Overlay(donate=True)``; the sync ``dispatch``
    oracle keeps the non-donating entry point.
    """
    return _vm_exec_multi(bank_tree, out_idx_bank, ctx_ids, x)


def pad_inputs(xs: list[jax.Array], rf_depth: int = RF_DEPTH,
               device=None) -> jax.Array:
    """Stack primary inputs into the [rf_depth, batch] RF image.

    ``device`` commits the image (and thus the execution that consumes it)
    to a specific device — required when the context it will run against
    is pinned to a non-default device (sharded serving replicas), where
    implicit default-device placement would be a cross-device error.
    """
    batch = xs[0].shape
    x = jnp.zeros((rf_depth, *batch), dtype=xs[0].dtype)
    for i, v in enumerate(xs):
        x = x.at[i].set(v)
    if device is not None:
        x = jax.device_put(x, device)
    return x


# ------------------------------------------------------------------- oracle
def dfg_eval(dfg: DFG, env: dict[str, jax.Array]) -> dict[str, jax.Array]:
    """Direct jnp evaluation of the DFG — the functional oracle."""
    vals = dict(env)
    for n in dfg.topo_order():
        node = dfg.nodes[n]
        a = vals[node.args[0]]
        b = vals[node.args[1]] if len(node.args) > 1 else a
        imm = node.imm
        fn = {
            Op.BYP: lambda: a, Op.ADD: lambda: a + b, Op.SUB: lambda: a - b,
            Op.MUL: lambda: a * b, Op.ADDC: lambda: a + imm,
            Op.SUBC: lambda: a - imm, Op.RSUBC: lambda: imm - a,
            Op.MULC: lambda: a * imm, Op.SQR: lambda: a * a,
            Op.MAX: lambda: jnp.maximum(a, b),
            Op.MIN: lambda: jnp.minimum(a, b),
            Op.ABS: lambda: jnp.abs(a), Op.NEG: lambda: -a,
        }[node.op]
        vals[n] = fn()
    return {o: vals[o] for o in dfg.outputs}
