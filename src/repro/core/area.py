"""Analytical area / throughput / context-switch models (paper Section V).

These models reproduce every row of Tables II and III from schedule-derived
quantities; the constants come straight from the paper:

  * FU cost: 1 DSP48E1 + 81 slices; 1 DSP ≙ 60 slices on the Zynq
    XC7Z020 => 141 e-Slices per FU.
  * Pipeline clock f = 300 MHz (8-FU pipeline on Zynq: 303 MHz).
  * Throughput = op_nodes / II × f   (GOPS)   — verified to reproduce
    Table III column 'Tput' for all 8 benchmarks.
  * Area(e-Slices) = #FUs × 141                — verified: Table III 'Area'.
  * Context switch: one 40-bit word / cycle; paper worst case 82 words =
    410 B = 0.27 µs @ 300 MHz, vs 13 µs (SCFU-SCN [13]) and 200 µs (PR).
"""

from __future__ import annotations

import dataclasses

#: single-FU implementation cost on Zynq XC7Z020 (ISE 14.6, paper III-A)
FU_DSP = 1
FU_LUTS = 160
FU_FFS = 293
FU_FMAX_MHZ = 325.0
#: 8-FU pipeline + 2 I/O FIFOs
PIPE8_DSP = 8
PIPE8_LUTS = 808
PIPE8_FFS = 1077
PIPE8_FMAX_MHZ = 303.0
VIRTEX7_FMAX_MHZ = 600.0

DSP_TO_SLICES = 60
FU_SLICES = 81
FU_ESLICES = FU_DSP * DSP_TO_SLICES + FU_SLICES  # = 141

F_CLK_MHZ = 300.0

#: published comparison points (paper Section V)
SCFU_CONTEXT_US = 13.0
PR_CONTEXT_US = 200.0
PR_BITSTREAM_BYTES = 75 * 1024


def area_eslices(n_fus: int) -> int:
    return n_fus * FU_ESLICES


def pipelines_needed(n_fus: int, pipe_len: int = 8) -> int:
    """Benchmarks needing >8 FUs cascade two 8-FU pipelines (Section V)."""
    return -(-n_fus // pipe_len)


def throughput_gops(n_ops: int, ii: int, f_mhz: float = F_CLK_MHZ) -> float:
    return n_ops / ii * f_mhz / 1000.0


def mops_per_eslice(n_ops: int, ii: int, n_fus: int,
                    f_mhz: float = F_CLK_MHZ) -> float:
    return throughput_gops(n_ops, ii, f_mhz) * 1000.0 / area_eslices(n_fus)


@dataclasses.dataclass(frozen=True)
class PaperRow:
    """One published benchmark row (Tables II + III)."""

    name: str
    n_in: int
    n_out: int
    edges: int
    ops: int
    depth: int
    parallelism: float
    ii: int
    eopc: float
    tput_gops: float          # proposed overlay
    area_eslices: int         # proposed overlay
    scfu_tput: float          # SCFU-SCN overlay [13]
    scfu_area: int
    hls_tput: float           # Vivado HLS
    hls_area: int


#: Tables II & III verbatim.
PAPER_ROWS: tuple[PaperRow, ...] = (
    PaperRow("chebyshev", 1, 1, 12, 7, 7, 1.00, 6, 1.2,
             0.35, 987, 2.35, 1900, 2.21, 265),
    PaperRow("sgfilter", 2, 1, 27, 18, 9, 2.00, 10, 1.8,
             0.54, 1269, 6.03, 4560, 4.59, 645),
    PaperRow("mibench", 3, 1, 22, 13, 6, 2.16, 11, 1.2,
             0.35, 846, 4.36, 3040, 3.51, 305),
    PaperRow("qspline", 7, 1, 50, 26, 8, 3.25, 18, 1.4,
             0.43, 1128, 8.71, 8360, 6.11, 1270),
    PaperRow("poly5", 3, 1, 43, 27, 9, 3.00, 14, 1.9,
             0.58, 1269, 9.05, 6460, 7.02, 765),
    PaperRow("poly6", 3, 1, 72, 44, 11, 4.00, 17, 2.6,
             0.78, 1551, 14.74, 11400, 11.88, 1455),
    PaperRow("poly7", 3, 1, 62, 39, 13, 3.00, 17, 2.3,
             0.69, 1833, 13.07, 10640, 10.92, 1025),
    PaperRow("poly8", 3, 1, 51, 32, 11, 2.90, 15, 2.1,
             0.64, 1551, 10.72, 7220, 8.32, 1025),
)

PAPER_BY_NAME = {r.name: r for r in PAPER_ROWS}
