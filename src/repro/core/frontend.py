"""HLL -> DFG conversion (paper Section IV, 'HLL to DFG Conversion').

The paper's in-house flow converts a C compute-kernel description into a DFG
text description.  We accept the equivalent: a small C-like/Python-like
kernel body of assignments over the primary inputs, e.g.::

    build_dfg("gradient", inputs=["m1","m2","m3","m4","m5"], source='''
        d1 = m1 - m3
        d2 = m2 - m3
        d3 = m3 - m4
        d4 = m3 - m5
        s1 = d1 * d1
        s2 = d2 * d2
        s3 = d3 * d3
        s4 = d4 * d4
        a1 = s1 + s2
        a2 = s3 + s4
        out = a1 + a2
    ''', outputs=["out"])

Supported: + - * (binary), unary -, abs/min/max, constants folded into
const-op immediates (ADDC/SUBC/RSUBC/MULC), x*x recognised as SQR.
Common-subexpression reuse happens through named temporaries, exactly as in
the paper's DFG figures.
"""

from __future__ import annotations

import ast

from repro.core.dfg import DFG, DFGError, Node, Op

_BINOPS = {ast.Add: Op.ADD, ast.Sub: Op.SUB, ast.Mult: Op.MUL}
_CALLS = {"abs": Op.ABS, "min": Op.MIN, "max": Op.MAX}


class _Builder:
    def __init__(self, inputs: list[str]):
        self.inputs = list(inputs)
        self.nodes: list[Node] = []
        self.names: set[str] = set(inputs)
        self._tmp = 0

    def fresh(self) -> str:
        self._tmp += 1
        return f"_t{self._tmp}"

    def emit(self, name: str | None, op: Op, args: tuple[str, ...],
             imm=None) -> str:
        name = name or self.fresh()
        if name in self.names:
            raise DFGError(f"single-assignment violated for {name!r}")
        self.nodes.append(Node(name=name, op=op, args=args, imm=imm))
        self.names.add(name)
        return name

    # Returns either a value name (str) or a python constant (int/float).
    def eval_expr(self, e: ast.expr, target: str | None = None):
        if isinstance(e, ast.Constant):
            return e.value
        if isinstance(e, ast.Name):
            if e.id not in self.names:
                raise DFGError(f"use of undefined name {e.id!r}")
            if target is not None:
                # alias: materialize as a bypass so SSA naming holds
                return self.emit(target, Op.BYP, (e.id,))
            return e.id
        if isinstance(e, ast.UnaryOp) and isinstance(e.op, ast.USub):
            v = self.eval_expr(e.operand)
            if isinstance(v, (int, float)):
                return -v
            return self.emit(target, Op.NEG, (v,))
        if isinstance(e, ast.Call) and isinstance(e.func, ast.Name):
            op = _CALLS.get(e.func.id)
            if op is None:
                raise DFGError(f"unsupported call {e.func.id!r}")
            args = [self.eval_expr(a) for a in e.args]
            if any(isinstance(a, (int, float)) for a in args):
                raise DFGError(f"{e.func.id} over constants unsupported")
            return self.emit(target, op, tuple(args))
        if isinstance(e, ast.BinOp):
            opty = type(e.op)
            if opty not in _BINOPS:
                raise DFGError(f"unsupported operator {opty.__name__}")
            lhs = self.eval_expr(e.left)
            rhs = self.eval_expr(e.right)
            lc = isinstance(lhs, (int, float))
            rc = isinstance(rhs, (int, float))
            if lc and rc:  # constant fold
                return {ast.Add: lhs + rhs, ast.Sub: lhs - rhs,
                        ast.Mult: lhs * rhs}[opty]
            if lc or rc:
                const = lhs if lc else rhs
                val = rhs if lc else lhs
                if opty is ast.Add:
                    return self.emit(target, Op.ADDC, (val,), imm=const)
                if opty is ast.Mult:
                    return self.emit(target, Op.MULC, (val,), imm=const)
                # Sub: val - const  or  const - val
                if rc:
                    return self.emit(target, Op.SUBC, (val,), imm=const)
                return self.emit(target, Op.RSUBC, (val,), imm=const)
            if lhs == rhs and opty is ast.Mult:
                return self.emit(target, Op.SQR, (lhs,))
            return self.emit(target, _BINOPS[opty], (lhs, rhs))
        raise DFGError(f"unsupported expression {ast.dump(e)}")


def build_dfg(name: str, inputs: list[str], source: str,
              outputs: list[str]) -> DFG:
    """Compile a kernel body (sequence of assignments) to a DFG."""
    tree = ast.parse(source)
    b = _Builder(inputs)
    for stmt in tree.body:
        if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1 \
                or not isinstance(stmt.targets[0], ast.Name):
            raise DFGError("kernel body must be simple assignments")
        tgt = stmt.targets[0].id
        v = b.eval_expr(stmt.value, target=tgt)
        if isinstance(v, (int, float)):
            raise DFGError(f"{tgt!r} is a constant; fold it instead")
    return DFG.build(name, inputs, b.nodes, outputs)
