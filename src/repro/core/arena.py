"""Pooled host staging buffers for the round pipeline (zero-copy assemble).

Every dispatch round needs one ``[G_pad, RF_DEPTH, tile]`` host tile stack
plus a ``[G_pad]`` context-id vector.  Allocating those fresh per round is
the single biggest host cost on the serving hot path: a large ``np.zeros``
(page-fault memset), the per-group ``np.concatenate`` intermediates, and a
``reshape(...).transpose(...)`` copy — four full-buffer passes around a
device launch that is itself one fused executable (the "overlay tax" of
JIT-assembled overlays, arXiv:1603.01187).

``RoundArena`` removes the allocation half of that tax.  Blocks are pooled
in free lists bucketed by ``(g_pad, rf_depth, tile, dtype)`` — the same
power-of-two ``g_pad`` bucketing the executor uses, so a steady workload
cycles through a handful of buckets and the pool converges to
``max_inflight + 1`` blocks per bucket.  A checked-out block is guaranteed
all-zero in every row a scatter could have dirtied before: each block
tracks a ``dirty_rows`` high-water mark (the max register-file row any
round ever wrote) and checkout scrubs only ``x[:, :dirty_rows, :]`` —
typically a handful of input rows, not the full ``RF_DEPTH`` image.

Lifecycle (mirrors the plan-pin protocol in ``core.overlay``)::

    block = arena.checkout(g_pad, tile, dtype)   # assemble (scatter into it)
    ...                                          # device copies it on launch
    arena.recycle(block)                         # plan.release(), post-collect

``jnp.asarray`` / ``jax.device_put`` of a numpy array COPIES onto the
device buffer, so the host block is safe to recycle as soon as the launch
has consumed it; the engine recycles at ``plan.release(bank)``, which it
already calls exactly once per round after delivery.  The sync
``Overlay.dispatch`` oracle never uses an arena (its collect is lazy, so
there is no single safe recycle point) — arenas are an engine-path
optimisation, opted into via ``Overlay(arena=...)``.

Thread safety: checkout/recycle take a small lock (the pump thread and a
caller thread may race); the scatter into a checked-out block is lock-free
because a block is owned by exactly one round between checkout and recycle.
"""

from __future__ import annotations

import threading

import numpy as np

from repro.core.isa import RF_DEPTH

#: free-list depth per shape bucket; beyond this, recycled blocks are
#: dropped (a burst of odd shapes must not pin host memory forever)
DEFAULT_MAX_FREE_PER_BUCKET = 8


class ArenaBlock:
    """One pooled ``([g_pad, rf_depth, tile] x, [g_pad] ids)`` staging pair.

    ``dirty_rows`` is the block's register-file-row high-water mark: rows
    ``>= dirty_rows`` of ``x`` are guaranteed zero.  A scatter that writes
    rows ``[0, n)`` must raise it to at least ``n`` (``Overlay.assemble``
    does); checkout scrubs ``[0, dirty_rows)`` back to zero so a recycled
    block is bit-identical to a fresh ``np.zeros``.
    """

    __slots__ = ("x", "ids", "bucket", "dirty_rows")

    def __init__(self, x: np.ndarray, ids: np.ndarray, bucket: tuple):
        self.x = x
        self.ids = ids
        self.bucket = bucket
        self.dirty_rows = 0

    @property
    def nbytes(self) -> int:
        return self.x.nbytes + self.ids.nbytes


class RoundArena:
    """Shape-bucketed pool of reusable host staging blocks."""

    def __init__(self, max_free_per_bucket: int = DEFAULT_MAX_FREE_PER_BUCKET):
        self.max_free_per_bucket = max_free_per_bucket
        self._free: dict[tuple, list[ArenaBlock]] = {}
        self._lock = threading.Lock()
        # counters (read via stats(); arena leaks show up as outstanding
        # never returning to zero instead of as silent RSS growth)
        self.allocations = 0      # fresh np.zeros blocks ever created
        self.checkouts = 0        # blocks handed to rounds
        self.recycles = 0         # blocks returned to a free list
        self.discards = 0         # returned blocks dropped (bucket full)
        self.outstanding = 0      # checked out and not yet recycled
        self.peak_outstanding = 0
        self.pooled_bytes = 0     # bytes currently parked in free lists

    # ------------------------------------------------------------ lifecycle
    def checkout(self, g_pad: int, tile: int, dtype,
                 rf_depth: int = RF_DEPTH) -> ArenaBlock:
        """Hand out an all-zero ``[g_pad, rf_depth, tile]`` block."""
        key = (int(g_pad), int(rf_depth), int(tile), np.dtype(dtype).str)
        with self._lock:
            free = self._free.get(key)
            block = free.pop() if free else None
            if block is not None:
                self.pooled_bytes -= block.nbytes
            self.checkouts += 1
            self.outstanding += 1
            self.peak_outstanding = max(self.peak_outstanding,
                                        self.outstanding)
            if block is None:
                self.allocations += 1
        if block is None:
            block = ArenaBlock(
                x=np.zeros((g_pad, rf_depth, tile), np.dtype(dtype)),
                ids=np.zeros(g_pad, np.int32), bucket=key)
        elif block.dirty_rows:
            # scrub only the rows any past round wrote; rows above the
            # high-water mark are provably still zero
            block.x[:, :block.dirty_rows, :] = 0
            block.dirty_rows = 0
        return block

    def recycle(self, block: ArenaBlock | None) -> None:
        """Return a block to its bucket's free list (idempotent on None)."""
        if block is None:
            return
        with self._lock:
            self.outstanding -= 1
            free = self._free.setdefault(block.bucket, [])
            if len(free) < self.max_free_per_bucket:
                free.append(block)
                self.recycles += 1
                self.pooled_bytes += block.nbytes
            else:
                self.discards += 1

    # ------------------------------------------------------------ telemetry
    def stats(self) -> dict:
        with self._lock:
            return {
                "buckets": len(self._free),
                "free_blocks": sum(len(v) for v in self._free.values()),
                "allocations": self.allocations,
                "checkouts": self.checkouts,
                "recycles": self.recycles,
                "discards": self.discards,
                "outstanding": self.outstanding,
                "peak_outstanding": self.peak_outstanding,
                "pooled_bytes": self.pooled_bytes,
            }
