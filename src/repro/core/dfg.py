"""Feed-forward data-flow-graph IR — the overlay's compile target.

The paper's overlay executes *feed-forward DFGs* (Section III): nodes are
arithmetic operations, edges carry 32-bit values, primary inputs stream in
from a FIFO and primary outputs stream out.  This module is the IR that the
frontend produces and the scheduler consumes.

Conventions (used to reproduce Table II):
  * ``op nodes``    — arithmetic nodes only (not i/o nodes, not constants).
  * ``graph depth`` — max ASAP level over op nodes (inputs are level 0);
                      equals the number of FUs in the linear overlay.
  * ``edges``       — non-constant operand references plus one edge per
                      primary output (op -> o-node).
  * ``average parallelism`` — op_nodes / depth.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, Mapping, Sequence


class Op(enum.IntEnum):
    """Overlay opcode set (DSP48E1-expressible ops, paper Section III-A).

    The DSP48E1 ALU supports add/sub/mul (and logic ops) selected by
    configuration bits; const variants fold one immediate operand, matching
    the paper's 32-bit no-decoder instruction word.
    """

    BYP = 0    # data bypass (forward operand A to the next stage)
    ADD = 1    # a + b
    SUB = 2    # a - b
    MUL = 3    # a * b
    ADDC = 4   # a + imm
    SUBC = 5   # a - imm
    RSUBC = 6  # imm - a
    MULC = 7   # a * imm
    SQR = 8    # a * a (encoded as MUL with both operands = A)
    MAX = 9    # max(a, b)
    MIN = 10   # min(a, b)
    ABS = 11   # |a|
    NEG = 12   # -a
    AND = 13   # bitwise/logical and (integer datapath)
    OR = 14
    XOR = 15
    OUT = 16   # stream result to the output FIFO (scheduler-inserted)
    NOP = 17


#: ops that reference two distinct value operands
BINARY_OPS = frozenset({Op.ADD, Op.SUB, Op.MUL, Op.MAX, Op.MIN,
                        Op.AND, Op.OR, Op.XOR})
#: ops with one value operand + one immediate
CONST_OPS = frozenset({Op.ADDC, Op.SUBC, Op.RSUBC, Op.MULC})
#: unary ops with a single value operand reference
UNARY_OPS = frozenset({Op.ABS, Op.NEG, Op.BYP})
#: SQR references its single operand twice (a * a) — counts as 2 edges,
#: matching the paper's Fig. 1(b) 'SQR (R0 R0)' two-register encoding.
SELF_OPS = frozenset({Op.SQR})


@dataclasses.dataclass(frozen=True)
class Node:
    """One DFG node.

    ``args`` are names of producer nodes (inputs or other ops).  ``imm`` is
    the folded immediate for ``CONST_OPS``.
    """

    name: str
    op: Op
    args: tuple[str, ...] = ()
    imm: float | int | None = None

    def value_refs(self) -> tuple[str, ...]:
        """Operand references that carry values (for edge counting)."""
        if self.op in SELF_OPS:
            return (self.args[0], self.args[0])
        return self.args


class DFGError(ValueError):
    pass


@dataclasses.dataclass
class DFG:
    """A feed-forward DFG: primary inputs, op nodes, primary outputs."""

    name: str
    inputs: tuple[str, ...]
    nodes: dict[str, Node]
    outputs: tuple[str, ...]

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, name: str, inputs: Sequence[str],
              nodes: Iterable[Node], outputs: Sequence[str]) -> "DFG":
        node_map: dict[str, Node] = {}
        for n in nodes:
            if n.name in node_map or n.name in inputs:
                raise DFGError(f"duplicate node name {n.name!r}")
            node_map[n.name] = n
        g = cls(name=name, inputs=tuple(inputs), nodes=node_map,
                outputs=tuple(outputs))
        g.validate()
        return g

    # --------------------------------------------------------------- validate
    def validate(self) -> None:
        defined = set(self.inputs)
        order = self.topo_order()
        for nname in order:
            node = self.nodes[nname]
            for a in node.args:
                if a not in defined:
                    raise DFGError(
                        f"{self.name}: node {nname!r} uses undefined {a!r}")
            defined.add(nname)
        for o in self.outputs:
            if o not in self.nodes:
                raise DFGError(f"{self.name}: output {o!r} is not an op node")
        # dead code is illegal: the linear pipeline streams every FU result
        # forward, so an unconsumed non-output value has no legal slot.
        consumed: set[str] = set(self.outputs)
        for node in self.nodes.values():
            consumed.update(node.args)
        for n in self.nodes:
            if n not in consumed:
                raise DFGError(f"{self.name}: dead node {n!r}")
        for i in self.inputs:
            if i not in consumed:
                raise DFGError(f"{self.name}: unused input {i!r}")
        arity = {**{op: 2 for op in BINARY_OPS},
                 **{op: 1 for op in CONST_OPS | UNARY_OPS | SELF_OPS}}
        for node in self.nodes.values():
            want = arity.get(node.op)
            if want is not None and len(node.args) != want:
                raise DFGError(
                    f"{self.name}: {node.name} op {node.op.name} wants "
                    f"{want} args, got {len(node.args)}")
            if node.op in CONST_OPS and node.imm is None:
                raise DFGError(f"{self.name}: {node.name} missing imm")

    # ------------------------------------------------------------------- topo
    def topo_order(self) -> list[str]:
        """Deterministic topological order (Kahn, insertion-stable)."""
        indeg = {n: 0 for n in self.nodes}
        consumers: dict[str, list[str]] = {n: [] for n in self.nodes}
        for n, node in self.nodes.items():
            for a in node.args:
                if a in self.nodes:
                    indeg[n] += 1
                    consumers[a].append(n)
        ready = [n for n in self.nodes if indeg[n] == 0]
        out: list[str] = []
        while ready:
            n = ready.pop(0)
            out.append(n)
            for c in consumers[n]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if len(out) != len(self.nodes):
            raise DFGError(f"{self.name}: cycle detected (not feed-forward)")
        return out

    # ------------------------------------------------------------------ levels
    def asap_levels(self) -> dict[str, int]:
        """ASAP level per node; primary inputs are level 0."""
        level: dict[str, int] = {i: 0 for i in self.inputs}
        for n in self.topo_order():
            node = self.nodes[n]
            lv = 0
            for a in node.args:
                lv = max(lv, level[a])
            level[n] = lv + 1
        return level

    # ------------------------------------------------------------------- stats
    @property
    def n_ops(self) -> int:
        return len(self.nodes)

    @property
    def depth(self) -> int:
        lv = self.asap_levels()
        return max((lv[n] for n in self.nodes), default=0)

    @property
    def n_edges(self) -> int:
        refs = sum(len(n.value_refs()) for n in self.nodes.values())
        return refs + len(self.outputs)

    def stats(self) -> dict[str, float]:
        """Table II columns derivable from the graph alone."""
        d = self.depth
        return {
            "io_nodes": (len(self.inputs), len(self.outputs)),
            "graph_edges": self.n_edges,
            "op_nodes": self.n_ops,
            "graph_depth": d,
            "average_parallelism": round(self.n_ops / d, 2) if d else 0.0,
        }

    def consumers_by_level(self) -> dict[str, list[int]]:
        """For each value (input or op), the ASAP levels that consume it."""
        lv = self.asap_levels()
        uses: dict[str, list[int]] = {}
        for n in self.topo_order():
            node = self.nodes[n]
            for a in set(node.args):
                uses.setdefault(a, []).append(lv[n])
        return uses
