"""Multi-tenant context bank: N resident kernel contexts, one executor.

The paper's area argument restated at serving scale (Sections III/V): a
single time-multiplexed FU pipeline hosts *many* kernels because a kernel
is just a context — a stream of 40-bit instruction words — and switching
costs 0.27 us, not a reconfiguration.  Here the bank stacks N encoded
contexts on device as [N, S_MAX, IM_DEPTH] arrays; ``vm_exec_multi`` (and
the Pallas ``tmfu_pipeline_multi``) select a context by int32 id with a
pure gather, so a mixed-kernel request batch runs through ONE compiled
executable and the context switch is literally an index.

Residency is managed LRU: loading a kernel into a full bank evicts the
least-recently-used resident and reuses its slot id.  All updates are
functional (``.at[slot].set``) — the executor never recompiles, only the
instruction data moves, mirroring the daisy-chain context load.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict

import jax.numpy as jnp
import numpy as np

from repro.core.dfg import Op
from repro.core.isa import IM_DEPTH, Program
from repro.core.vm import S_MAX, make_context

#: default number of resident contexts (two cascaded 8-kernel groups)
DEFAULT_CAPACITY = 8
#: default output-slot padding width shared by every resident kernel
DEFAULT_MAX_OUTPUTS = 8


class BankError(ValueError):
    pass


def context_key(kernel) -> tuple[str, str]:
    """Content identity of a kernel's encoded context.

    Residency and dispatch grouping key on this — (name, digest of the
    encoded instruction words + constant tables) — so two different
    programs that happen to share a name can never alias each other in the
    bank.  Cached on the Program object (encoding is immutable post-build).
    """
    program: Program = getattr(kernel, "program", kernel)
    key = getattr(program, "_context_key", None)
    if key is None:
        h = hashlib.sha1()
        for img in program.images:
            h.update(np.asarray(img.words, np.uint32).tobytes())
            h.update(np.asarray(img.consts, np.float32).tobytes())
            h.update(bytes([img.n_loads]))
        h.update(np.asarray(getattr(program, "_output_slots", []),
                            np.int32).tobytes())
        key = (program.name, h.hexdigest())
        program._context_key = key
    return key


class ContextBank:
    """Fixed-capacity, LRU-managed store of device-resident contexts.

    All instruction state lives in four stacked arrays whose leading axis
    is the slot id; ``tree()`` hands them to ``vm_exec_multi`` /
    ``tmfu_pipeline_multi`` unchanged.  ``out_idx`` rows are padded to
    ``max_outputs`` (pad rows repeat slot 0 — harmless, callers slice to
    the kernel's real ``n_outputs``).
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 s_max: int = S_MAX, dtype=jnp.float32,
                 max_outputs: int = DEFAULT_MAX_OUTPUTS):
        if capacity < 1:
            raise BankError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.s_max = s_max
        self.dtype = dtype
        self.max_outputs = max_outputs
        # identity padding for empty slots: BYP slot i <- rf[i], like
        # make_context's padding, so an unloaded slot is a pure pass-through
        ident = np.tile(np.arange(IM_DEPTH, dtype=np.int32),
                        (capacity, s_max, 1))
        self.op = jnp.full((capacity, s_max, IM_DEPTH), int(Op.BYP),
                           jnp.int32)
        self.src_a = jnp.asarray(ident)
        self.src_b = jnp.asarray(ident)
        self.imm = jnp.zeros((capacity, s_max, IM_DEPTH), dtype)
        self.out_idx = jnp.zeros((capacity, max_outputs), jnp.int32)
        #: residency map: context_key -> slot, MRU last
        self._lru: OrderedDict[tuple[str, str], int] = OrderedDict()
        self._free = list(range(capacity))
        self._meta: dict[int, dict] = {}  # slot -> {name, n_inputs, n_outputs}
        #: host-side cache of encoded contexts, so an eviction reload is a
        #: pure device write (no re-run of the Python encode loop); bounded
        #: LRU (4x capacity) so a churning tenant population cannot pin the
        #: device arrays of every kernel ever seen
        self._ctx_cache: OrderedDict[tuple[str, str], object] = OrderedDict()
        self._ctx_cache_cap = 4 * capacity
        self.n_loads = 0
        self.n_evictions = 0
        self.n_hits = 0

    # ------------------------------------------------------------- residency
    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, kernel) -> bool:
        """Membership by kernel/Program (exact content) or by name (str)."""
        if isinstance(kernel, str):
            return any(k[0] == kernel for k in self._lru)
        return context_key(kernel) in self._lru

    @property
    def resident(self) -> tuple[str, ...]:
        """Resident kernel names, LRU first."""
        return tuple(name for name, _ in self._lru)

    def slot_of(self, kernel) -> int | None:
        """Slot id of a resident kernel (touches LRU), else None."""
        key = context_key(kernel)
        slot = self._lru.get(key)
        if slot is not None:
            self._lru.move_to_end(key)
            self.n_hits += 1
        return slot

    def meta(self, slot: int) -> dict:
        return self._meta[slot]

    # ----------------------------------------------------------------- load
    def load(self, kernel) -> int:
        """Make a kernel resident and return its slot id.

        ``kernel`` is an ``overlay.CompiledKernel`` (or a bare ``Program``).
        Residency is keyed on context CONTENT (see ``context_key``), so a
        same-named but different program is a distinct tenant, never an
        alias.  A resident kernel is an LRU touch; otherwise the context
        image is written into a free slot, evicting the LRU resident when
        the bank is full (its slot id is reused by the newcomer).
        """
        program: Program = getattr(kernel, "program", kernel)
        key = context_key(program)
        name = program.name
        slot = self._lru.get(key)
        if slot is not None:
            self._lru.move_to_end(key)
            self.n_hits += 1
            return slot
        ctx = self._ctx_cache.get(key)
        if ctx is None:
            ctx = make_context(program, self.s_max, self.dtype)
            self._ctx_cache[key] = ctx
            while len(self._ctx_cache) > self._ctx_cache_cap:
                self._ctx_cache.popitem(last=False)
        else:
            self._ctx_cache.move_to_end(key)
        if ctx.n_outputs > self.max_outputs:
            raise BankError(
                f"{name}: {ctx.n_outputs} outputs > bank max_outputs="
                f"{self.max_outputs}")
        if self._free:
            slot = self._free.pop(0)
        else:
            _evicted, slot = self._lru.popitem(last=False)
            del self._meta[slot]
            self.n_evictions += 1
        self.op = self.op.at[slot].set(ctx.op)
        self.src_a = self.src_a.at[slot].set(ctx.src_a)
        self.src_b = self.src_b.at[slot].set(ctx.src_b)
        self.imm = self.imm.at[slot].set(ctx.imm)
        out_pad = np.zeros(self.max_outputs, np.int32)
        out_pad[:ctx.n_outputs] = np.asarray(ctx.out_idx)
        self.out_idx = self.out_idx.at[slot].set(jnp.asarray(out_pad))
        self._meta[slot] = {"name": name, "n_inputs": ctx.n_inputs,
                            "n_outputs": ctx.n_outputs,
                            "context_bytes": ctx.context_bytes}
        self._lru[key] = slot
        self.n_loads += 1
        return slot

    # ------------------------------------------------------------- executor
    def tree(self):
        """The stacked instruction arrays, in vm_exec_multi leaf order."""
        return (self.op, self.src_a, self.src_b, self.imm)

    def stats(self) -> dict:
        return {"capacity": self.capacity, "resident": len(self),
                "loads": self.n_loads, "evictions": self.n_evictions,
                "hits": self.n_hits}
