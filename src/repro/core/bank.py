"""Multi-tenant context bank: N resident kernel contexts, one executor.

The paper's area argument restated at serving scale (Sections III/V): a
single time-multiplexed FU pipeline hosts *many* kernels because a kernel
is just a context — a stream of 40-bit instruction words — and switching
costs 0.27 us, not a reconfiguration.  Here the bank stacks N encoded
contexts on device as [N, S_MAX, IM_DEPTH] arrays; ``vm_exec_multi`` (and
the Pallas ``tmfu_pipeline_multi``) select a context by int32 id with a
pure gather, so a mixed-kernel request batch runs through ONE compiled
executable and the context switch is literally an index.

Residency is managed LRU: loading a kernel into a full bank evicts the
least-recently-used resident and reuses its slot id.  All updates are
functional (``.at[slot].set``) — the executor never recompiles, only the
instruction data moves, mirroring the daisy-chain context load.

Pipeline-safety hooks for the async serving engine
(``launch.serve.OverlayServer``):

* ``pin`` / ``unpin`` — refcounted eviction guards.  The engine pins every
  context referenced by an in-flight round between ``Overlay.plan`` (slot
  assignment) and ``Overlay.collect`` (result delivery), so planning round
  N+1 can never reassign a slot that round N's device launch is about to
  read.  Eviction skips pinned slots; a load that finds no evictable slot
  raises ``BankError`` instead of corrupting an in-flight round.
* ``prefetch`` — batch warm-up: make a working set resident ahead of
  traffic (e.g. a known-hot tenant before opening the queue).  Inside the
  engine the same effect falls out of ``Overlay.plan`` itself: plan's
  loads for round N+1 are issued while round N still executes, and JAX's
  async dispatch overlaps the ``.at[slot].set`` context writes with the
  running launch.
* ``evictable_capacity`` — how many slots a new round may claim (free +
  resident-but-unpinned, optionally excluding keys the caller will pin);
  the engine retires in-flight rounds until the next round's new contexts
  fit.

Multi-device serving (``launch.serve.ShardedOverlayServer``) adds two
pieces at this layer:

* a ``device`` pin — a bank constructed with ``device=`` keeps its stacked
  instruction arrays committed to that device (every ``.at[slot].set``
  context write stays there), so each serving replica's working set is
  genuinely resident on its own device instead of silently living on the
  JAX default device;
* residency GENERATIONS — ``generation`` bumps on every slot-content
  change (load or eviction) and each resident key remembers the generation
  at which it landed.  A :class:`BankDirectory` snapshot of (replica,
  slot, generation) can therefore be validated later with ``peek``: a
  mismatched generation means the directory entry is stale (the context
  was evicted, possibly reloaded) and the router must fall back instead of
  trusting the cached slot.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dfg import Op
from repro.core.isa import IM_DEPTH, Program
from repro.core.vm import S_MAX, make_context

#: default number of resident contexts (two cascaded 8-kernel groups)
DEFAULT_CAPACITY = 8
#: default output-slot padding width shared by every resident kernel
DEFAULT_MAX_OUTPUTS = 8


class BankError(ValueError):
    pass


def context_key(kernel) -> tuple[str, str]:
    """Content identity of a kernel's encoded context.

    Residency and dispatch grouping key on this — (name, digest of the
    encoded instruction words + constant tables) — so two different
    programs that happen to share a name can never alias each other in the
    bank.  Cached on the Program object (encoding is immutable post-build).
    """
    program: Program = getattr(kernel, "program", kernel)
    key = getattr(program, "_context_key", None)
    if key is None:
        h = hashlib.sha1()
        for img in program.images:
            h.update(np.asarray(img.words, np.uint32).tobytes())
            h.update(np.asarray(img.consts, np.float32).tobytes())
            h.update(bytes([img.n_loads]))
        h.update(np.asarray(getattr(program, "_output_slots", []),
                            np.int32).tobytes())
        key = (program.name, h.hexdigest())
        program._context_key = key
    return key


class ContextBank:
    """Fixed-capacity, LRU-managed store of device-resident contexts.

    All instruction state lives in four stacked arrays whose leading axis
    is the slot id; ``tree()`` hands them to ``vm_exec_multi`` /
    ``tmfu_pipeline_multi`` unchanged.  ``out_idx`` rows are padded to
    ``max_outputs`` (pad rows repeat slot 0 — harmless, callers slice to
    the kernel's real ``n_outputs``).
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 s_max: int = S_MAX, dtype=jnp.float32,
                 max_outputs: int = DEFAULT_MAX_OUTPUTS,
                 device=None):
        if capacity < 1:
            raise BankError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.s_max = s_max
        self.dtype = dtype
        self.max_outputs = max_outputs
        #: device the stacked arrays are committed to; None = JAX default
        #: (uncommitted).  Serving replicas pin their bank so the working
        #: set is resident where the replica's rounds execute.
        self.device = device
        # identity padding for empty slots: BYP slot i <- rf[i], like
        # make_context's padding, so an unloaded slot is a pure pass-through
        ident = np.tile(np.arange(IM_DEPTH, dtype=np.int32),
                        (capacity, s_max, 1))
        self.op = self._place(np.full((capacity, s_max, IM_DEPTH),
                                      int(Op.BYP), np.int32))
        self.src_a = self._place(ident)
        self.src_b = self._place(ident.copy())
        self.imm = self._place(np.zeros((capacity, s_max, IM_DEPTH),
                                        np.dtype(dtype)))
        self.out_idx = self._place(np.zeros((capacity, max_outputs),
                                            np.int32))
        #: residency map: context_key -> slot, MRU last
        self._lru: OrderedDict[tuple[str, str], int] = OrderedDict()
        self._free = list(range(capacity))
        self._meta: dict[int, dict] = {}  # slot -> {name, n_inputs, n_outputs}
        #: host-side cache of encoded contexts, so an eviction reload is a
        #: pure device write (no re-run of the Python encode loop); bounded
        #: LRU (4x capacity) so a churning tenant population cannot pin the
        #: device arrays of every kernel ever seen
        self._ctx_cache: OrderedDict[tuple[str, str], object] = OrderedDict()
        self._ctx_cache_cap = 4 * capacity
        #: eviction guards: context_key -> pin refcount (see ``pin``)
        self._pins: dict[tuple[str, str], int] = {}
        #: residency generation: bumped on every slot-content change (load
        #: into a slot or eviction), so external residency caches
        #: (BankDirectory) can detect staleness without subscribing to
        #: eviction events
        self.generation = 0
        #: context_key -> generation at which that key became resident
        self._key_gen: dict[tuple[str, str], int] = {}
        self.n_loads = 0
        self.n_evictions = 0
        self.n_hits = 0
        #: optional RoundArena serving this bank's rounds (attached by the
        #: engine); surfaced in stats() so a leaking arena bucket shows up
        #: in telemetry instead of just RSS
        self._arena = None

    def attach_arena(self, arena) -> None:
        """Expose a RoundArena's occupancy/recycle counters via stats()."""
        self._arena = arena

    def _place(self, x):
        """Commit an array to this bank's device (default device if None)."""
        if self.device is None:
            return jnp.asarray(x)
        return jax.device_put(jnp.asarray(x), self.device)

    # ------------------------------------------------------------- residency
    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, kernel) -> bool:
        """Membership by kernel/Program (exact content) or by name (str)."""
        if isinstance(kernel, str):
            return any(k[0] == kernel for k in self._lru)
        return context_key(kernel) in self._lru

    @property
    def resident(self) -> tuple[str, ...]:
        """Resident kernel names, LRU first."""
        return tuple(name for name, _ in self._lru)

    def slot_of(self, kernel) -> int | None:
        """Slot id of a resident kernel (touches LRU), else None."""
        key = context_key(kernel)
        slot = self._lru.get(key)
        if slot is not None:
            self._lru.move_to_end(key)
            self.n_hits += 1
        return slot

    def meta(self, slot: int) -> dict:
        return self._meta[slot]

    def peek(self, kernel) -> tuple[int, int] | None:
        """Residency probe WITHOUT an LRU touch: ``(slot, generation)``.

        Returns None when the kernel is not resident.  Routers use this to
        validate a :class:`BankDirectory` entry — a probe is not a *use*,
        so it must not refresh the key's LRU position (the eventual
        ``load`` at plan time is the use).
        """
        key = context_key(getattr(kernel, "program", kernel))
        slot = self._lru.get(key)
        if slot is None:
            return None
        return slot, self._key_gen[key]

    # -------------------------------------------------------------- pinning
    def pin(self, kernel) -> int:
        """Make ``kernel`` resident and guard it against eviction.

        Pins are refcounted: every ``pin`` must be balanced by an ``unpin``.
        The async engine pins a round's contexts at plan time and unpins at
        collect time, so a context can never be evicted (its slot reused by
        another tenant) while a launch referencing that slot is in flight.
        Returns the slot id.
        """
        slot = self.load(kernel)
        key = context_key(getattr(kernel, "program", kernel))
        self._pins[key] = self._pins.get(key, 0) + 1
        return slot

    def unpin(self, kernel) -> None:
        """Release one pin on ``kernel`` (refcounted; see ``pin``)."""
        key = context_key(getattr(kernel, "program", kernel))
        n = self._pins.get(key, 0)
        if n <= 0:
            raise BankError(f"unpin without matching pin: {key[0]}")
        if n == 1:
            del self._pins[key]
        else:
            self._pins[key] = n - 1

    def is_pinned(self, kernel) -> bool:
        key = context_key(getattr(kernel, "program", kernel))
        return self._pins.get(key, 0) > 0

    @property
    def n_pinned(self) -> int:
        """Number of distinct pinned resident contexts."""
        return len(self._pins)

    def evictable_capacity(self, excluding=()) -> int:
        """Slots a newcomer working set may claim: free + unpinned residents.

        ``excluding`` (context keys) removes residents the caller intends
        to keep — e.g. the serving engine excludes the next round's own
        resident kernels, since those will be pinned rather than evicted.
        The engine checks this before planning a round and retires
        in-flight rounds (dropping their pins) until the round's new
        contexts fit.
        """
        ex = set(excluding)
        return len(self._free) + sum(1 for k in self._lru
                                     if self._pins.get(k, 0) == 0
                                     and k not in ex)

    def prefetch(self, kernels) -> list[int]:
        """Warm-up hook: make a working set resident ahead of traffic.

        Functionally ``load`` per kernel (LRU rules apply — the set may
        evict colder residents, never pinned ones); returns the slot ids.
        Useful before opening a queue to a known-hot tenant, or from any
        caller that wants context writes issued while earlier launches
        still execute (JAX async dispatch overlaps them with compute).
        """
        return [self.load(k) for k in kernels]

    # ----------------------------------------------------------------- load
    def load(self, kernel) -> int:
        """Make a kernel resident and return its slot id.

        ``kernel`` is an ``overlay.CompiledKernel`` (or a bare ``Program``).
        Residency is keyed on context CONTENT (see ``context_key``), so a
        same-named but different program is a distinct tenant, never an
        alias.  A resident kernel is an LRU touch; otherwise the context
        image is written into a free slot, evicting the LRU resident when
        the bank is full (its slot id is reused by the newcomer).
        """
        program: Program = getattr(kernel, "program", kernel)
        key = context_key(program)
        name = program.name
        slot = self._lru.get(key)
        if slot is not None:
            self._lru.move_to_end(key)
            self.n_hits += 1
            return slot
        ctx = self._ctx_cache.get(key)
        if ctx is None:
            # the encode is deterministic over the immutable program, so
            # memoize the built Context ON the program (like
            # context_key): a second bank loading the same kernel — an
            # elastic scale-up warming a fresh replica, a migration, a
            # steal prefetch — pays a device write, not a re-run of the
            # Python encode loop.  The memo holds HOST (numpy) arrays:
            # it lives as long as the Program (the caller's object, GC'd
            # with it), so it must not pin device memory — the bounded
            # _ctx_cache rationale above stays true, device residency is
            # still capped by bank capacity.  The Context is read-only
            # to every bank (slot writes are functional), so sharing is
            # safe.
            memo = getattr(program, "_ctx_memo", None)
            if memo is None:
                memo = program._ctx_memo = {}
            mkey = (self.s_max, np.dtype(self.dtype).str)
            ctx = memo.get(mkey)
            if ctx is None:
                ctx = make_context(program, self.s_max, self.dtype)
                ctx = dataclasses.replace(
                    ctx, op=np.asarray(ctx.op),
                    src_a=np.asarray(ctx.src_a),
                    src_b=np.asarray(ctx.src_b),
                    imm=np.asarray(ctx.imm),
                    out_idx=np.asarray(ctx.out_idx))
                memo[mkey] = ctx
            self._ctx_cache[key] = ctx
            while len(self._ctx_cache) > self._ctx_cache_cap:
                self._ctx_cache.popitem(last=False)
        else:
            self._ctx_cache.move_to_end(key)
        if ctx.n_outputs > self.max_outputs:
            raise BankError(
                f"{name}: {ctx.n_outputs} outputs > bank max_outputs="
                f"{self.max_outputs}")
        if self._free:
            slot = self._free.pop(0)
        else:
            # evict the least-recently-used UNPINNED resident; pinned slots
            # belong to in-flight rounds and must keep their contents
            victim = next((k for k in self._lru
                           if self._pins.get(k, 0) == 0), None)
            if victim is None:
                raise BankError(
                    f"{name}: bank full and all {self.capacity} resident "
                    f"contexts are pinned; retire in-flight rounds (unpin) "
                    f"before loading new tenants")
            slot = self._lru.pop(victim)
            del self._meta[slot]
            del self._key_gen[victim]
            self.n_evictions += 1
        self.op = self.op.at[slot].set(ctx.op)
        self.src_a = self.src_a.at[slot].set(ctx.src_a)
        self.src_b = self.src_b.at[slot].set(ctx.src_b)
        self.imm = self.imm.at[slot].set(ctx.imm)
        out_pad = np.zeros(self.max_outputs, np.int32)
        out_pad[:ctx.n_outputs] = np.asarray(ctx.out_idx)
        self.out_idx = self.out_idx.at[slot].set(jnp.asarray(out_pad))
        self._meta[slot] = {"name": name, "n_inputs": ctx.n_inputs,
                            "n_outputs": ctx.n_outputs,
                            "context_bytes": ctx.context_bytes}
        self._lru[key] = slot
        # one bump covers the slot's content change (and the eviction that
        # freed it, if any): every stale BankDirectory entry — the victim's
        # and any older snapshot of this key — now fails its generation check
        self.generation += 1
        self._key_gen[key] = self.generation
        self.n_loads += 1
        return slot

    # ------------------------------------------------------------- lifecycle
    def retire(self) -> None:
        """Decommission this bank: drop every residency and bump the
        generation.

        The elastic fleet calls this while draining a replica, AFTER its
        in-flight rounds have retired (pins released) and its queued work
        has been evacuated.  Clearing the residency map makes every
        ``peek`` miss, and the generation bump is belt-and-braces: any
        external residency snapshot (a :class:`BankDirectory` entry that
        escaped the drain's unpublish, a caller-cached ``(slot,
        generation)`` pair) can never validate against this bank again —
        stale lookups fall back to the router's miss path instead of
        dispatching into a decommissioned replica.

        Raises :class:`BankError` if pinned contexts remain: a pin means
        an in-flight round still references these slots, and retiring
        under it would be exactly the slot-reuse corruption pins exist to
        prevent.
        """
        if self._pins:
            names = sorted(k[0] for k in self._pins)
            raise BankError(
                f"retire with {len(self._pins)} pinned contexts "
                f"({', '.join(names)}); retire in-flight rounds first")
        self._lru.clear()
        self._meta.clear()
        self._key_gen.clear()
        self._free = list(range(self.capacity))
        self.generation += 1

    # ------------------------------------------------------------- executor
    def tree(self):
        """The stacked instruction arrays, in vm_exec_multi leaf order."""
        return (self.op, self.src_a, self.src_b, self.imm)

    def stats(self) -> dict:
        # occupancy / pinned_fraction are the bank-saturation signals the
        # serving gateway's edge-shed heuristics read: a bank whose slots
        # are mostly pinned is backed up behind in-flight rounds, so
        # pushing more depth at it buys latency, not throughput
        return {"capacity": self.capacity, "resident": len(self),
                "free": len(self._free), "loads": self.n_loads,
                "evictions": self.n_evictions, "hits": self.n_hits,
                "pinned": self.n_pinned, "generation": self.generation,
                "ctx_cache": len(self._ctx_cache),
                "occupancy": len(self) / self.capacity,
                "pinned_fraction": self.n_pinned / self.capacity,
                "arena": (self._arena.stats()
                          if self._arena is not None else None)}


# ================================================================ directory
@dataclasses.dataclass
class DirectoryEntry:
    """One published residency: kernel key -> (replica, slot, generation)."""

    replica: int
    slot: int
    generation: int


class BankDirectory:
    """Residency cache for a fleet of per-replica ContextBanks.

    The sharded serving router keys every request by context content
    (``context_key``) and asks the directory which replica already hosts
    that context.  The directory is a CACHE, not the source of truth — the
    banks are.  Every ``locate`` validates its entry against the owning
    bank with ``ContextBank.peek``: the entry is fresh only when the key
    is still resident there at the SAME generation it was published at.
    An eviction (or evict-and-reload) on the replica bumps the bank's
    generation, so the stale entry fails validation, is dropped, and the
    router takes the miss/fallback path instead of dispatching against a
    slot that now holds another tenant's context.

    ``publish`` after a load/prefetch records the fresh residency;
    ``drop`` forgets a key (e.g. when a migration retires the old owner);
    ``republish_current`` is the work-stealing/migration hook — it moves
    a key's published home to a new replica (which must already hold the
    context) and counts the move, so routing follows stolen work.
    """

    def __init__(self):
        self._map: dict[tuple[str, str], DirectoryEntry] = {}
        self.n_fresh = 0
        self.n_stale = 0
        self.n_unknown = 0
        self.n_republished = 0
        self.n_unpublished = 0

    def __len__(self) -> int:
        return len(self._map)

    def publish(self, kernel, replica: int, slot: int,
                generation: int) -> None:
        key = context_key(getattr(kernel, "program", kernel))
        self._map[key] = DirectoryEntry(replica=replica, slot=slot,
                                        generation=generation)

    def publish_current(self, kernel, replica: int, bank: ContextBank) -> None:
        """Publish the key's CURRENT residency in ``bank`` (must be
        resident — call right after a ``load``/``prefetch``)."""
        res = bank.peek(kernel)
        if res is None:
            raise BankError("publish_current: kernel is not resident")
        self.publish(kernel, replica, res[0], res[1])

    def republish_current(self, kernel, replica: int,
                          bank: ContextBank) -> None:
        """Move a key's published home to ``replica`` (steal/migration):
        ``publish_current`` plus a republish count.  The context must
        already be resident in ``bank`` — callers prefetch BEFORE moving
        work, so a failed prefetch never strands the directory entry."""
        self.publish_current(kernel, replica, bank)
        self.n_republished += 1

    def drop(self, kernel) -> None:
        self._map.pop(context_key(getattr(kernel, "program", kernel)), None)

    def remove_replica(self, replica: int) -> int:
        """Unpublish every entry homed on ``replica`` and shift higher
        replica ids down by one; returns how many entries were dropped.

        The elastic fleet compacts replica indices when it decommissions
        a replica (``ShardedOverlayServer.drain_replica``): entries on
        the dying replica are unpublished (their contexts are gone — a
        lookup must take the miss path), and every surviving entry's
        replica id is renumbered to keep pointing at the SAME bank in the
        compacted list.  Generation validation still backstops the whole
        move: an entry that somehow escapes this (published concurrently,
        or by a caller holding a stale fleet view) fails its ``peek``
        check against whatever bank now sits at that index and is dropped
        at ``locate`` time.
        """
        dropped = [k for k, e in self._map.items() if e.replica == replica]
        for k in dropped:
            del self._map[k]
        for e in self._map.values():
            if e.replica > replica:
                e.replica -= 1
        self.n_unpublished += len(dropped)
        return len(dropped)

    def locate(self, kernel, banks) -> int | None:
        """Validated lookup: the owning replica id, or None on miss/stale.

        ``banks`` maps replica id -> ContextBank (list or dict).  A stale
        entry (generation mismatch, evicted key, or out-of-range replica)
        is dropped and counted; the caller must treat None as a residency
        miss and fall back to its placement policy.
        """
        key = context_key(getattr(kernel, "program", kernel))
        ent = self._map.get(key)
        if ent is None:
            self.n_unknown += 1
            return None
        try:
            bank = banks[ent.replica]
        except (IndexError, KeyError):
            bank = None
        res = bank.peek(kernel) if bank is not None else None
        if res is None or res != (ent.slot, ent.generation):
            del self._map[key]
            self.n_stale += 1
            return None
        self.n_fresh += 1
        return ent.replica

    def stats(self) -> dict:
        return {"entries": len(self._map), "fresh": self.n_fresh,
                "stale": self.n_stale, "unknown": self.n_unknown,
                "republished": self.n_republished,
                "unpublished": self.n_unpublished}
