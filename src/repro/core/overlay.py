"""The overlay object: compile-once executor + fast context switching.

Public API::

    ov = Overlay(s_max=16)                      # 'configure the FPGA' once
    ctx = ov.load(compile_program(dfg))         # context switch (no recompile)
    ys = ov(ctx, xs)                            # stream a batch through

``Overlay.load`` is the paper's 0.27 µs daisy-chain analogue: only int32
instruction words + constant tables move; the XLA executable is untouched.
``spatial_jit`` is the SCFU-SCN / vendor-flow analogue: the DFG is inlined
into a fresh XLA program (1 HLO op per DFG node) and must be recompiled per
kernel.  benchmarks/context_switch.py and benchmarks/area_analogue.py
measure the two against each other.

Multi-tenant dispatch is a STAGED PIPELINE so a serving engine can overlap
the host-side work of one round with the device execution of another::

    plan     = ov.plan(bank, requests)     # residency + tile layout (host)
    batch    = ov.assemble(plan)           # one [G,RF,tile] host buffer
    ys       = ov.execute(bank, batch)     # async device launch, NO block
    outs     = ov.collect(plan, ys)        # slice per request (lazy)

``Overlay.dispatch`` is exactly ``collect(execute(assemble(plan)))`` — the
synchronous composition is the bit-for-bit oracle for the async engine in
``launch.serve.OverlayServer``, which interleaves the stages of successive
rounds.  ``plan(..., pin=True)`` pins every referenced context in the bank
until ``plan.release(bank)``, so a later round's planning can never evict
a context out from under an in-flight launch (see ``core.bank``).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import OrderedDict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vm
from repro.core.arena import ArenaBlock, RoundArena
from repro.core.bank import (DEFAULT_MAX_OUTPUTS, BankError, ContextBank,
                             context_key)
from repro.core.dfg import DFG
from repro.core.isa import RF_DEPTH, Program, encode
from repro.core.schedule import Schedule, schedule
from repro.core.vm import Context, dfg_eval, make_context, pad_inputs

#: default per-tile batch width for bank dispatch (VPU lane multiple)
DISPATCH_TILE = 128


@dataclasses.dataclass
class CompiledKernel:
    dfg: DFG
    sched: Schedule
    program: Program


@dataclasses.dataclass
class _GroupSpec:
    """Tile layout of one kernel group inside a dispatch round."""

    key: tuple                # context identity (bank.context_key)
    idxs: list                # request indices, submission order
    kernel: CompiledKernel
    slot: int                 # bank slot the group's tiles select
    lens: list                # per-request batch lengths
    total: int                # sum(lens)
    n_tiles: int              # ceil(total / tile)
    start: int                # first row of this group in the tile stack


@dataclasses.dataclass
class DispatchPlan:
    """Host-side layout of one mixed-kernel round (output of ``plan``).

    Carries everything ``assemble``/``collect`` need to build the tile
    stack and slice results back out, plus the request list itself so the
    stages cannot be fed mismatched arguments.  When built with
    ``pin=True`` the referenced contexts are pinned in the bank; call
    ``release(bank)`` exactly once after ``collect`` (or on abandon).
    """

    tile: int
    requests: list            # the [(CompiledKernel, xs)] pairs, verbatim
    groups: list              # [_GroupSpec]
    g_total: int              # live tile rows
    g_pad: int                # pow2-padded tile rows (executable bucket)
    pinned: bool = False
    arena: RoundArena | None = None   # pool the staging block came from
    block: ArenaBlock | None = None   # host block owned until release()

    @property
    def n_kernels(self) -> int:
        return len(self.groups)

    def release(self, bank: ContextBank) -> None:
        """Drop this plan's eviction pins and recycle its arena block.

        Called exactly once per round after delivery (``collect``); a
        no-op for unpinned, arena-less plans.  The host staging block is
        safe to reuse here because ``execute``'s device placement COPIES
        it — the launch never aliases host memory.
        """
        if self.pinned:
            self.pinned = False
            for g in self.groups:
                bank.unpin(g.kernel)
        if self.arena is not None:
            self.arena.recycle(self.block)
            self.arena = None
            self.block = None


@partial(jax.jit, static_argnames=("n_tiles", "n_out"))
def _gather_live(ys, n_tiles: int, n_out: int):
    """Device-side live-rows slice + transpose for ``collect(host=True)``.

    Drops the padding tiles and the dead ``max_outputs`` rows BEFORE the
    host transfer, and moves the output axis outermost so each group's
    per-output flatten on the host is a contiguous view, not a copy.
    ``n_tiles`` is bucketed by the caller (multiple of 8, capped at
    ``g_pad``) so steady traffic reuses a handful of executables.
    """
    return jnp.moveaxis(ys[:n_tiles, :n_out, :], 1, 0)


def _round_up8(n: int) -> int:
    return -(-n // 8) * 8


def _on_device(arr, device) -> bool:
    """True when ``arr`` is a jax.Array already resident on ``device``."""
    sharding = getattr(arr, "sharding", None)
    return (sharding is not None
            and getattr(sharding, "device_set", None) == {device})


def _host_backed(arr) -> bool:
    """True when ``arr``'s buffer lives in host memory (numpy, or a
    jax.Array on CPU devices) — i.e. ``np.asarray`` on it is zero-copy
    and a device-side gather would only add dispatch latency."""
    if isinstance(arr, np.ndarray):
        return True
    sharding = getattr(arr, "sharding", None)
    if sharding is None:
        return False
    return all(d.platform == "cpu" for d in sharding.device_set)


def compile_program(dfg: DFG) -> CompiledKernel:
    """Full mapping flow: DFG -> schedule -> encoded context image."""
    sched = schedule(dfg)
    program = encode(sched)
    # record the RF slots of the primary outputs in the final stage stream
    final = sched.stages[-1]
    slot_of = {ins.dest: i for i, ins in enumerate(final.instrs)}
    program._output_slots = np.asarray(
        [slot_of[o] for o in dfg.outputs], dtype=np.int32)
    return CompiledKernel(dfg=dfg, sched=sched, program=program)


class Overlay:
    """A fixed executor for a family of kernels (<= s_max stages)."""

    def __init__(self, s_max: int = vm.S_MAX, dtype=jnp.float32,
                 backend: str = "jnp", device=None,
                 arena: RoundArena | None = None, donate: bool = False):
        if backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.s_max = s_max
        self.dtype = dtype
        self.backend = backend
        #: device this overlay's contexts and launches are pinned to;
        #: None = JAX default.  A sharded serving replica pins its overlay
        #: (and its ContextBank) so rounds execute where the working set
        #: is resident, never via implicit default-device placement.
        self.device = device
        #: host staging pool for ``assemble``; None = allocate per round.
        #: The caller owns the recycle protocol: every assembled plan must
        #: eventually see ``plan.release(bank)`` (the serving engines do).
        self.arena = arena
        #: donate the round's device tile stack to the executor so XLA
        #: frees/reuses the input allocation instead of holding it until
        #: the round retires.  Contract: the caller must not touch the
        #: batch after ``execute`` consumes it (the engines never do).
        self.donate = donate
        #: reusable packing scratch for ``assemble`` (grown on demand);
        #: per-overlay, so concurrent engines never share it.
        self._scratch: np.ndarray | None = None

    # --------------------------------------------------------------- context
    def load(self, kernel: CompiledKernel) -> Context:
        """Context switch: build + device_put the instruction image.

        The arrays are placed field by field: ``Context`` is a plain
        dataclass, not a registered pytree, so a ``jax.tree.map`` over it
        would treat the whole context as one leaf and silently skip the
        transfer — the image would stay on the default device no matter
        what this overlay is pinned to (regression-tested in
        tests/test_sharded_serving.py).
        """
        ctx = make_context(kernel.program, self.s_max, self.dtype)
        return dataclasses.replace(
            ctx, **{f: jax.device_put(getattr(ctx, f), self.device)
                    for f in ("op", "src_a", "src_b", "imm", "out_idx")})

    # --------------------------------------------------------------- execute
    def __call__(self, ctx: Context, xs: list[jax.Array]) -> list[jax.Array]:
        x = pad_inputs([jnp.asarray(v, self.dtype) for v in xs],
                       device=self.device)
        if self.backend == "pallas":
            from repro.kernels.tmfu import ops as tmfu_ops
            ys = tmfu_ops.tmfu_pipeline(ctx, x)
        else:
            ys = vm.vm_exec(ctx.tree(), ctx.out_idx, x)
        return [ys[i] for i in range(ctx.n_outputs)]

    # ---------------------------------------------------------- multi-tenant
    def load_many(self, kernels, capacity: int | None = None,
                  max_outputs: int = DEFAULT_MAX_OUTPUTS) -> ContextBank:
        """Load a family of kernels into a fresh ContextBank.

        The bank's stacked arrays feed ``vm_exec_multi`` (or the Pallas
        multi kernel) so every resident kernel is reachable by slot id from
        ONE compiled executable.
        """
        ks = list(kernels)
        bank = ContextBank(capacity or max(len(ks), 1), s_max=self.s_max,
                           dtype=self.dtype, max_outputs=max_outputs,
                           device=self.device)
        for k in ks:
            bank.load(k)
        return bank

    def plan(self, bank: ContextBank, requests, tile: int = DISPATCH_TILE,
             pin: bool = False) -> DispatchPlan:
        """Stage 1/4 — residency + tile layout for a mixed-kernel round.

        ``requests`` is a list of ``(CompiledKernel, xs)`` pairs (``xs`` a
        list of 1-D input arrays, all the same length within a request).
        Requests are grouped by context CONTENT (not name: two distinct
        programs sharing a name must never be served from one slot), every
        group's kernel is made bank-resident (this is the prefetch point —
        the device context writes overlap whatever is already executing),
        and each group gets a run of fixed-width tile rows.  The round may
        reference at most ``bank.capacity`` distinct kernels; larger
        working sets are split into rounds by ``launch.serve``.

        With ``pin=True`` each referenced context is refcount-pinned until
        ``DispatchPlan.release(bank)`` — required whenever another round
        may load contexts between this plan and its ``execute``.
        """
        groups: OrderedDict[tuple, list[int]] = OrderedDict()
        for i, (k, _) in enumerate(requests):
            groups.setdefault(context_key(k.program), []).append(i)
        if len(groups) > bank.capacity:
            raise BankError(
                f"batch references {len(groups)} kernels > bank capacity "
                f"{bank.capacity}; split into rounds (see OverlayServer)")

        specs: list[_GroupSpec] = []
        g_total = 0
        try:
            for key, idxs in groups.items():
                kern = requests[idxs[0]][0]
                slot = bank.pin(kern) if pin else bank.load(kern)
                lens = [int(np.shape(requests[i][1][0])[0]) for i in idxs]
                total = sum(lens)
                n_tiles = -(-total // tile)
                specs.append(_GroupSpec(key=key, idxs=idxs, kernel=kern,
                                        slot=slot, lens=lens, total=total,
                                        n_tiles=n_tiles, start=g_total))
                g_total += n_tiles
        except BankError:
            # unwind pins already taken by this (never-returned) plan — a
            # caller can't release() a plan it never got
            if pin:
                for g in specs:
                    bank.unpin(g.kernel)
            raise
        g_pad = 1 << (g_total - 1).bit_length() if g_total else 0
        return DispatchPlan(tile=tile, requests=list(requests), groups=specs,
                            g_total=g_total, g_pad=g_pad, pinned=pin)

    def assemble(self, plan: DispatchPlan):
        """Stage 2/4 — build the round's host tile stack (single pass).

        Packs every request into ONE ``[G_pad, RF_DEPTH, tile]`` host
        buffer (a single device transfer — the hot serving path must not
        pay per-group/per-tile dispatches) plus the per-tile context-id
        vector.  The tile count is padded to the next power of two with
        replicas of tile 0 so repeated mixed workloads land in a handful
        of executable buckets (zero retraces after warmup).

        Each group's rows are concatenated ONCE into a pooled overlay
        scratch (``np.concatenate(..., out=)`` — no intermediate
        allocation) and stored with a single strided scatter into the
        group's tile run — the legacy per-group ``np.zeros`` + concat
        copy + ``reshape().transpose()`` triple pass survives only as
        ``assemble_reference``, the paired-benchmark baseline.  With
        ``self.arena`` set the destination is a recycled pool block
        (scrubbed to its dirty high-water mark, so contents are
        bit-identical to a fresh zeros) that ``plan.release(bank)``
        returns to the pool.

        Pure host work (numpy) plus an async device placement: in the
        async engine this stage runs for round N+1 while round N executes
        on device.  Returns ``(id_arr, x_stack)`` — already resident on
        ``self.device`` when one is pinned, so ``execute`` skips its
        placement — or ``None`` when the round is all zero-length
        requests (nothing to launch).
        """
        if plan.g_total == 0:
            return None
        np_dtype = np.dtype(self.dtype)
        tile = plan.tile
        if self.arena is not None:
            if plan.block is not None:       # re-assembled plan: no leak
                plan.arena.recycle(plan.block)
            block = self.arena.checkout(plan.g_pad, tile, np_dtype)
            plan.arena, plan.block = self.arena, block
            x_np, ids_np = block.x, block.ids
        else:
            block = None
            x_np = np.zeros((plan.g_pad, RF_DEPTH, tile), np_dtype)
            ids_np = np.zeros(plan.g_pad, np.int32)
        max_cols = max((g.n_tiles for g in plan.groups), default=0) * tile
        scratch = self._scratch
        if (scratch is None or scratch.dtype != np_dtype
                or scratch.shape[1] < max_cols):
            scratch = self._scratch = np.empty((RF_DEPTH, max_cols), np_dtype)
        dirty = 0
        for g in plan.groups:
            if g.n_tiles == 0:
                continue
            n_in = len(g.kernel.dfg.inputs)
            dirty = max(dirty, n_in)
            nt = g.n_tiles
            buf = scratch[:n_in, :nt * tile]    # [n_in, nt*tile] pooled
            for j in range(n_in):
                np.concatenate([np.asarray(plan.requests[i][1][j], np_dtype)
                                for i in g.idxs], out=buf[j, :g.total])
            if g.total < nt * tile:
                buf[:, g.total:] = 0            # zero tail of the last tile
            # single strided store: row j of the scratch lands in RF row j
            # of every tile in the group's run
            x_np[g.start:g.start + nt, :n_in, :] = \
                buf.reshape(n_in, nt, tile).transpose(1, 0, 2)
            ids_np[g.start:g.start + g.n_tiles] = g.slot
        # padding tiles replicate tile 0; only its dirty rows can be
        # nonzero, so copying those rows is bit-identical to a full copy
        if plan.g_total < plan.g_pad:
            x_np[plan.g_total:, :dirty] = x_np[0, :dirty]
        ids_np[plan.g_total:] = ids_np[0]
        if block is not None:
            block.dirty_rows = max(block.dirty_rows, dirty)
        if self.device is not None:
            return jax.device_put((ids_np, x_np), self.device)
        return jnp.asarray(ids_np), jnp.asarray(x_np)

    def assemble_reference(self, plan: DispatchPlan):
        """The seed's copy-heavy assemble, kept verbatim as the paired
        baseline for ``benchmarks/hot_path.py`` and the bit-parity tests
        (``assemble`` must reproduce this buffer exactly)."""
        if plan.g_total == 0:
            return None
        np_dtype = np.dtype(self.dtype)
        tile = plan.tile
        x_np = np.zeros((plan.g_pad, RF_DEPTH, tile), np_dtype)
        ids_np = np.zeros(plan.g_pad, np.int32)
        for g in plan.groups:
            if g.n_tiles == 0:
                continue
            n_in = len(g.kernel.dfg.inputs)
            buf = np.zeros((n_in, g.n_tiles * tile), np_dtype)
            for j in range(n_in):
                buf[j, :g.total] = np.concatenate(
                    [np.asarray(plan.requests[i][1][j], np_dtype)
                     for i in g.idxs])
            x_np[g.start:g.start + g.n_tiles, :n_in, :] = \
                buf.reshape(n_in, g.n_tiles, tile).transpose(1, 0, 2)
            ids_np[g.start:g.start + g.n_tiles] = g.slot
        x_np[plan.g_total:] = x_np[0]
        ids_np[plan.g_total:] = ids_np[0]
        return jnp.asarray(ids_np), jnp.asarray(x_np)

    def execute(self, bank: ContextBank, batch):
        """Stage 3/4 — launch the round on device; does NOT block.

        Snapshots the bank's stacked instruction arrays at call time (the
        arrays are immutable — later ``bank.load`` writes produce NEW
        arrays, so an in-flight launch is never disturbed) and issues one
        ``vm_exec_multi`` / ``tmfu_pipeline_multi`` call.  JAX dispatch is
        asynchronous: the returned ``[G_pad, max_outputs, tile]`` array is
        a future; only ``jax.block_until_ready`` (the engine's delivery
        point) waits on it.  Slot validity between ``plan`` and this call
        is the caller's contract — hold plan pins if any other round may
        touch the bank in between.
        """
        if batch is None:
            return None
        id_arr, x_stack = batch
        # co-locate the round with the bank: a device-pinned bank (sharded
        # replica) must execute where its contexts are resident — mixing a
        # committed bank with default-device inputs is an XLA placement
        # error, not a transfer.  ``assemble`` already places on
        # ``self.device``, so the placement here only fires for batches
        # built elsewhere — never a redundant no-op put per round.
        device = getattr(bank, "device", None) or self.device
        if device is not None and not (_on_device(id_arr, device)
                                       and _on_device(x_stack, device)):
            id_arr, x_stack = jax.device_put((id_arr, x_stack), device)
        if self.backend == "pallas":
            from repro.kernels.tmfu import ops as tmfu_ops
            return tmfu_ops.tmfu_pipeline_multi(bank, id_arr, x_stack,
                                                donate=self.donate)
        if self.donate:
            # XLA frees (rather than aliases) the donation here: the jnp
            # executor's [G, max_outputs, tile] result is narrower than
            # the donated stack, and its lowering warns about the partial
            # use at every compile — expected, so keep each bucket quiet
            with warnings.catch_warnings():
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                return vm.vm_exec_multi_donated(bank.tree(), bank.out_idx,
                                                id_arr, x_stack)
        return vm.vm_exec_multi(bank.tree(), bank.out_idx, id_arr, x_stack)

    def collect(self, plan: DispatchPlan, ys, host: bool = False):
        """Stage 4/4 — slice the round's result stack back per request.

        Two delivery modes:

        * ``host=False`` (the ``dispatch`` default): the slices are lazy
          device ops on the (possibly still executing) result array —
          nothing blocks, results stay ``jax.Array``.
        * ``host=True`` (the streaming engine's delivery path): ``ys``
          must already be ready (the engine just blocked on it); only
          the LIVE ``g_total`` tiles and live output rows reach a
          contiguous host buffer, in ONE bulk gather; every per-group
          flatten and per-request slice after that is a numpy VIEW —
          the padding tiles and dead ``max_outputs`` rows are never
          copied.  On an accelerator the slice+transpose runs device-
          side (``_gather_live`` — one fused op, tile count bucketed to
          a multiple of 8 so steady traffic never retraces) so the one
          host transfer carries live bytes only; for a host-backed
          result (CPU jax) ``np.asarray`` is already zero-copy, so the
          gather is a single strided ``np.copyto`` of the live view —
          no XLA dispatch on the delivery path at all.

        Returns one output list per request, in request order; both modes
        yield bit-identical values.  ``collect_reference`` keeps the
        seed's full-stack readback as the paired-benchmark baseline.
        """
        if ys is None:
            return [[jnp.zeros((0,), self.dtype) for _ in k.dfg.outputs]
                    for k, _ in plan.requests]
        if host:
            if _host_backed(ys):
                arr = None
                view = np.asarray(ys)            # zero-copy on CPU
            else:
                n_live = max((len(g.kernel.dfg.outputs)
                              for g in plan.groups), default=1)
                nt = min(plan.g_pad, _round_up8(plan.g_total))
                arr = np.asarray(_gather_live(ys, nt, max(n_live, 1)))
        results: list = [None] * len(plan.requests)
        for g in plan.groups:
            n_out = len(g.kernel.dfg.outputs)
            if host:
                if arr is None:
                    # one strided gather per group: exactly this group's
                    # live output rows, output axis out front so each row
                    # flattens to a contiguous view
                    buf = view[g.start:g.start + g.n_tiles, :n_out, :] \
                        .transpose(1, 0, 2).copy()
                    flats = [buf[j].reshape(-1) for j in range(n_out)]
                else:
                    # [n_live, nt, tile] device-gathered stack: per-group
                    # flattens are contiguous views of the one transfer
                    flats = [arr[j, g.start:g.start + g.n_tiles].reshape(-1)
                             for j in range(n_out)]
            else:
                block = ys[g.start:g.start + g.n_tiles]  # [nt, max_out, tile]
                flat = jnp.moveaxis(block, 1, 0).reshape(ys.shape[1], -1)
                flats = [flat[j] for j in range(n_out)]
            off = 0
            for i, n in zip(g.idxs, g.lens):
                results[i] = [flats[j][off:off + n] for j in range(n_out)]
                off += n
        return results

    def collect_reference(self, plan: DispatchPlan, ys, host: bool = False):
        """The seed's collect: full padded-stack readback + one
        ``ascontiguousarray`` copy per live output row.  Paired-benchmark
        baseline; bit-identical to ``collect`` in both modes."""
        if ys is None:
            return [[jnp.zeros((0,), self.dtype) for _ in k.dfg.outputs]
                    for k, _ in plan.requests]
        if host:
            ys = np.asarray(ys)
        results: list = [None] * len(plan.requests)
        for g in plan.groups:
            n_out = len(g.kernel.dfg.outputs)
            block = ys[g.start:g.start + g.n_tiles]    # [nt, max_out, tile]
            if host:
                flats = [np.ascontiguousarray(block[:, j, :]).reshape(-1)
                         for j in range(n_out)]
            else:
                flat = jnp.moveaxis(block, 1, 0).reshape(ys.shape[1], -1)
                flats = [flat[j] for j in range(n_out)]
            off = 0
            for i, n in zip(g.idxs, g.lens):
                results[i] = [flats[j][off:off + n] for j in range(n_out)]
                off += n
        return results

    def dispatch(self, bank: ContextBank, requests, tile: int = DISPATCH_TILE):
        """Serve a mixed-kernel batch through the bank in one launch family.

        The synchronous composition of the four pipeline stages —
        ``collect(execute(assemble(plan(...))))`` — and therefore the
        bit-for-bit oracle for the streaming engine, which runs the same
        stages interleaved across rounds.  Returns one output list per
        request, in request order.
        """
        if not requests:
            return []
        p = self.plan(bank, requests, tile=tile)
        ys = self.execute(bank, self.assemble(p))
        # the lazy collect below never blocks, so there is no engine-style
        # delivery point to recycle at; the device placement in execute
        # already copied the staging block, so hand it back now (release
        # on an unpinned plan only recycles)
        p.release(bank)
        return self.collect(p, ys)

    # ------------------------------------------------------------ timing
    def time_context_switch(self, kernel: CompiledKernel,
                            iters: int = 20) -> float:
        """Median seconds to swap a kernel onto the live overlay."""
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            ctx = self.load(kernel)
            jax.block_until_ready(ctx.op)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))


def spatial_jit(dfg: DFG):
    """SCFU-SCN analogue: the DFG inlined into its own XLA program."""

    @jax.jit
    def run(xs: list[jax.Array]) -> list[jax.Array]:
        env = {name: x for name, x in zip(dfg.inputs, xs)}
        out = dfg_eval(dfg, env)
        return [out[o] for o in dfg.outputs]

    return run


def time_recompile(dfg: DFG, xs, iters: int = 3) -> float:
    """Seconds for the vendor-flow analogue: fresh trace + XLA compile."""
    ts = []
    for _ in range(iters):
        fn = spatial_jit(dfg)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(xs))
        ts.append(time.perf_counter() - t0)
        fn._clear_cache()
    return float(np.median(ts))
