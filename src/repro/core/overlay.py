"""The overlay object: compile-once executor + fast context switching.

Public API::

    ov = Overlay(s_max=16)                      # 'configure the FPGA' once
    ctx = ov.load(compile_program(dfg))         # context switch (no recompile)
    ys = ov(ctx, xs)                            # stream a batch through

``Overlay.load`` is the paper's 0.27 µs daisy-chain analogue: only int32
instruction words + constant tables move; the XLA executable is untouched.
``spatial_jit`` is the SCFU-SCN / vendor-flow analogue: the DFG is inlined
into a fresh XLA program (1 HLO op per DFG node) and must be recompiled per
kernel.  benchmarks/context_switch.py and benchmarks/area_analogue.py
measure the two against each other.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vm
from repro.core.dfg import DFG
from repro.core.isa import Program, encode
from repro.core.schedule import Schedule, schedule
from repro.core.vm import Context, dfg_eval, make_context, pad_inputs


@dataclasses.dataclass
class CompiledKernel:
    dfg: DFG
    sched: Schedule
    program: Program


def compile_program(dfg: DFG) -> CompiledKernel:
    """Full mapping flow: DFG -> schedule -> encoded context image."""
    sched = schedule(dfg)
    program = encode(sched)
    # record the RF slots of the primary outputs in the final stage stream
    final = sched.stages[-1]
    slot_of = {ins.dest: i for i, ins in enumerate(final.instrs)}
    program._output_slots = np.asarray(
        [slot_of[o] for o in dfg.outputs], dtype=np.int32)
    return CompiledKernel(dfg=dfg, sched=sched, program=program)


class Overlay:
    """A fixed executor for a family of kernels (<= s_max stages)."""

    def __init__(self, s_max: int = vm.S_MAX, dtype=jnp.float32,
                 backend: str = "jnp"):
        if backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.s_max = s_max
        self.dtype = dtype
        self.backend = backend

    # --------------------------------------------------------------- context
    def load(self, kernel: CompiledKernel) -> Context:
        """Context switch: build + device_put the instruction image."""
        ctx = make_context(kernel.program, self.s_max, self.dtype)
        return jax.tree.map(
            lambda x: jax.device_put(x) if isinstance(x, jax.Array) else x,
            ctx, is_leaf=lambda x: isinstance(x, jax.Array))

    # --------------------------------------------------------------- execute
    def __call__(self, ctx: Context, xs: list[jax.Array]) -> list[jax.Array]:
        x = pad_inputs([jnp.asarray(v, self.dtype) for v in xs])
        if self.backend == "pallas":
            from repro.kernels.tmfu import ops as tmfu_ops
            ys = tmfu_ops.tmfu_pipeline(ctx, x)
        else:
            ys = vm.vm_exec(ctx.tree(), ctx.out_idx, x)
        return [ys[i] for i in range(ctx.n_outputs)]

    # ------------------------------------------------------------ timing
    def time_context_switch(self, kernel: CompiledKernel,
                            iters: int = 20) -> float:
        """Median seconds to swap a kernel onto the live overlay."""
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            ctx = self.load(kernel)
            jax.block_until_ready(ctx.op)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))


def spatial_jit(dfg: DFG):
    """SCFU-SCN analogue: the DFG inlined into its own XLA program."""

    @jax.jit
    def run(xs: list[jax.Array]) -> list[jax.Array]:
        env = {name: x for name, x in zip(dfg.inputs, xs)}
        out = dfg_eval(dfg, env)
        return [out[o] for o in dfg.outputs]

    return run


def time_recompile(dfg: DFG, xs, iters: int = 3) -> float:
    """Seconds for the vendor-flow analogue: fresh trace + XLA compile."""
    ts = []
    for _ in range(iters):
        fn = spatial_jit(dfg)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(xs))
        ts.append(time.perf_counter() - t0)
        fn._clear_cache()
    return float(np.median(ts))
