"""The overlay object: compile-once executor + fast context switching.

Public API::

    ov = Overlay(s_max=16)                      # 'configure the FPGA' once
    ctx = ov.load(compile_program(dfg))         # context switch (no recompile)
    ys = ov(ctx, xs)                            # stream a batch through

``Overlay.load`` is the paper's 0.27 µs daisy-chain analogue: only int32
instruction words + constant tables move; the XLA executable is untouched.
``spatial_jit`` is the SCFU-SCN / vendor-flow analogue: the DFG is inlined
into a fresh XLA program (1 HLO op per DFG node) and must be recompiled per
kernel.  benchmarks/context_switch.py and benchmarks/area_analogue.py
measure the two against each other.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vm
from repro.core.bank import (DEFAULT_MAX_OUTPUTS, BankError, ContextBank,
                             context_key)
from repro.core.dfg import DFG
from repro.core.isa import RF_DEPTH, Program, encode
from repro.core.schedule import Schedule, schedule
from repro.core.vm import Context, dfg_eval, make_context, pad_inputs

#: default per-tile batch width for bank dispatch (VPU lane multiple)
DISPATCH_TILE = 128


@dataclasses.dataclass
class CompiledKernel:
    dfg: DFG
    sched: Schedule
    program: Program


def compile_program(dfg: DFG) -> CompiledKernel:
    """Full mapping flow: DFG -> schedule -> encoded context image."""
    sched = schedule(dfg)
    program = encode(sched)
    # record the RF slots of the primary outputs in the final stage stream
    final = sched.stages[-1]
    slot_of = {ins.dest: i for i, ins in enumerate(final.instrs)}
    program._output_slots = np.asarray(
        [slot_of[o] for o in dfg.outputs], dtype=np.int32)
    return CompiledKernel(dfg=dfg, sched=sched, program=program)


class Overlay:
    """A fixed executor for a family of kernels (<= s_max stages)."""

    def __init__(self, s_max: int = vm.S_MAX, dtype=jnp.float32,
                 backend: str = "jnp"):
        if backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.s_max = s_max
        self.dtype = dtype
        self.backend = backend

    # --------------------------------------------------------------- context
    def load(self, kernel: CompiledKernel) -> Context:
        """Context switch: build + device_put the instruction image."""
        ctx = make_context(kernel.program, self.s_max, self.dtype)
        return jax.tree.map(
            lambda x: jax.device_put(x) if isinstance(x, jax.Array) else x,
            ctx, is_leaf=lambda x: isinstance(x, jax.Array))

    # --------------------------------------------------------------- execute
    def __call__(self, ctx: Context, xs: list[jax.Array]) -> list[jax.Array]:
        x = pad_inputs([jnp.asarray(v, self.dtype) for v in xs])
        if self.backend == "pallas":
            from repro.kernels.tmfu import ops as tmfu_ops
            ys = tmfu_ops.tmfu_pipeline(ctx, x)
        else:
            ys = vm.vm_exec(ctx.tree(), ctx.out_idx, x)
        return [ys[i] for i in range(ctx.n_outputs)]

    # ---------------------------------------------------------- multi-tenant
    def load_many(self, kernels, capacity: int | None = None,
                  max_outputs: int = DEFAULT_MAX_OUTPUTS) -> ContextBank:
        """Load a family of kernels into a fresh ContextBank.

        The bank's stacked arrays feed ``vm_exec_multi`` (or the Pallas
        multi kernel) so every resident kernel is reachable by slot id from
        ONE compiled executable.
        """
        ks = list(kernels)
        bank = ContextBank(capacity or max(len(ks), 1), s_max=self.s_max,
                           dtype=self.dtype, max_outputs=max_outputs)
        for k in ks:
            bank.load(k)
        return bank

    def dispatch(self, bank: ContextBank, requests, tile: int = DISPATCH_TILE):
        """Serve a mixed-kernel batch through the bank in one launch family.

        ``requests`` is a list of ``(CompiledKernel, xs)`` pairs (``xs`` a
        list of 1-D input arrays, all the same length within a request).
        Requests are grouped by kernel, each group's batch is padded to the
        ``tile`` boundary and split into fixed-width tiles, and the whole
        mixed tile stack runs through ``vm_exec_multi`` as one call — the
        context switch between tiles is a gathered index.  The tile count is
        padded to the next power of two so repeated mixed workloads land in
        a handful of executable buckets (zero retraces after warmup).

        Returns one output list per request, in request order.  The batch
        may reference at most ``bank.capacity`` distinct kernels; queues
        with larger working sets are round-robined by
        ``launch.serve.OverlayServer``.
        """
        if not requests:
            return []
        # group by context CONTENT, not name: two distinct programs sharing
        # a name must never be served from one slot
        groups: OrderedDict[tuple, list[int]] = OrderedDict()
        for i, (k, _) in enumerate(requests):
            groups.setdefault(context_key(k.program), []).append(i)
        if len(groups) > bank.capacity:
            raise BankError(
                f"batch references {len(groups)} kernels > bank capacity "
                f"{bank.capacity}; split into rounds (see OverlayServer)")

        # first pass: residency + tile layout per group
        specs = []        # (key, idxs, kern, slot, lens, total, n_tiles, start)
        g_total = 0
        for key, idxs in groups.items():
            kern = requests[idxs[0]][0]
            slot = bank.load(kern)
            lens = [int(np.shape(requests[i][1][0])[0]) for i in idxs]
            total = sum(lens)
            n_tiles = -(-total // tile)
            specs.append((key, idxs, kern, slot, lens, total, n_tiles,
                          g_total))
            g_total += n_tiles

        if g_total == 0:
            # every request in the batch was zero-length: nothing to launch
            return [[jnp.zeros((0,), self.dtype) for _ in k.dfg.outputs]
                    for k, _ in requests]

        # second pass: assemble the whole [G_pad, RF_DEPTH, tile] batch in
        # ONE host buffer (a single device transfer — the hot serving path
        # must not pay per-group/per-tile device dispatches), padding the
        # tile count to a power-of-two bucket with replicas of tile 0
        np_dtype = np.dtype(self.dtype)
        g_pad = 1 << (g_total - 1).bit_length()
        x_np = np.zeros((g_pad, RF_DEPTH, tile), np_dtype)
        ids_np = np.zeros(g_pad, np.int32)
        layout: dict[tuple, tuple[int, int, list[int]]] = {}
        for key, idxs, kern, slot, lens, total, n_tiles, start in specs:
            layout[key] = (start, n_tiles, lens)
            if n_tiles == 0:
                continue
            n_in = len(kern.dfg.inputs)
            buf = np.zeros((n_in, n_tiles * tile), np_dtype)
            for j in range(n_in):
                buf[j, :total] = np.concatenate(
                    [np.asarray(requests[i][1][j], np_dtype) for i in idxs])
            x_np[start:start + n_tiles, :n_in, :] = \
                buf.reshape(n_in, n_tiles, tile).transpose(1, 0, 2)
            ids_np[start:start + n_tiles] = slot
        x_np[g_total:] = x_np[0]
        ids_np[g_total:] = ids_np[0]
        x_stack = jnp.asarray(x_np)
        id_arr = jnp.asarray(ids_np)

        if self.backend == "pallas":
            from repro.kernels.tmfu import ops as tmfu_ops
            ys = tmfu_ops.tmfu_pipeline_multi(bank, id_arr, x_stack)
        else:
            ys = vm.vm_exec_multi(bank.tree(), bank.out_idx, id_arr, x_stack)

        results: list[list[jax.Array] | None] = [None] * len(requests)
        for key, idxs in groups.items():
            start, n_tiles, lens = layout[key]
            n_out = len(requests[idxs[0]][0].dfg.outputs)
            block = ys[start:start + n_tiles]          # [nt, max_out, tile]
            flat = jnp.moveaxis(block, 1, 0).reshape(ys.shape[1], -1)
            off = 0
            for i, n in zip(idxs, lens):
                results[i] = [flat[j, off:off + n] for j in range(n_out)]
                off += n
        return results

    # ------------------------------------------------------------ timing
    def time_context_switch(self, kernel: CompiledKernel,
                            iters: int = 20) -> float:
        """Median seconds to swap a kernel onto the live overlay."""
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            ctx = self.load(kernel)
            jax.block_until_ready(ctx.op)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))


def spatial_jit(dfg: DFG):
    """SCFU-SCN analogue: the DFG inlined into its own XLA program."""

    @jax.jit
    def run(xs: list[jax.Array]) -> list[jax.Array]:
        env = {name: x for name, x in zip(dfg.inputs, xs)}
        out = dfg_eval(dfg, env)
        return [out[o] for o in dfg.outputs]

    return run


def time_recompile(dfg: DFG, xs, iters: int = 3) -> float:
    """Seconds for the vendor-flow analogue: fresh trace + XLA compile."""
    ts = []
    for _ in range(iters):
        fn = spatial_jit(dfg)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(xs))
        ts.append(time.perf_counter() - t0)
        fn._clear_cache()
    return float(np.median(ts))
