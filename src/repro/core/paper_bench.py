"""The paper's benchmark set (Table II) + the 'gradient' worked example.

``chebyshev`` and ``gradient`` are written out as kernel source (frontend
path); the other seven come from frozen DFGs reconstructed to match every
published Table II characteristic under the paper's scheduling model (the
paper cites the suites [4],[13] but does not print the kernels —
dev/search_benches.py documents the reconstruction).
"""

from __future__ import annotations

from repro.core.bench_data import BENCH_NODES
from repro.core.dfg import DFG, Node, Op
from repro.core.frontend import build_dfg

GRADIENT_SRC = """
d1 = m1 - m3
d2 = m2 - m3
d3 = m3 - m4
d4 = m3 - m5
s1 = d1 * d1
s2 = d2 * d2
s3 = d3 * d3
s4 = d4 * d4
a1 = s1 + s2
a2 = s3 + s4
out = a1 + a2
"""

CHEBYSHEV_SRC = """
t1 = x * x
t2 = 16 * t1
t3 = t2 - 20
t4 = t1 * t3
t5 = t4 + 5
t6 = t1 * t5
y = t6 * t6
"""


def gradient() -> DFG:
    """Fig. 1 medical-imaging 'gradient' kernel (5 in, 11 ops, depth 4)."""
    return build_dfg("gradient", ["m1", "m2", "m3", "m4", "m5"],
                     GRADIENT_SRC, ["out"])


def chebyshev() -> DFG:
    return build_dfg("chebyshev", ["x"], CHEBYSHEV_SRC, ["y"])


def _from_frozen(name: str) -> DFG:
    spec = BENCH_NODES[name]
    nodes = [Node(n, Op(op), tuple(args), imm)
             for (n, op, args, imm) in spec["nodes"]]
    return DFG.build(name, spec["inputs"], nodes, spec["outputs"])


#: Table II benchmark order
BENCH_NAMES = ("chebyshev", "sgfilter", "mibench", "qspline",
               "poly5", "poly6", "poly7", "poly8")


def benchmark(name: str) -> DFG:
    if name == "chebyshev":
        return chebyshev()
    if name == "gradient":
        return gradient()
    return _from_frozen(name)


def all_benchmarks() -> dict[str, DFG]:
    return {n: benchmark(n) for n in BENCH_NAMES}
