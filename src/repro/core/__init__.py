"""The paper's primary contribution: an area-efficient overlay built from
linearly-connected, time-multiplexed functional units.

Pipeline: frontend (HLL->DFG) -> schedule (ASAP staging + bypass + II) ->
isa (32-bit no-decoder words, 40-bit context stream) -> overlay executor
(compile-once VM / Pallas TMFU kernel, context switch = data swap).
Analytical models in ``area`` reproduce the paper's Tables II/III.
"""

from repro.core.bank import BankError, ContextBank
from repro.core.dfg import DFG, Node, Op
from repro.core.frontend import build_dfg
from repro.core.schedule import Schedule, schedule
from repro.core.isa import Program, encode
from repro.core.overlay import (CompiledKernel, Overlay, compile_program,
                                spatial_jit)
from repro.core.vm import dfg_eval, vm_exec, vm_exec_multi

__all__ = [
    "DFG", "Node", "Op", "build_dfg", "Schedule", "schedule", "Program",
    "encode", "CompiledKernel", "Overlay", "compile_program", "spatial_jit",
    "dfg_eval", "ContextBank", "BankError", "vm_exec", "vm_exec_multi",
]
