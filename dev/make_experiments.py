"""Assemble EXPERIMENTS.md tables from dry-run artifacts + analytic model."""
import glob
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.analytic import cell_model  # noqa: E402
from repro.configs import ARCHS, SHAPES, skip_reason  # noqa: E402


def load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], r["mesh"],
               r.get("layout", "2d"), bool(r.get("mixed")))
        out[key] = r
    return out


def fmt(x, unit="", nd=3):
    if x is None:
        return "—"
    if isinstance(x, float):
        if x == 0:
            return "0"
        mag = abs(x)
        if mag < 1e-3 or mag >= 1e4:
            return f"{x:.2e}{unit}"
        return f"{x:.{nd}g}{unit}"
    return f"{x}{unit}"


def dryrun_table(tm):
    lines = ["| arch | shape | mesh | status | compile_s | HLO flops/dev | "
             "temp GB/dev | collectives (count) |",
             "|---|---|---|---|---|---|---|---|"]
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                r = tm.get((arch, shape, mesh, "2d", False))
                if r is None:
                    lines.append(f"| {arch} | {shape} | {mesh} | MISSING "
                                 "| | | | |")
                    continue
                if "skipped" in r:
                    lines.append(f"| {arch} | {shape} | {mesh} | skip "
                                 f"(sub-quadratic-only shape) | | | | |")
                    continue
                if "error" in r:
                    lines.append(f"| {arch} | {shape} | {mesh} | **FAIL** "
                                 f"| | | | |")
                    continue
                mem = r.get("memory", {})
                colls = r.get("collectives", {})
                cstr = " ".join(f"{k}:{v['count']}" for k, v in
                                sorted(colls.items()))
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok "
                    f"| {fmt(r['timing']['compile_s'], nd=2)} "
                    f"| {fmt(r['roofline']['hlo_flops_per_device'])} "
                    f"| {fmt(mem.get('temp_size_in_bytes', 0) / 2**30, nd=3)} "
                    f"| {cstr} |")
    return "\n".join(lines)


def roofline_table():
    lines = ["| arch | shape | t_compute | t_memory | t_collective | "
             "bottleneck | MODEL_FLOPs/dev | useful/HLO | MFU@roofline | "
             "what moves the dominant term |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    moves = {
        "collective": "drop TP activation all-reduces (pure-FSDP layout) "
                      "and reduce weight-gather/grad wire to bf16 (mixed)",
        "memory": "weights+cache streaming bound: quantize KV (int8), "
                  "fuse decode attention, larger decode batch per chip",
        "compute": "at the MXU roof: only larger per-chip batch or fewer "
                   "FLOPs (e.g. window attention) help",
    }
    for arch in ARCHS:
        for shape in SHAPES:
            reason = skip_reason(arch, shape)
            if reason:
                lines.append(f"| {arch} | {shape} | — | — | — | skip | — "
                             f"| — | — | {reason[:60]} |")
                continue
            m = cell_model(arch, shape)
            t = m.terms
            lines.append(
                f"| {arch} | {shape} | {fmt(t['compute'])}s "
                f"| {fmt(t['memory'])}s | {fmt(t['collective'])}s "
                f"| **{m.bottleneck}** | {fmt(m.model_flops_dev)} "
                f"| {fmt(m.model_flops_dev / m.flops_dev, nd=2)} "
                f"| {m.mfu_at_roofline:.3f} | {moves[m.bottleneck][:70]} |")
    return "\n".join(lines)


def variant_table(var):
    lines = ["| cell | layout | mixed | analytic step_s | MFU@roofline | "
             "compile | parsed wire GB/dev |",
             "|---|---|---|---|---|---|---|"]
    for arch in ("mamba2-2.7b", "zamba2-7b", "deepseek-7b"):
        for layout, mixed in (("2d", False), ("2d", True),
                              ("fsdp", False), ("fsdp", True)):
            m = cell_model(arch, "train_4k", layout, mixed)
            r = var.get((arch, "train_4k", "single", layout, mixed))
            if r is None and layout == "2d" and not mixed:
                r = load("artifacts/dryrun").get(
                    (arch, "train_4k", "single", "2d", False))
            status = "—"
            wire = None
            if r is not None and "error" not in r and "skipped" not in r:
                status = f"ok ({r['timing']['compile_s']:.0f}s)"
                wire = r["roofline"][
                    "collective_wire_bytes_per_device"] / 2**30
            elif r is not None:
                status = "FAIL"
            lines.append(
                f"| {arch} train_4k | {layout} | {int(mixed)} "
                f"| {m.step_time:.3f} | {m.mfu_at_roofline:.3f} "
                f"| {status} | {fmt(wire, nd=3)} |")
    return "\n".join(lines)


if __name__ == "__main__":
    tm = load("artifacts/dryrun")
    var = load("artifacts/dryrun_variants")
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("dryrun", "all"):
        print("<!-- DRYRUN TABLE -->")
        print(dryrun_table(tm))
    if which in ("roofline", "all"):
        print("<!-- ROOFLINE TABLE -->")
        print(roofline_table())
    if which in ("variants", "all"):
        print("<!-- VARIANT TABLE -->")
        print(variant_table(var))
