"""Dev tool: synthesize benchmark DFGs matching Table II characteristics.

The paper cites its benchmark suites but does not print the kernel source,
so we reconstruct DFGs whose published characteristics (i/o, edges, ops,
depth, parallelism, II, eOPC) all match Table II exactly under the paper's
own scheduling/II model.  Hill-climbing over a layered-graph parameterization
scored by the real scheduler; found graphs frozen to
src/repro/core/bench_data.py.
"""
import pprint
import random
import sys

sys.path.insert(0, "src")

from repro.core.dfg import DFG, Node, Op  # noqa: E402
from repro.core.isa import encode  # noqa: E402
from repro.core.schedule import schedule  # noqa: E402

TARGETS = {
    # name: (n_in, edges, ops, depth, II)
    "sgfilter": (2, 27, 18, 9, 10),
    "mibench": (3, 22, 13, 6, 11),
    "qspline": (7, 50, 26, 8, 18),
    "poly5": (3, 43, 27, 9, 14),
    "poly6": (3, 72, 44, 11, 17),
    "poly7": (3, 62, 39, 13, 17),
    "poly8": (3, 51, 32, 11, 15),
}

BIN_OPS = [Op.ADD, Op.SUB, Op.MUL]
CONST_OPS_ = [Op.MULC, Op.ADDC, Op.SUBC]


class State:
    """Layered graph: per-op (level, kind, a_arg, b_arg)."""

    def __init__(self, rng, n_in, ops, depth):
        self.rng = rng
        self.n_in = n_in
        self.depth = depth
        for _ in range(200):
            sizes = [1] * depth
            for _ in range(ops - depth):
                sizes[rng.randrange(max(1, depth - 1))] += 1
            # consumption capacity: level l values can only be consumed by
            # ops at levels > l (each op has at most 2 operand slots)
            ok = all(sizes[l] <= 2 * sum(sizes[l + 1:])
                     for l in range(depth - 1))
            ok = ok and n_in <= 2 * ops
            if ok:
                break
        self.level = []   # per op
        for lv in range(1, depth + 1):
            self.level += [lv] * sizes[lv - 1]
        self.names = [f"n{i}" for i in range(ops)]
        self.kind = [rng.random() < 0.5 for _ in range(ops)]  # binary?
        self.a = [None] * ops
        self.b = [None] * ops
        for i in range(ops):
            self.a[i] = self._pick(self.level[i] - 1)
            if self.kind[i]:
                self.b[i] = self._pick_any(self.level[i] - 1)
        self.repair()

    def values_at(self, lv):
        if lv == 0:
            return [f"x{i}" for i in range(self.n_in)]
        return [self.names[i] for i in range(len(self.names))
                if self.level[i] == lv]

    def _pick(self, lv):
        return self.rng.choice(self.values_at(lv))

    def _pick_any(self, max_lv):
        lv = self.rng.randrange(0, max_lv + 1)
        vs = self.values_at(lv)
        return self.rng.choice(vs) if vs else self._pick(max_lv)

    def level_of(self, v):
        if v.startswith("x"):
            return 0
        return self.level[self.names.index(v)]

    def repair(self):
        """Ensure every input/non-final op is consumed."""
        ops = len(self.names)
        final = max(self.level)
        for _ in range(25):
            used = set(self.a) | {b for b in self.b if b is not None}
            orphans = [f"x{i}" for i in range(self.n_in)
                       if f"x{i}" not in used]
            orphans += [self.names[i] for i in range(ops)
                        if self.level[i] < final and self.names[i] not in used]
            if not orphans:
                return True
            for v in orphans:
                lv = self.level_of(v)
                cands = [i for i in range(ops) if self.level[i] > lv]
                self.rng.shuffle(cands)
                done = False
                for i in cands:
                    if not self.kind[i]:
                        self.kind[i] = True
                        self.b[i] = v
                        done = True
                        break
                if not done:
                    # rewire a binary op whose b-value has other consumers
                    counts = {}
                    for j in range(ops):
                        if self.b[j] is not None:
                            counts[self.b[j]] = counts.get(self.b[j], 0) + 1
                        counts[self.a[j]] = counts.get(self.a[j], 0) + 1
                    for i in cands:
                        if self.kind[i] and self.b[i] != self.a[i] \
                                and counts.get(self.b[i], 0) > 1:
                            self.b[i] = v
                            done = True
                            break
                if not done:
                    for i in cands:
                        if self.kind[i] and self.b[i] != self.a[i]:
                            self.b[i] = v
                            done = True
                            break
                if not done:
                    return False
        used = set(self.a) | {b for b in self.b if b is not None}
        return all(f"x{i}" in used for i in range(self.n_in))

    def mutate(self):
        i = self.rng.randrange(len(self.names))
        r = self.rng.random()
        if r < 0.35:
            self.kind[i] = not self.kind[i]
            self.b[i] = self._pick_any(self.level[i] - 1) if self.kind[i] else None
        elif r < 0.7:
            if self.kind[i]:
                self.b[i] = self._pick_any(self.level[i] - 1)
            else:
                self.a[i] = self._pick(self.level[i] - 1)
        else:
            self.a[i] = self._pick(self.level[i] - 1)
        self.repair()

    def to_dfg(self, name):
        nodes = []
        for i, n in enumerate(self.names):
            if self.kind[i]:
                if self.b[i] == self.a[i]:
                    nodes.append(Node(n, Op.SQR, (self.a[i],)))
                else:
                    op = BIN_OPS[i % 3]
                    nodes.append(Node(n, op, (self.a[i], self.b[i])))
            else:
                op = CONST_OPS_[i % 3]
                nodes.append(Node(n, op, (self.a[i],),
                                  imm=float(2 + i % 7)))
        out = self.names[max(range(len(self.names)),
                             key=lambda i: self.level[i])]
        return DFG.build(name, [f"x{i}" for i in range(self.n_in)],
                         nodes, [out])

    def snapshot(self):
        return (list(self.kind), list(self.a), list(self.b))

    def restore(self, snap):
        self.kind, self.a, self.b = [list(x) for x in snap]


def n_orphans(state):
    used = set(state.a) | {b for b in state.b if b is not None}
    final = max(state.level)
    k = sum(1 for i in range(state.n_in) if f"x{i}" not in used)
    k += sum(1 for i, n in enumerate(state.names)
             if state.level[i] < final and n not in used)
    return k


def score(state, name, edges, depth, ii):
    orph = n_orphans(state)
    if orph:
        return 200 + 50 * orph, None, None
    try:
        dfg = state.to_dfg(name)
        st = dfg.stats()
        if st["graph_depth"] != depth:
            return 10_000, None, None
        sch = schedule(dfg)
        encode(sch)
        s = 3 * abs(sch.ii - ii) + abs(st["graph_edges"] - edges)
        return s, dfg, sch
    except Exception:
        return 10_000, None, None


def search(name, n_in, edges, ops, depth, ii, budget=60.0):
    import time
    rng = random.Random(0xBEEF ^ hash(name) % 65536)
    t0 = time.time()
    best_overall = None
    while time.time() - t0 < budget:
        st = None
        for _ in range(50):
            cand = State(rng, n_in, ops, depth)
            if cand.repair():
                st = cand
                break
        if st is None:
            continue
        cur, dfg, sch = score(st, name, edges, depth, ii)
        stall = 0
        while stall < 2000 and time.time() - t0 < budget:
            snap = st.snapshot()
            st.mutate()
            new, ndfg, nsch = score(st, name, edges, depth, ii)
            if new <= cur:
                if new < cur:
                    stall = 0
                cur, dfg, sch = new, ndfg, nsch
                if cur == 0:
                    return dfg, sch
            else:
                st.restore(snap)
                stall += 1
        if dfg is not None and (best_overall is None or cur < best_overall[0]):
            best_overall = (cur, dfg, sch)
    if best_overall:
        print(f"  [!] {name}: best residual score {best_overall[0]}")
        return best_overall[1], best_overall[2]
    return None, None


def freeze(dfg):
    rows = []
    for n in dfg.topo_order():
        node = dfg.nodes[n]
        rows.append((node.name, int(node.op), list(node.args),
                     node.imm if node.imm is None else float(node.imm)))
    return rows


def main():
    out = {}
    for name, (n_in, edges, ops, depth, ii) in TARGETS.items():
        dfg, sch = search(name, n_in, edges, ops, depth, ii)
        if dfg is None:
            print(f"{name}: NOT FOUND")
            continue
        st = dfg.stats()
        print(f"{name}: {st} II={sch.ii} eOPC={sch.eopc} "
              f"ctx={encode(sch).context_bytes}B")
        out[name] = {
            "inputs": [f"x{i}" for i in range(n_in)],
            "outputs": list(dfg.outputs),
            "nodes": freeze(dfg),
        }
    with open("src/repro/core/bench_data.py", "w") as f:
        f.write('"""Frozen benchmark DFGs matching Table II '
                '(generated by dev/search_benches.py)."""\n\n')
        f.write("BENCH_NODES = ")
        f.write(pprint.pformat(out, width=100))
        f.write("\n")


if __name__ == "__main__":
    main()
