"""Frame codec: property round trips, rejection, and stream adaptation.

Three layers:

* ROUND TRIP — property tests (hypothesis shim) over random nested
  messages with embedded ndarrays: every codec must reproduce the
  message exactly, arrays BIT-FOR-BIT (the loopback soak's oracle
  parity rides on this), and ``decode_frame`` must report the exact
  frame length so frames can be parsed back-to-back from one buffer.
* REJECTION — truncation at every prefix length, declared lengths past
  the size cap (refused before any payload is read), garbage magic,
  unknown codec ids, undecodable payloads, and version-mismatched
  headers each raise their own typed error; nothing is "best-effort
  parsed".
* STREAMS — ``read_frame``/``write_frame`` against a fed
  ``StreamReader``: clean EOF between frames is ``None``, EOF inside a
  frame is :class:`TruncatedFrameError`, and the ``on_bytes`` hook sees
  exactly header + payload.

Tests drive their own ``asyncio.run``; no async pytest plugin.
"""

import asyncio
import struct

import numpy as np
import pytest

from repro.launch import transport
from repro.launch.transport import (CODECS, HEADER_BYTES,
                                    FrameTooLargeError, MalformedFrameError,
                                    PROTOCOL_VERSION, ProtocolVersionError,
                                    TruncatedFrameError, decode_frame,
                                    default_codec, encode_frame, read_frame,
                                    write_frame)
from repro.testing import given, settings, st

DTYPES = ("float32", "float64", "int32", "uint8")


def _random_message(seed: int) -> dict:
    """A random nested message shaped like real gateway traffic."""
    rng = np.random.RandomState(seed)
    n = int(rng.randint(1, 4))
    xs = [rng.uniform(-100, 100,
                      size=tuple(rng.randint(1, 5,
                                             size=int(rng.randint(1, 3)))))
          .astype(DTYPES[int(rng.randint(len(DTYPES)))]) for _ in range(n)]
    return {
        "type": "submit", "req": int(rng.randint(0, 2 ** 31)),
        "key": ["kernel", "a" * 40],
        "xs": xs,
        "meta": {"nested": [1, 2.5, "s", None, True],
                 "empty": [], "flag": bool(rng.randint(2))},
    }


def _assert_same(a, b):
    assert type(a) is type(b) or (isinstance(a, (list, tuple))
                                  and isinstance(b, (list, tuple)))
    if isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            _assert_same(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            _assert_same(x, y)
    elif isinstance(a, np.ndarray):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a, b)
    else:
        assert a == b


# ============================================================= round trip
@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.sampled_from(CODECS))
def test_roundtrip_property(seed, codec):
    msg = _random_message(seed)
    frame = encode_frame(msg, codec)
    out, consumed = decode_frame(frame)
    assert consumed == len(frame)
    _assert_same(msg, out)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_back_to_back_frames(seed):
    """bytes_consumed lets a buffer consumer parse concatenated frames."""
    msgs = [_random_message(seed), {"type": "flush", "req": seed},
            _random_message(seed + 1)]
    buf = b"".join(encode_frame(m) for m in msgs)
    off = 0
    for want in msgs:
        got, used = decode_frame(buf[off:])
        _assert_same(want, got)
        off += used
    assert off == len(buf)


def test_array_bit_exactness_all_dtypes():
    """Raw-bytes carriage: NaNs, -0.0, denormals survive both codecs."""
    arrs = [np.array([0.0, -0.0, np.nan, np.inf, -np.inf, 1e-45],
                     dtype=np.float32),
            np.array([[1, -2], [2 ** 31 - 1, -2 ** 31]], dtype=np.int32),
            np.arange(12, dtype=np.float64).reshape(3, 4) * np.pi]
    for codec in CODECS:
        out, _ = decode_frame(encode_frame({"xs": arrs}, codec))
        for a, b in zip(arrs, out["xs"]):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_array_equal(a, b)


def test_default_codec_is_supported():
    assert default_codec() in CODECS
    assert "json" in CODECS             # the always-available fallback


# ============================================================== rejection
def test_truncated_at_every_prefix():
    frame = encode_frame({"type": "hello", "n": 7}, "json")
    for cut in range(len(frame)):
        with pytest.raises(TruncatedFrameError):
            decode_frame(frame[:cut])
    # TruncatedFrameError IS a MalformedFrameError (one except clause
    # catches both for consumers that don't care which)
    assert issubclass(TruncatedFrameError, MalformedFrameError)


def test_oversized_rejected_both_directions():
    big = {"xs": [np.zeros(4096, dtype=np.float32)]}
    with pytest.raises(FrameTooLargeError):
        encode_frame(big, "json", max_bytes=64)
    frame = encode_frame(big, "json")
    with pytest.raises(FrameTooLargeError):
        decode_frame(frame, max_bytes=64)


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_garbage_rejected(seed):
    rng = np.random.RandomState(seed)
    junk = rng.bytes(int(rng.randint(HEADER_BYTES, 64)))
    if junk[:2] == transport.MAGIC:     # astronomically unlikely; skip
        return
    with pytest.raises(MalformedFrameError):
        decode_frame(junk)


def test_undecodable_payload_rejected():
    frame = transport._HEADER.pack(transport.MAGIC, PROTOCOL_VERSION,
                                   transport._CODEC_IDS["json"], 4) \
        + b"\xff\xfe\x00{"
    with pytest.raises(MalformedFrameError):
        decode_frame(frame)


def test_unknown_codec_id_rejected():
    frame = transport._HEADER.pack(transport.MAGIC, PROTOCOL_VERSION,
                                   250, 2) + b"{}"
    with pytest.raises(MalformedFrameError):
        decode_frame(frame)
    with pytest.raises(MalformedFrameError):
        encode_frame({}, "pickle")      # never, ever


def test_version_mismatch_rejected():
    frame = transport._HEADER.pack(transport.MAGIC, PROTOCOL_VERSION + 1,
                                   transport._CODEC_IDS["json"], 2) + b"{}"
    with pytest.raises(ProtocolVersionError):
        decode_frame(frame)


def test_header_layout_frozen():
    """The on-wire header is a compatibility contract: 8 bytes, magic +
    version + codec + big-endian length."""
    assert HEADER_BYTES == 8
    frame = encode_frame({}, "json")
    magic, version, codec_id, length = struct.unpack(">2sBBI",
                                                     frame[:HEADER_BYTES])
    assert magic == transport.MAGIC
    assert version == PROTOCOL_VERSION
    assert length == len(frame) - HEADER_BYTES


# ================================================================ streams
def _feed_reader(data: bytes) -> asyncio.StreamReader:
    r = asyncio.StreamReader()
    r.feed_data(data)
    r.feed_eof()
    return r


def test_read_frame_stream_roundtrip_and_eof():
    msgs = [{"type": "a", "i": 1}, {"type": "b",
                                    "x": np.ones(3, dtype=np.float32)}]

    async def main():
        r = _feed_reader(b"".join(encode_frame(m) for m in msgs))
        sizes = []
        out = [await read_frame(r, on_bytes=sizes.append),
               await read_frame(r, on_bytes=sizes.append)]
        assert await read_frame(r) is None          # clean EOF
        assert sizes == [len(encode_frame(m)) for m in msgs]
        return out

    out = asyncio.run(main())
    _assert_same(msgs[0], out[0])
    np.testing.assert_array_equal(out[1]["x"], msgs[1]["x"])


def test_read_frame_stream_truncation_and_cap():
    frame = encode_frame({"type": "a", "pad": "x" * 100}, "json")

    async def truncated():
        with pytest.raises(TruncatedFrameError):
            await read_frame(_feed_reader(frame[:HEADER_BYTES + 10]))
        with pytest.raises(TruncatedFrameError):
            await read_frame(_feed_reader(frame[:3]))

    async def over_cap():
        with pytest.raises(FrameTooLargeError):
            await read_frame(_feed_reader(frame), max_bytes=16)

    asyncio.run(truncated())
    asyncio.run(over_cap())


def test_write_frame_counts_bytes():
    async def main():
        r = asyncio.StreamReader()

        class _W:                        # minimal StreamWriter stand-in
            def write(self, b):
                r.feed_data(b)

            async def drain(self):
                pass

        msg = {"type": "result", "ys": [np.ones(8, dtype=np.float32)]}
        n = await write_frame(_W(), msg)
        r.feed_eof()
        got = await read_frame(r)
        assert n == len(encode_frame(msg))
        np.testing.assert_array_equal(got["ys"][0], msg["ys"][0])

    asyncio.run(main())
