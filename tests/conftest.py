"""Shared test configuration: multi-device CPU CI via fake host devices.

Setting ``JAX_DEVICES=N`` (N > 1) in the environment makes the whole test
session run against N fake CPU devices by injecting
``--xla_force_host_platform_device_count=N`` into ``XLA_FLAGS`` *before*
jax initialises — the same mechanism ``launch/dryrun.py`` uses for its
512-chip dry runs.  CI runs the suite both ways (see the ``JAX_DEVICES=8``
matrix job in .github/workflows/ci.yml); locally::

    JAX_DEVICES=8 PYTHONPATH=src python -m pytest tests/test_sharded_serving.py

This must happen at conftest IMPORT time: pytest imports conftest before
any test module, but once any module imports jax the backend is fixed and
the flag is ignored.  The injection is guarded — it does nothing when
JAX_DEVICES is unset/1 (plain single-device runs are the default) or when
the flag is already present (e.g. a caller exported XLA_FLAGS itself).
"""

import os

import pytest

_FLAG = "--xla_force_host_platform_device_count"


def _force_fake_devices() -> int | None:
    n = os.environ.get("JAX_DEVICES", "")
    if not n.isdigit() or int(n) <= 1:
        return None
    if "jax" in __import__("sys").modules:  # pragma: no cover - ordering bug
        raise RuntimeError(
            "conftest must run before jax is imported for JAX_DEVICES "
            "to take effect")
    flags = os.environ.get("XLA_FLAGS", "")
    if _FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_FLAG}={int(n)}".strip()
    return int(n)


_REQUESTED_DEVICES = _force_fake_devices()


@pytest.fixture(scope="session")
def device_count() -> int:
    """Live JAX device count (after any JAX_DEVICES forcing)."""
    import jax
    n = jax.device_count()
    if _REQUESTED_DEVICES is not None:
        assert n == _REQUESTED_DEVICES, (
            f"JAX_DEVICES={_REQUESTED_DEVICES} requested but jax reports "
            f"{n} devices — something imported jax before conftest")
    return n


@pytest.fixture(scope="session")
def multi_device(device_count) -> int:
    """Skip the test unless the session really has >= 2 devices."""
    if device_count < 2:
        pytest.skip("needs >= 2 devices (run with JAX_DEVICES=8)")
    return device_count
