"""Error-path tests: scheduler rejects, encoder capacity, context fallbacks.

The mapping flow must fail loudly at the stage that owns the invariant:
schedule() rejects graphs the linear pipeline cannot host, encode() rejects
capacity overflows, make_context() rejects programs deeper than the
configured executor, and _output_slots falls back sanely when a Program
arrives without the compile_program side table.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dfg import DFG, DFGError, Node, Op
from repro.core.frontend import build_dfg
from repro.core.isa import EncodeError, IM_DEPTH, encode
from repro.core.overlay import compile_program
from repro.core.paper_bench import benchmark, gradient
from repro.core.schedule import ScheduleError, schedule
from repro.core.vm import (_output_slots, dfg_eval, make_context,
                           pad_inputs, vm_exec)


# --------------------------------------------------------------- scheduler
def test_schedule_rejects_empty_dfg():
    # bypass DFG.build validation (which rejects unused inputs) to hit the
    # scheduler's own emptiness guard
    empty = DFG(name="empty", inputs=("x",), nodes={}, outputs=())
    with pytest.raises(ScheduleError, match="empty"):
        schedule(empty)


def test_schedule_rejects_dead_value_mid_pipeline():
    # 'a' is produced at stage 1 and never consumed nor output: the linear
    # interconnect streams every result forward, so there is no legal slot.
    nodes = {
        "a": Node("a", Op.ADDC, ("x",), imm=1.0),
        "b": Node("b", Op.ADDC, ("x",), imm=2.0),
        "c": Node("c", Op.SQR, ("b",)),
    }
    dead = DFG(name="dead", inputs=("x",), nodes=nodes, outputs=("c",))
    with pytest.raises(ScheduleError, match="dead value"):
        schedule(dead)


def test_dfg_build_rejects_dead_node_up_front():
    with pytest.raises(DFGError, match="dead node"):
        DFG.build("d", ["x"], [Node("a", Op.ADDC, ("x",), imm=1.0),
                               Node("b", Op.SQR, ("x",))], ["b"])


# ----------------------------------------------------------------- encoder
def test_encode_rejects_instruction_memory_overflow():
    # a single-stage fan-out wider than IM_DEPTH: every op at ASAP level 1
    n = IM_DEPTH + 1
    lines = [f"t{i} = x * {i + 2}" for i in range(n)]
    # fold the fan-out back down so validation passes (dead code illegal)
    acc = "t0"
    for i in range(1, n):
        lines.append(f"s{i} = {acc} + t{i}")
        acc = f"s{i}"
    dfg = build_dfg("wide", ["x"], "\n".join(lines), [acc])
    with pytest.raises(EncodeError, match="instruction slots"):
        encode(schedule(dfg))


def test_encode_rejects_constant_table_overflow():
    n = 10  # > CONST_DEPTH=8 immediates in one stage
    lines = [f"t{i} = x + {i}.5" for i in range(n)]
    acc = "t0"
    for i in range(1, n):
        lines.append(f"s{i} = {acc} + t{i}")
        acc = f"s{i}"
    dfg = build_dfg("consty", ["x"], "\n".join(lines), [acc])
    with pytest.raises(EncodeError, match="constants"):
        encode(schedule(dfg))


# ------------------------------------------------------------- make_context
def test_make_context_rejects_stage_overflow():
    prog = compile_program(gradient()).program          # 4 stages
    with pytest.raises(ValueError, match="stages > s_max"):
        make_context(prog, s_max=2)


def test_make_context_accepts_exact_fit():
    prog = compile_program(gradient()).program
    ctx = make_context(prog, s_max=4)
    assert ctx.op.shape == (4, IM_DEPTH)


# ------------------------------------------------------- _output_slots path
def test_output_slots_default_fallback_runs_correctly():
    """encode() without compile_program's side table: the default (last
    n_outputs instructions of the final stage) must still match the oracle
    for kernels whose outputs are the final stage's trailing instructions."""
    dfg = benchmark("chebyshev")
    prog = encode(schedule(dfg))                        # no _output_slots
    assert not hasattr(prog, "_output_slots")
    n = len(prog.images[-1].words)
    np.testing.assert_array_equal(
        _output_slots(prog), np.arange(n - prog.n_outputs, n))
    ctx = make_context(prog)
    rng = np.random.RandomState(2)
    xs = [rng.uniform(-1, 1, (64,)).astype(np.float32) for _ in dfg.inputs]
    ys = vm_exec(ctx.tree(), ctx.out_idx,
                 pad_inputs([jnp.asarray(v) for v in xs]))
    ref = dfg_eval(dfg, {m: jnp.asarray(v)
                         for m, v in zip(dfg.inputs, xs)})
    np.testing.assert_allclose(np.asarray(ys[0]),
                               np.asarray(ref[dfg.outputs[0]]),
                               rtol=1e-6, atol=1e-6)


def test_output_slots_side_table_wins_over_default():
    k = compile_program(gradient())
    np.testing.assert_array_equal(_output_slots(k.program),
                                  k.program._output_slots)
