"""Core overlay tests: paper-claims reproduction + functional correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import area
from repro.testing import given, settings, st
from repro.core.area import PAPER_BY_NAME, area_eslices, throughput_gops
from repro.core.dfg import DFG, DFGError, Node, Op
from repro.core.frontend import build_dfg
from repro.core.isa import encode, pack_word, unpack_word
from repro.core.overlay import Overlay, compile_program, spatial_jit
from repro.core.paper_bench import (BENCH_NAMES, all_benchmarks, benchmark,
                                    gradient)
from repro.core.schedule import schedule
from repro.core.vm import dfg_eval


# --------------------------------------------------------------- Table II
@pytest.mark.parametrize("name", BENCH_NAMES)
def test_table2_row(name):
    """Every published Table II column must be reproduced exactly."""
    row = PAPER_BY_NAME[name]
    dfg = benchmark(name)
    sch = schedule(dfg)
    st_ = dfg.stats()
    assert st_["io_nodes"] == (row.n_in, row.n_out)
    assert st_["graph_edges"] == row.edges
    assert st_["op_nodes"] == row.ops
    assert st_["graph_depth"] == row.depth
    # paper truncates/round-halves parallelism inconsistently (2.16 = 13/6)
    assert abs(st_["average_parallelism"] - row.parallelism) < 0.02
    assert sch.ii == row.ii
    assert abs(sch.eopc - row.eopc) < 0.05
    assert sch.n_fus == row.depth


# --------------------------------------------------------------- Table III
@pytest.mark.parametrize("name", BENCH_NAMES)
def test_table3_row(name):
    """Analytical area/throughput models reproduce Table III."""
    row = PAPER_BY_NAME[name]
    sch = schedule(benchmark(name))
    assert area_eslices(sch.n_fus) == row.area_eslices
    assert abs(throughput_gops(row.ops, sch.ii) - row.tput_gops) < 0.005
    # sanity on the published comparison direction (6x-18x tput gap)
    ratio = row.scfu_tput / throughput_gops(row.ops, sch.ii)
    assert 5.9 < ratio < 21.0
    assert row.area_eslices < row.scfu_area


# ------------------------------------------------------------ gradient ex.
def test_gradient_worked_example():
    """Section III: II=11 (TM), 17 (single FU), 11 FUs spatial."""
    sch = schedule(gradient())
    assert sch.n_fus == 4
    assert sch.ii == 11
    assert sch.single_fu_ii == 17
    assert sch.spatial_fus == 11
    # stage shape from Table I: loads 5/4/4/2, ops 4/4/2/1
    assert [s.n_loads for s in sch.stages] == [5, 4, 4, 2]
    assert [s.n_instrs for s in sch.stages] == [4, 4, 2, 1]


def test_gradient_table1_trace():
    """Cycle-accurate trace matches the published Table I rows."""
    sch = schedule(gradient())
    rows = dict((c, a) for c, a in sch.cycle_trace(n_iters=3))
    assert rows[1][0] == "Load R0"
    assert rows[6][0] == "SUB (R0 R2)"
    assert rows[8][0] == "SUB (R2 R3)" and rows[8][1] == "Load R0"
    assert rows[12][1] == "SQR (R0 R0)" and rows[12][0] == "Load R0"
    assert rows[14][2] == "Load R0"
    assert rows[18][2] == "ADD (R0 R1)"
    assert rows[20][3] == "Load R0"
    assert rows[22][3] == "ADD (R0 R1)"
    # period = II
    assert rows[12 + 11][1] == rows[12][1]


# ------------------------------------------------------- context switching
def test_context_bytes_range():
    """Paper Section V: contexts are a few hundred bytes, worst ~82 words."""
    progs = [encode(schedule(d)) for d in all_benchmarks().values()]
    lo = min(p.context_bytes for p in progs)
    hi = max(p.context_bytes for p in progs)
    assert 50 <= lo <= 80          # paper: 65 B
    assert 330 <= hi <= 460        # paper: 410 B
    worst_us = max(p.context_switch_us() for p in progs)
    assert worst_us < 0.35         # paper: 0.27 us @300 MHz
    assert worst_us < area.SCFU_CONTEXT_US / 10
    assert worst_us < area.PR_CONTEXT_US / 100


# ----------------------------------------------------------------- ISA
@given(op=st.sampled_from(list(Op)), dest=st.integers(0, 31),
       a=st.integers(0, 31), b=st.integers(0, 31))
def test_isa_pack_roundtrip(op, dest, a, b):
    w = pack_word(op, dest, a, b)
    assert 0 <= w < 2 ** 32
    assert unpack_word(w) == (op, dest, a, b)


def test_im_capacity_respected():
    for d in all_benchmarks().values():
        p = encode(schedule(d))
        for img in p.images:
            assert len(img.words) <= 32
            assert img.n_loads <= 24
            assert len(img.consts) <= 8


# --------------------------------------------------------------- VM oracle
@pytest.mark.parametrize("name", BENCH_NAMES + ("gradient",))
def test_vm_matches_oracle(name):
    dfg = benchmark(name)
    ov = Overlay()
    ctx = ov.load(compile_program(dfg))
    rng = np.random.RandomState(42)
    xs = [rng.uniform(-2, 2, size=(128,)).astype(np.float32)
          for _ in dfg.inputs]
    ys = ov(ctx, xs)
    ref = dfg_eval(dfg, {n: jnp.asarray(v) for n, v in zip(dfg.inputs, xs)})
    for o, y in zip(dfg.outputs, ys):
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref[o]),
                                   rtol=1e-6, atol=1e-6)


def test_vm_context_switch_no_recompile():
    """One executable serves every kernel: swap = data movement only."""
    ov = Overlay()
    ker_a = compile_program(benchmark("chebyshev"))
    ker_b = compile_program(benchmark("poly6"))
    xs1 = [np.ones(64, np.float32)]
    xs3 = [np.ones(64, np.float32)] * 3
    from repro.core import vm as vm_mod
    ov(ov.load(ker_a), xs1)
    n0 = vm_mod.vm_exec._cache_size()
    ov(ov.load(ker_b), xs3)   # same shapes => same executable
    assert vm_mod.vm_exec._cache_size() == n0


def test_spatial_jit_matches_vm():
    dfg = benchmark("poly5")
    xs = [np.random.RandomState(i).randn(32).astype(np.float32)
          for i in range(3)]
    spatial = spatial_jit(dfg)(xs)
    ov = Overlay()
    tm = ov(ov.load(compile_program(dfg)), xs)
    for a, b in zip(spatial, tm):
        # XLA may fuse/reorder the inlined graph (FMA-level drift)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-4)


# ------------------------------------------------------- property: frontend
@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_random_expression_pipeline(data):
    """Random straight-line kernels: schedule+encode+VM == direct eval."""
    rng = np.random.RandomState(data.draw(st.integers(0, 2 ** 31 - 1)))
    n_in = data.draw(st.integers(1, 6))
    n_stmt = data.draw(st.integers(1, 20))
    names = [f"x{i}" for i in range(n_in)]
    used: set = set()
    lines = []
    for i in range(n_stmt):
        op = rng.choice(["+", "-", "*"])
        a = names[rng.randint(len(names))]
        used.add(a)
        if rng.rand() < 0.3:
            b = str(rng.randint(1, 9))
        else:
            b = names[rng.randint(len(names))]
            used.add(b)
        t = f"t{i}"
        lines.append(f"{t} = {a} {op} {b}")
        names.append(t)
    # fold unconsumed values into the output (dead code is illegal)
    out = f"t{n_stmt - 1}"
    dangling = [n for n in names[:-1] if n not in used]
    for j, d in enumerate(dangling):
        lines.append(f"f{j} = {out} + {d}")
        out = f"f{j}"
    src = "\n".join(lines)
    dfg = build_dfg("rand", [f"x{i}" for i in range(n_in)], src, [out])
    sch = schedule(dfg)
    assert sch.n_fus == dfg.depth
    assert sch.ii >= 3
    try:
        encode(sch)
    except Exception:
        return  # capacity overflow is a legal reject, not a bug
    ov = Overlay(s_max=max(16, sch.n_fus))
    ctx = ov.load(compile_program(dfg))
    xs = [rng.uniform(-1.5, 1.5, (16,)).astype(np.float32)
          for _ in range(n_in)]
    ys = ov(ctx, xs)
    ref = dfg_eval(dfg, {n: jnp.asarray(v)
                         for n, v in zip(dfg.inputs, xs)})
    np.testing.assert_allclose(np.asarray(ys[0]), np.asarray(ref[out]),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ DFG validity
def test_cycle_rejected():
    with pytest.raises(DFGError):
        DFG.build("c", ["x"], [Node("a", Op.ADD, ("x", "b")),
                               Node("b", Op.ADD, ("a", "x"))], ["b"])


def test_undefined_rejected():
    with pytest.raises(DFGError):
        DFG.build("u", ["x"], [Node("a", Op.ADD, ("x", "zz"))], ["a"])
