"""Runtime + distributed substrate tests (single host, simulated meshes)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, MemmapCorpus, SyntheticCorpus
from repro.testing import given, settings, st
from repro.distributed import checkpoint as C
from repro.distributed.elastic import accumulate_with_deadline
from repro.runtime import optim as O
from repro.runtime.compress import compress_decompress
from repro.runtime.pipeline import pipeline_ii


# ----------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic_loss():
    oc = O.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                     weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = O.init_opt(params)
    tgt = jnp.asarray([1.0, 1.0])
    for _ in range(60):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - tgt) ** 2))(params)
        params, state, stats = O.adamw_update(oc, grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                               atol=0.15)


def test_grad_clip_caps_update_norm():
    oc = O.OptConfig(lr=1.0, warmup_steps=0, grad_clip=1e-3)
    params = {"w": jnp.zeros(4)}
    state = O.init_opt(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, stats = O.adamw_update(oc, grads, state, params)
    assert float(stats["grad_norm"]) > 1e5  # raw norm reported


# ---------------------------------------------------------------- compression
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_compression_error_feedback_converges(seed):
    """Quantized-sum with EF ~ true value accumulated over steps."""
    rng = np.random.RandomState(seed)
    g_true = jnp.asarray(rng.randn(32).astype(np.float32))
    ef = None
    acc = jnp.zeros(32)
    T = 50
    for _ in range(T):
        gq, ef = compress_decompress({"g": g_true}, ef)
        acc = acc + gq["g"]
    np.testing.assert_allclose(np.asarray(acc) / T, np.asarray(g_true),
                               atol=0.02, rtol=0.02)


def test_compression_is_int8_rangeful():
    g = {"g": jnp.asarray([1e-4, 5.0, -3.0, 0.0])}
    gq, ef = compress_decompress(g)
    assert np.abs(np.asarray(gq["g"]) - np.asarray(g["g"])).max() < 5 / 127


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16)}}
    C.save(str(tmp_path), 7, tree, extra={"cursor": 42})
    like = jax.eval_shape(lambda: tree)
    out, step, extra = C.restore(str(tmp_path), like)
    assert step == 7 and extra["cursor"] == 42
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"a": jnp.arange(8, dtype=jnp.float32)}
    path = C.save(str(tmp_path), 1, tree)
    # flip a byte in the leaf file
    fn = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    with open(os.path.join(path, fn), "r+b") as f:
        f.seek(-2, 2)
        f.write(b"\xFF")
    with pytest.raises(IOError):
        C.restore(str(tmp_path), jax.eval_shape(lambda: tree))


def test_checkpoint_async_and_gc(tmp_path):
    ck = C.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        ck.save_async(s, tree)
    ck.wait()
    assert C.list_steps(str(tmp_path)) == [3, 4]


def test_checkpoint_atomic_latest_good(tmp_path):
    tree = {"a": jnp.zeros(3)}
    C.save(str(tmp_path), 1, tree)
    # a .tmp dir from a crashed save must be ignored
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert C.list_steps(str(tmp_path)) == [1]


# ---------------------------------------------------------------------- data
def test_synthetic_data_deterministic_and_host_disjoint():
    dc0 = DataConfig(global_batch=8, seq_len=16, vocab=100, num_hosts=2,
                     host_index=0)
    dc1 = DataConfig(global_batch=8, seq_len=16, vocab=100, num_hosts=2,
                     host_index=1)
    a = SyntheticCorpus(dc0).batch(3)["tokens"]
    a2 = SyntheticCorpus(dc0).batch(3)["tokens"]
    b = SyntheticCorpus(dc1).batch(3)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a2))
    assert not np.array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (4, 16)


def test_memmap_corpus(tmp_path):
    path = str(tmp_path / "tokens.bin")
    np.arange(1000, dtype=np.uint16).tofile(path)
    dc = DataConfig(global_batch=2, seq_len=10, vocab=5000)
    corp = MemmapCorpus(dc, path)
    b0 = corp.batch(0)["tokens"]
    assert b0.shape == (2, 10)
    np.testing.assert_array_equal(np.asarray(b0[0]), np.arange(10))


# ---------------------------------------------------- straggler mitigation
def test_deadline_skip_unbiased():
    params = {"w": jnp.asarray(2.0)}

    def grad_fn(p, mb):
        return jax.grad(lambda q: jnp.mean((q["w"] * mb) ** 2))(p)

    mbs = jnp.asarray([[1.0, 2.0], [3.0, 4.0], [5.0, 6.0], [0.5, 1.5]])
    all_ok = jnp.ones(4, bool)
    g_all, kept = accumulate_with_deadline(grad_fn, params, mbs, all_ok)
    assert int(kept) == 4
    some = jnp.asarray([True, False, True, True])
    g_some, kept2 = accumulate_with_deadline(grad_fn, params, mbs, some)
    assert int(kept2) == 3
    # rescaled mean over kept microbatches
    manual = sum(np.asarray(grad_fn(params, mbs[i])["w"])
                 for i in (0, 2, 3)) / 3
    np.testing.assert_allclose(np.asarray(g_some["w"]), manual, rtol=1e-6)


# ------------------------------------------------------------------ pipeline
def test_pipeline_ii_model():
    ii = pipeline_ii(n_microbatches=8, n_stages=4)
    assert ii["slots"] == 11
    assert abs(ii["bubble_fraction"] - 3 / 11) < 1e-9
    # paper limit: replication/microbatching drives II/output toward 1
    assert pipeline_ii(256, 4)["ii_per_output"] < 1.02


_PIPE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.runtime.pipeline import pipeline_apply, pipeline_reference

mesh = jax.make_mesh((4,), ("stage",))
S, M, mb, d = 4, 8, 2, 16
k = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(k, (S, d, d)) * 0.3,
          "b": jnp.zeros((S, d))}
x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

y = pipeline_apply(mesh, stage_fn, params, x)
ref = pipeline_reference(stage_fn, params, x)
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
print("PIPELINE_OK")
"""


def test_pipeline_matches_reference_on_4_stage_mesh():
    """Runs in a subprocess so the 4-device XLA flag doesn't pollute us."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _PIPE_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=480,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
