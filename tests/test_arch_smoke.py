"""Per-architecture smoke tests on reduced configs (CPU).

For every assigned arch: one forward/train step (shapes + finiteness), a
prefill+decode consistency check against the full forward pass, and
tm(scan)-vs-spatial equivalence — the paper's execution-mode axis.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import (decode_step, forward, init_params, loss_fn,
                          prefill)


def _batch(cfg, B=2, S=32, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.vision_tokens:
        b["vision_embeds"] = jax.random.normal(
            ks[1], (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder is not None:
        b["frame_embeds"] = jax.random.normal(
            ks[2], (B, S, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux, _ = forward(cfg, params, batch["tokens"],
                             extra_embeds=batch.get("vision_embeds"),
                             frame_embeds=batch.get("frame_embeds"))
    S_total = batch["tokens"].shape[1] + cfg.vision_tokens
    assert logits.shape == (2, S_total, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """decode_step(prefill(x[:S]), x[S]) == forward(x[:S+1])[:, S]."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S + 1, seed=7)
    toks = batch["tokens"]
    full_logits, _, _ = forward(
        cfg, params, toks, extra_embeds=batch.get("vision_embeds"),
        frame_embeds=batch.get("frame_embeds"))
    last_full = full_logits[:, -1]                     # position S
    _, caches = prefill(cfg, params, toks[:, :S],
                        cache_len=S + 8 + cfg.vision_tokens,
                        extra_embeds=batch.get("vision_embeds"),
                        frame_embeds=batch.get("frame_embeds"))
    pos = S + cfg.vision_tokens
    dec_logits, _ = decode_step(cfg, params, caches, toks[:, S:S + 1],
                                jnp.asarray(pos))
    a = np.asarray(last_full, np.float32)
    bb = np.asarray(dec_logits, np.float32)
    # bf16 compute + different code path: compare top-1 and correlation
    assert (np.argmax(a, -1) == np.argmax(bb, -1)).mean() >= 0.95, \
        (np.argmax(a, -1), np.argmax(bb, -1))
    cc = np.corrcoef(a.ravel(), bb.ravel())[0, 1]
    assert cc > 0.99, cc


@pytest.mark.parametrize("arch", ["deepseek-7b", "gemma3-4b", "zamba2-7b",
                                  "phi3.5-moe-42b-a6.6b", "mamba2-2.7b"])
def test_tm_equals_spatial(arch):
    """Scan (time-multiplexed) and unrolled (spatial) execution agree."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, seed=3)
    lg_tm, _, _ = forward(cfg, params, batch["tokens"],
                          extra_embeds=batch.get("vision_embeds"),
                          frame_embeds=batch.get("frame_embeds"))
    cfg_sp = dataclasses.replace(cfg, scan_layers=False)
    lg_sp, _, _ = forward(cfg_sp, params, batch["tokens"],
                          extra_embeds=batch.get("vision_embeds"),
                          frame_embeds=batch.get("frame_embeds"))
    a = np.asarray(lg_tm, np.float32)
    b = np.asarray(lg_sp, np.float32)
    # bf16 + different XLA fusion orders: structural equivalence check
    assert np.corrcoef(a.ravel(), b.ravel())[0, 1] > 0.999
    # argmax may legitimately flip where the top two logits are a
    # NEAR-TIE (zamba2/phi3.5-moe flip 2-4 of 64 positions, varying with
    # XLA's CPU reduction order, all with top-2 gaps under 0.07 of the
    # logit std — pure bf16 noise).  A real divergence separates by
    # O(1) std, so: mostly matching argmax, and every mismatch must be a
    # near-tie in BOTH executions.
    am, bm = a.argmax(-1), b.argmax(-1)
    assert (am == bm).mean() >= 0.9, (am, bm)
    tie_tol = 0.1 * float(np.std(a))
    for i, j in np.argwhere(am != bm):
        ia, ib = am[i, j], bm[i, j]
        gap = max(abs(a[i, j, ia] - a[i, j, ib]),
                  abs(b[i, j, ia] - b[i, j, ib]))
        assert gap < tie_tol, (
            f"argmax mismatch at {(i, j)} is not a near-tie: "
            f"top-2 gap {gap:.4f} vs tolerance {tie_tol:.4f}")


def test_window_attention_matches_full_when_window_covers():
    """A window >= S must equal full attention."""
    from repro.models.layers import AttnDims, attention_apply, init_attention
    key = jax.random.PRNGKey(0)
    dims = AttnDims(4, 2, 16)
    p = init_attention(key, 64, dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(24)[None], (2, 24))
    full = attention_apply(p, x, dims=dims, positions=pos, causal=True)
    win = attention_apply(p, x, dims=dims, positions=pos, causal=True,
                          window=64)
    np.testing.assert_allclose(np.asarray(full), np.asarray(win),
                               rtol=1e-5, atol=1e-5)


def test_flash_matches_sdpa():
    from repro.models.layers import AttnDims, attention_apply, init_attention
    dims = AttnDims(4, 4, 16)
    p = init_attention(jax.random.PRNGKey(0), 64, dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 96, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(96)[None], (1, 96))
    direct = attention_apply(p, x, dims=dims, positions=pos, causal=True,
                             flash_threshold=4096)
    flash = attention_apply(p, x, dims=dims, positions=pos, causal=True,
                            flash_threshold=8)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(flash),
                               rtol=2e-2, atol=2e-2)


def test_flash_windowed_matches_masked_full():
    from repro.models.layers import (AttnDims, _flash_windowed, _sdpa,
                                     init_attention)
    B, S, KH, G, hd, W = 1, 64, 2, 2, 8, 16
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(k1, (B, S, KH, G, hd))
    k = jax.random.normal(k2, (B, S, KH, hd))
    v = jax.random.normal(k3, (B, S, KH, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ref = _sdpa(q, k, v, pos, pos, True, W)
    got = _flash_windowed(q, k, v, pos, pos, True, W, q_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunked_matches_naive_recurrence():
    """Chunked SSD == step-by-step recurrence."""
    from repro.models.ssm import SSMDims, init_mamba2, mamba2_apply, \
        mamba2_decode
    dims = SSMDims(d_model=32, d_state=8, d_conv=4, expand=2, head_dim=8)
    p = init_mamba2(jax.random.PRNGKey(0), dims)
    B, L = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, 32)) * 0.5
    y_par = mamba2_apply(p, x, dims=dims, chunk=4)
    conv = jnp.zeros((B, dims.d_conv - 1, dims.d_inner
                      + 2 * dims.n_groups * dims.d_state))
    ssm = jnp.zeros((B, dims.n_heads, dims.d_state, dims.head_dim))
    ys = []
    for t in range(L):
        y, conv, ssm = mamba2_decode(p, x[:, t:t + 1], conv, ssm, dims=dims)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=2e-3, atol=2e-3)
