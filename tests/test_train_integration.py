"""Integration: the training launcher end-to-end, incl. resume determinism
and simulated-failure recovery."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.distributed import checkpoint as C
from repro.models import init_params
from repro.runtime import optim as O
from repro.runtime.steps import make_train_step


def _run_steps(cfg, params, opt, step_fn, corpus, start, n):
    losses = []
    for s in range(start, start + n):
        params, opt, m = step_fn(params, opt, corpus.batch(s))
        losses.append(float(m["loss"]))
    return params, opt, losses


def test_resume_is_bitwise_deterministic(tmp_path):
    """train 6 steps straight == train 3, checkpoint, restore, train 3."""
    cfg = get_smoke_config("deepseek-7b")
    oc = O.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    corpus = SyntheticCorpus(DataConfig(global_batch=2, seq_len=32,
                                        vocab=cfg.vocab))
    step_fn = jax.jit(make_train_step(cfg, oc))

    p0 = init_params(cfg, jax.random.PRNGKey(0))
    o0 = O.init_opt(p0)
    pA, oA, lossA = _run_steps(cfg, p0, o0, step_fn, corpus, 0, 6)

    p1 = init_params(cfg, jax.random.PRNGKey(0))
    o1 = O.init_opt(p1)
    p1, o1, _ = _run_steps(cfg, p1, o1, step_fn, corpus, 0, 3)
    C.save(str(tmp_path), 3, (p1, o1), extra=corpus.cursor(3))
    (p2, o2), step, extra = C.restore(
        str(tmp_path), jax.eval_shape(lambda: (p1, o1)))
    assert step == 3 and extra["step"] == 3
    pB, oB, lossB = _run_steps(cfg, p2, o2, step_fn, corpus, 3, 3)

    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert abs(lossA[-1] - lossB[-1]) < 1e-6


def test_train_launcher_with_failure_recovery(tmp_path):
    """CLI launcher: checkpoint, simulated device loss, re-mesh, resume."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "deepseek-7b", "--smoke", "--steps", "8", "--batch", "2",
         "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
         "--simulate-failure-at", "6"],
        capture_output=True, text=True, timeout=560,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[elastic] simulating failure" in r.stdout
    assert "done:" in r.stdout


def test_grad_compression_training_still_learns():
    """Compressed-grad training converges under a loss-MEDIAN oracle.

    The old check (``mean(losses[-5:]) < losses[0]``) compared a window
    against one arbitrary sample of a noisy series — at smoke scale the
    per-step loss on random tokens swings ~+-0.2, so the test was flaky
    by construction and sat xfail'd.  The sturdier oracle (the
    windowed-median logging idiom from the HomebrewNLP ``wandblog.py``
    exemplar cited in ROADMAP.md) compares the MEDIAN of the first
    window against the median of the last: medians shrug off the
    per-step noise, and the re-tuned run (lr 3e-3, 60 steps — the
    original 20 steps at 1e-3 were simply not enough optimizer work for
    the int8+error-feedback path to show progress) descends ~0.2 nats
    toward the synthetic corpus's ~ln(vocab) entropy floor, several
    times the residual median jitter.
    """
    cfg = get_smoke_config("deepseek-7b")
    oc = O.OptConfig(lr=3e-3, warmup_steps=1, total_steps=70)
    corpus = SyntheticCorpus(DataConfig(global_batch=2, seq_len=32,
                                        vocab=cfg.vocab))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = O.init_opt(params)
    step_fn = jax.jit(make_train_step(cfg, oc, compress_grads=True))
    _, _, losses = _run_steps(cfg, params, opt, step_fn, corpus, 0, 60)
    assert np.isfinite(losses).all()
    first, last = np.median(losses[:10]), np.median(losses[-10:])
    assert last < first - 0.05, (
        f"loss median did not converge: first10={first:.4f} "
        f"last10={last:.4f}")
