"""Async streaming engine tests: ordering, parity, fairness, pinning.

The PR acceptance bar for the streaming OverlayServer:

* streamed results (``as_completed`` / ``result`` / pipelined ``flush``)
  are BIT-FOR-BIT identical to the synchronous ``Overlay.dispatch`` path;
* a hot tenant cannot starve a cold one — deficit-round-robin bounds the
  cold tenant's wait to O(1) rounds regardless of backlog;
* per-tenant token-bucket admission control rejects over-rate submits
  deterministically (injectable clock);
* contexts pinned by in-flight rounds survive LRU pressure, and the
  engine never leaks pins.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bank import BankError, ContextBank
from repro.core.overlay import Overlay, compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.launch.serve import (AdmissionError, OverlayServer, TokenBucket)

ALL_NAMES = BENCH_NAMES + ("gradient",)


@pytest.fixture(scope="module")
def kernels():
    return {n: compile_program(benchmark(n)) for n in ALL_NAMES}


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _xs(kernel, batch, seed):
    rng = np.random.RandomState(seed)
    return [rng.uniform(-2, 2, (batch,)).astype(np.float32)
            for _ in kernel.dfg.inputs]


def _dispatch_oracle(kernels_xs, bank_capacity=16):
    """The synchronous one-shot path: a fresh bank + Overlay.dispatch."""
    ov = Overlay()
    bank = ContextBank(bank_capacity)
    return ov.dispatch(bank, kernels_xs)


# ------------------------------------------------------------------- parity
def test_streamed_results_match_dispatch_bitexact(kernels):
    """as_completed delivery == synchronous Overlay.dispatch, bit for bit."""
    srv = OverlayServer(bank_capacity=4, round_kernels=2, max_inflight=2)
    names = ("chebyshev", "poly5", "poly6", "gradient", "mibench") * 2
    reqs = {}
    for i, n in enumerate(names):
        k = kernels[n]
        xs = _xs(k, batch=64 + 32 * (i % 3), seed=i)
        reqs[srv.submit(k, xs, tenant=f"t{i % 3}")] = (k, xs)
    got = dict(srv.as_completed())
    assert set(got) == set(reqs)
    for t, (k, xs) in reqs.items():
        want = _dispatch_oracle([(k, xs)])[0]
        assert len(got[t]) == len(k.dfg.outputs)
        for y, w in zip(got[t], want):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))
    assert srv.pending == 0 and srv.bank.n_pinned == 0


def test_flush_and_flush_sync_agree_bitexact(kernels):
    """Pipelined drain and barrier drain serve identical bits."""
    def build():
        srv = OverlayServer(bank_capacity=3, round_kernels=2,
                            max_inflight=3, quantum_tiles=2)
        tickets = {}
        for i in range(14):
            k = kernels[ALL_NAMES[i % 7]]
            xs = _xs(k, batch=48 + 16 * (i % 4), seed=100 + i)
            tickets[srv.submit(k, xs, tenant=f"t{i % 4}")] = (k, xs)
        return srv, tickets

    srv_a, tickets_a = build()
    srv_b, tickets_b = build()
    out_pipe = srv_a.flush()
    out_sync = srv_b.flush_sync()
    assert set(out_pipe) == set(out_sync) == set(tickets_a)
    for t in tickets_a:
        for y, w in zip(out_pipe[t], out_sync[t]):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))


def test_staged_pipeline_composes_to_dispatch(kernels):
    """plan -> assemble -> execute -> collect == dispatch, both collect
    modes (lazy device slices and host numpy views)."""
    ov = Overlay()
    bank = ContextBank(4)
    pairs = [(kernels["chebyshev"], _xs(kernels["chebyshev"], 200, 1)),
             (kernels["poly6"], _xs(kernels["poly6"], 33, 2)),
             (kernels["chebyshev"], _xs(kernels["chebyshev"], 64, 3))]
    want = ov.dispatch(ContextBank(4), pairs)
    plan = ov.plan(bank, pairs)
    ys = ov.execute(bank, ov.assemble(plan))
    for host in (False, True):
        got = ov.collect(plan, ys, host=host)
        for g, w in zip(got, want):
            for y, ref in zip(g, w):
                np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))


# ----------------------------------------------------------------- ordering
def test_as_completed_yields_rounds_in_completion_order(kernels):
    """Results stream out round by round (arrival order), within a round
    in submission order — not held back to a full-queue barrier."""
    srv = OverlayServer(bank_capacity=4, round_kernels=1, max_inflight=2)
    order = []
    tickets = []
    for i, n in enumerate(("chebyshev", "poly5", "poly6", "gradient")):
        k = kernels[n]
        for j in range(2):
            tickets.append(srv.submit(k, _xs(k, 32, i * 10 + j)))
    for t, _ in srv.as_completed():
        order.append(t)
    # round_kernels=1 => one kernel per round, rounds launch in DRR order,
    # delivery preserves it: tickets grouped pairwise in submission order
    assert order == tickets
    rounds = [srv.record(t)["round"] for t in order]
    assert rounds == sorted(rounds)
    assert len(set(rounds)) == 4


def test_result_blocks_and_claims_once(kernels):
    srv = OverlayServer(bank_capacity=2)
    k = kernels["poly5"]
    xs = _xs(k, 96, 7)
    t = srv.submit(k, xs)
    want = _dispatch_oracle([(k, xs)])[0]
    got = srv.result(t)
    for y, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(y), np.asarray(w))
    with pytest.raises(KeyError):
        srv.result(t)               # a ticket can be claimed once
    with pytest.raises(KeyError):
        srv.result(10_000)          # unknown ticket


def test_submit_during_streaming_is_served(kernels):
    """as_completed picks up requests submitted while iterating."""
    srv = OverlayServer(bank_capacity=2)
    k1, k2 = kernels["chebyshev"], kernels["poly6"]
    t1 = srv.submit(k1, _xs(k1, 32, 0))
    seen = []
    it = srv.as_completed()
    seen.append(next(it)[0])
    t2 = srv.submit(k2, _xs(k2, 32, 1))
    seen.extend(t for t, _ in it)
    assert seen == [t1, t2]


# ----------------------------------------------------------------- fairness
def test_hot_tenant_cannot_starve_cold_tenant(kernels):
    """Bounded wait: a cold tenant's lone request lands within the first
    two rounds even when a hot tenant queued a large multi-kernel backlog
    first (DRR round-robin, one kernel group per round)."""
    srv = OverlayServer(bank_capacity=2, round_kernels=1)
    hot_tickets = []
    for i in range(12):                     # 6 kernels x 2 requests
        k = kernels[ALL_NAMES[i % 6]]
        hot_tickets.append(srv.submit(k, _xs(k, 64, i), tenant="hot"))
    cold_k = kernels[ALL_NAMES[7]]
    cold_ticket = srv.submit(cold_k, _xs(cold_k, 64, 99), tenant="cold")
    srv.flush()
    cold_round = srv.record(cold_ticket)["round"]
    hot_rounds = [srv.record(t)["round"] for t in hot_tickets]
    assert cold_round <= 1, (cold_round, hot_rounds)
    assert max(hot_rounds) >= 5             # backlog really spanned rounds
    # FIFO group order would have served cold LAST
    assert cold_round < max(hot_rounds)


def test_quantum_bounds_hot_tenant_per_round(kernels):
    """With a finite DRR quantum, a hot tenant's backlog on ONE kernel is
    spread across rounds instead of monopolising each round.  Pinned to
    the DRR policy: this is a DRR-semantics test (coalescing/dynamic
    policies deliberately pace differently; see test_sched_policies)."""
    k = kernels["chebyshev"]
    srv = OverlayServer(bank_capacity=4, quantum_tiles=2,
                        round_policy="drr")
    hot = [srv.submit(k, _xs(k, 128, i), tenant="hot") for i in range(8)]
    srv.flush()
    rounds = sorted(srv.record(t)["round"] for t in hot)
    # cost 1 tile each, quantum 2 => at most 2 hot requests per round
    assert max(rounds) >= 3
    for r in set(rounds):
        assert rounds.count(r) <= 2


# ---------------------------------------------------------------- admission
def test_token_bucket_admission_rejects_and_recovers(kernels):
    clock = FakeClock()
    srv = OverlayServer(bank_capacity=2, clock=clock,
                        admission={"metered": (1.0, 2.0)})
    k = kernels["poly5"]
    xs = _xs(k, 128, 0)                     # cost: 1 tile
    srv.submit(k, xs, tenant="metered")
    srv.submit(k, xs, tenant="metered")     # burst of 2 exhausted
    with pytest.raises(AdmissionError) as ei:
        srv.submit(k, xs, tenant="metered")
    assert ei.value.tenant == "metered" and ei.value.retry_after > 0
    # unmetered tenants are unaffected
    srv.submit(k, xs, tenant="free")
    clock.advance(1.0)                      # one token accrues
    srv.submit(k, xs, tenant="metered")
    assert srv.pending == 4
    srv.flush()
    assert srv.pending == 0


def test_default_admission_applies_to_new_tenants(kernels):
    clock = FakeClock()
    srv = OverlayServer(bank_capacity=2, clock=clock,
                        default_admission=(1.0, 1.0))
    k = kernels["poly5"]
    xs = _xs(k, 64, 0)
    srv.submit(k, xs, tenant="anyone")
    with pytest.raises(AdmissionError):
        srv.submit(k, xs, tenant="anyone")
    srv.submit(k, xs, tenant="someone-else")    # separate bucket
    srv.flush()


def test_token_bucket_unit():
    clock = FakeClock()
    tb = TokenBucket(rate=2.0, burst=4.0, clock=clock)
    assert tb.try_acquire(4.0) and not tb.try_acquire(1.0)
    assert tb.retry_after(1.0) == pytest.approx(0.5)
    clock.advance(0.5)
    assert tb.try_acquire(1.0)
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0)


# ------------------------------------------------------------------ pinning
def test_pinned_context_survives_lru_pressure(kernels):
    bank = ContextBank(capacity=2)
    k_pin = kernels["chebyshev"]
    bank.pin(k_pin)
    # churn 3 other kernels through the remaining slot
    for n in ("poly5", "poly6", "gradient"):
        bank.load(kernels[n])
        assert k_pin in bank                # never evicted
    assert bank.n_evictions == 2
    assert bank.evictable_capacity() == 1
    bank.unpin(k_pin)
    assert bank.evictable_capacity() == 2
    # now the (LRU) former pin is evictable again
    bank.load(kernels["mibench"])
    assert k_pin not in bank


def test_all_pinned_bank_raises_instead_of_corrupting(kernels):
    bank = ContextBank(capacity=2)
    bank.pin(kernels["chebyshev"])
    bank.pin(kernels["poly5"])
    with pytest.raises(BankError):
        bank.load(kernels["poly6"])
    # refcounted: double pin needs double unpin
    bank.pin(kernels["chebyshev"])
    bank.unpin(kernels["chebyshev"])
    with pytest.raises(BankError):
        bank.load(kernels["poly6"])
    bank.unpin(kernels["chebyshev"])
    bank.unpin(kernels["poly5"])
    bank.load(kernels["poly6"])             # evictable again
    with pytest.raises(BankError):
        bank.unpin(kernels["poly6"])        # unpin without pin


def test_engine_pins_during_flight_and_releases(kernels):
    """The server pins each round's contexts while in flight and leaves a
    clean bank afterwards, even under eviction pressure."""
    srv = OverlayServer(bank_capacity=2, round_kernels=1, max_inflight=2)
    for i in range(8):
        k = kernels[ALL_NAMES[i % 4]]
        srv.submit(k, _xs(k, 64, i))
    results = srv.flush()
    assert len(results) == 8
    assert srv.bank.n_pinned == 0
    assert srv.bank.n_evictions >= 2
    # served correctly despite churn
    for t, outs in results.items():
        assert all(np.isfinite(np.asarray(y)).all() for y in outs)


def test_round_mixing_resident_and_new_kernels_under_pressure(kernels):
    """Regression: a round containing a resident-but-unpinned kernel plus a
    new kernel, while another round is in flight, must retire/retry — not
    crash with BankError or leak pins."""
    srv = OverlayServer(bank_capacity=3, round_kernels=2, max_inflight=2)
    a, b, c, d = (kernels[n] for n in ("chebyshev", "poly5", "poly6",
                                       "gradient"))
    srv.submit(a, _xs(a, 64, 0))
    srv.flush()                             # A resident, unpinned
    for k, s in ((c, 1), (d, 2), (a, 3), (b, 4)):
        srv.submit(k, _xs(k, 64, s))
    got = dict(srv.as_completed())          # round {C,D} then round {A,B}
    assert len(got) == 4
    assert srv.bank.n_pinned == 0


def test_plan_bankerror_unwinds_pins(kernels):
    """A failed pinned plan must not leak pin refcounts."""
    ov = Overlay()
    bank = ContextBank(capacity=2)
    bank.pin(kernels["chebyshev"])
    bank.pin(kernels["poly5"])
    pairs = [(kernels["poly6"], _xs(kernels["poly6"], 32, 0)),
             (kernels["gradient"], _xs(kernels["gradient"], 32, 1))]
    with pytest.raises(BankError):
        ov.plan(bank, pairs, pin=True)
    assert bank.n_pinned == 2               # only the pre-existing pins


def test_flush_sync_delivers_inflight_rounds(kernels):
    """flush_sync after pipelined use must deliver rounds already in
    flight (no dropped tickets, no leaked pins)."""
    srv = OverlayServer(bank_capacity=4, round_kernels=1, max_inflight=2)
    tickets = []
    for i, n in enumerate(("chebyshev", "poly5", "poly6")):
        k = kernels[n]
        tickets.append(srv.submit(k, _xs(k, 32, i)))
    srv.result(tickets[0])                  # leaves a round in flight
    out = srv.flush_sync()
    assert set(out) == set(tickets[1:])
    assert srv.pending == 0 and srv.bank.n_pinned == 0


def test_quantum_must_be_positive(kernels):
    with pytest.raises(ValueError):
        OverlayServer(bank_capacity=2, quantum_tiles=0)
    with pytest.raises(ValueError):
        OverlayServer(bank_capacity=2, quantum_tiles=-1)
    with pytest.raises(ValueError):
        OverlayServer(bank_capacity=2, round_kernels=0)


def test_same_tenant_old_request_not_starved_by_hot_kernel(kernels):
    """Regression: within one tenant, an old request for a cold kernel
    must not be starved by a continuous stream of hot-kernel traffic —
    untaken requests keep their arrival order in the queue."""
    srv = OverlayServer(bank_capacity=4, round_kernels=1, max_inflight=1)
    a, b = kernels["chebyshev"], kernels["poly5"]
    srv.submit(a, _xs(a, 32, 0))
    t_b = srv.submit(b, _xs(b, 32, 1))
    served = []
    it = srv.as_completed()
    for i in range(8):
        t, _ = next(it)
        served.append(t)
        if t == t_b:
            break
        srv.submit(a, _xs(a, 32, 10 + i))   # sustained hot-kernel load
    assert t_b in served and served.index(t_b) <= 2, served


def test_reset_metrics_keeps_unclaimed_results(kernels):
    """Regression: reset_metrics must not orphan delivered-but-unclaimed
    results — their tickets stay claimable with telemetry intact."""
    srv = OverlayServer(bank_capacity=2, round_kernels=1, max_inflight=2)
    k1, k2 = kernels["chebyshev"], kernels["poly5"]
    xs1 = _xs(k1, 32, 0)
    t1 = srv.submit(k1, xs1)
    t2 = srv.submit(k2, _xs(k2, 32, 1))
    srv.result(t2)                 # delivers t1's round too, unclaimed
    srv.reset_metrics()
    out1 = srv.result(t1)          # must not raise KeyError
    want = _dispatch_oracle([(k1, xs1)])[0]
    for y, w in zip(out1, want):
        np.testing.assert_array_equal(np.asarray(y), np.asarray(w))


def test_metrics_window_bounds_record_history(kernels):
    """Telemetry for claimed tickets is pruned beyond metrics_window."""
    srv = OverlayServer(bank_capacity=2, metrics_window=4)
    k = kernels["chebyshev"]
    for i in range(10):
        srv.submit(k, _xs(k, 32, i))
    srv.flush()
    assert len(srv.latencies()) <= 4
    assert len(srv._records) <= 4


def test_drained_tenant_flows_are_pruned(kernels):
    """Per-tenant flow state must not accumulate over the server's life
    (unbounded tenant-label spaces)."""
    srv = OverlayServer(bank_capacity=2)
    k = kernels["chebyshev"]
    for i in range(20):
        srv.submit(k, _xs(k, 32, i), tenant=f"one-shot-{i}")
    srv.flush()
    assert len(srv._flows) == 0 and len(srv._rr) == 0
    # pruning must not break a tenant that comes back
    t = srv.submit(k, _xs(k, 32, 99), tenant="one-shot-3")
    assert len(srv.flush()) == 1 and srv.record(t)["tenant"] == "one-shot-3"


def test_admission_cost_above_burst_is_unsatisfiable(kernels):
    """A request larger than the bucket burst reports retry_after=inf —
    callers must not retry-livelock on it."""
    import math
    clock = FakeClock()
    srv = OverlayServer(bank_capacity=2, tile=128, clock=clock,
                        admission={"t": (1.0, 4.0)})
    k = kernels["poly5"]
    with pytest.raises(AdmissionError) as ei:
        srv.submit(k, _xs(k, 8 * 128, 0), tenant="t")   # cost 8 > burst 4
    assert math.isinf(ei.value.retry_after)


def test_bank_prefetch_warms_working_set(kernels):
    bank = ContextBank(capacity=4)
    slots = bank.prefetch([kernels[n] for n in ("chebyshev", "poly5",
                                                "poly6")])
    assert sorted(slots) == [0, 1, 2]
    assert all(kernels[n] in bank for n in ("chebyshev", "poly5", "poly6"))
    # prefetching again is pure LRU touches
    assert bank.prefetch([kernels["poly5"]]) == [1]
    assert bank.n_hits >= 1


def test_empty_and_zero_length_requests(kernels):
    srv = OverlayServer(bank_capacity=2)
    assert srv.flush() == {}
    k = kernels["chebyshev"]
    t0 = srv.submit(k, [np.zeros(0, np.float32)])
    t1 = srv.submit(k, _xs(k, 64, 0))
    out = srv.flush()
    assert np.shape(out[t0][0]) == (0,) and np.shape(out[t1][0]) == (64,)
