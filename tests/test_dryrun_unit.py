"""Unit tests for dry-run machinery that don't need 512 devices."""

import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cells, skip_reason


def test_cells_cover_40():
    cs = cells()
    assert len(cs) == 40
    skips = [c for c in cs if c[2]]
    # exactly the full-attention archs skip long_500k
    assert {(a, s) for a, s, r in skips} == {
        (a, "long_500k") for a in ARCHS
        if a not in ("zamba2-7b", "gemma3-4b", "mamba2-2.7b")}


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %ar = f32[16,1024]{1,0} all-reduce(f32[16,1024] %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[32,512]{1,0} all-gather(bf16[2,512] %y), replica_groups=[8,16]<=[128], dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8] %z), source_target_pairs={{0,1}}
"""
    out = parse_collectives(hlo)
    kinds = {c["kind"]: c for c in out}
    assert kinds["all-reduce"]["bytes"] == 16 * 1024 * 4
    assert kinds["all-reduce"]["group"] == 4
    # ring all-reduce wire = 2 * size * (g-1)/g
    assert kinds["all-reduce"]["wire_bytes"] == 2 * 16 * 1024 * 4 * 3 / 4
    assert kinds["all-gather"]["group"] == 16
    assert kinds["collective-permute"]["wire_bytes"] == 8 * 8 * 2


def test_input_specs_all_cells():
    from repro.launch.dryrun import input_specs
    for arch in ARCHS:
        for shape in SHAPES:
            if skip_reason(arch, shape):
                continue
            cfg, batch, (seq, gb, kind) = input_specs(arch, shape)
            assert "tokens" in batch
            if kind != "decode":
                total = batch["tokens"].shape[1] + (
                    batch["vision_embeds"].shape[1]
                    if "vision_embeds" in batch else 0)
                assert total == seq, (arch, shape)
            else:
                assert batch["tokens"].shape == (gb, 1)


def test_analytic_model_sane():
    import sys
    sys.path.insert(0, ".")
    from benchmarks.analytic import cell_model
    m = cell_model("deepseek-7b", "train_4k")
    # 6ND vs 4x-forward analytic: ratio must be within 2x
    assert 0.5 < m.model_flops_dev / m.flops_dev < 1.2
    assert m.bottleneck in ("compute", "memory", "collective")
    opt = cell_model("deepseek-7b", "train_4k", layout="fsdp", mixed=True)
    assert opt.step_time < m.step_time          # the hillclimb must help
    assert opt.mfu_at_roofline > m.mfu_at_roofline


def test_analytic_vs_spatial_dryrun_crosscheck():
    """If the spatial artifact exists, analytic flops within 40%."""
    import json
    import os
    f = "artifacts/dryrun_spatial/deepseek-7b_train_4k_single.json"
    if not os.path.exists(f):
        pytest.skip("spatial artifact not generated in this environment")
    import sys
    sys.path.insert(0, ".")
    from benchmarks.analytic import cell_model
    rec = json.load(open(f))
    hlo_flops = rec["roofline"]["hlo_flops_per_device"]
    m = cell_model("deepseek-7b", "train_4k")
    assert 0.6 < m.flops_dev / hlo_flops < 1.7, \
        (m.flops_dev, hlo_flops)
