"""Conservation invariants over the structured telemetry sink.

The telemetry refactor's whole point is that the serving stack's story
is auditable from ONE store: every submit, delivery, orphan, shed, and
scaling action lands in the fleet's `Telemetry` sink, so flow
conservation can be asserted from the OUTSIDE at any barrier — without
reaching into engine internals — and a counter that drifts from the
requests it claims to describe fails loudly here.

Two seeded chaos drivers, adapted from the existing soak harnesses
(tests/test_autoscale.py, tests/test_gateway.py):

* FLEET CHAOS — random interleavings of submits/bursts, every drain
  flavour, and forced grow/drain with a live autoscaler.  After EVERY
  action:
      submits == delivered + pending          (no request lost or dup'd)
      scale_ups - scale_downs == replicas - initial
      orphans_created == orphan_claims + orphans_held
      claims <= submits; at the final barrier claims == submits
* GATEWAY CHURN — connect/submit/drop/reclaim churn over an autoscaled
  fleet behind the asyncio edge.  At every barrier:
      edge attempts == submitted + shed + park_cancelled + parked
      edge submitted == fleet submits        (all traffic rides the edge)
  and at the final barrier the fleet conservation above, with zero
  orphans outstanding.
"""

import asyncio

import numpy as np
import pytest

from repro.core.overlay import compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.launch.gateway import OverlayGateway
from repro.launch.serve import ShardedOverlayServer
from repro.sched import PressureAutoscaler

ALL_NAMES = BENCH_NAMES + ("gradient",)


@pytest.fixture(scope="module")
def kernels():
    return {n: compile_program(benchmark(n)) for n in ALL_NAMES}


def _xs(kernel, batch, seed):
    rng = np.random.RandomState(seed)
    return [rng.uniform(-2, 2, (batch,)).astype(np.float32)
            for _ in kernel.dfg.inputs]


def _assert_fleet_conserved(srv, initial_replicas):
    """The sink-level conservation laws that must hold at EVERY barrier
    (single-threaded drivers: between actions nothing is in between
    states)."""
    c = srv.telemetry.counter
    submits = c("fleet.submits")
    delivered = c("engine.delivered")
    assert submits == delivered + srv.pending, (
        f"flow conservation broke: {submits} submits != "
        f"{delivered} delivered + {srv.pending} pending")
    assert (c("fleet.scale_ups") - c("fleet.scale_downs")
            == srv.n_replicas - initial_replicas), (
        "scaling ledger broke: ups - downs != replicas - initial")
    assert (c("fleet.orphaned_results")
            == c("fleet.orphan_claims") + len(srv._orphaned)), (
        "orphan conservation broke: created != claimed + held")
    assert c("fleet.claims") <= submits, "claimed more than was submitted"
    # the engine-side ledger rides the same shared sink: every fleet
    # submit became exactly one replica-engine submit (steals/evacuation
    # adopt requests without re-counting them)
    assert c("engine.submits") == submits


# ============================================================ fleet chaos
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_fleet_chaos_conservation(kernels, seed):
    rng = np.random.RandomState(0x7E1E + seed)
    names = list(kernels)
    auto = PressureAutoscaler(
        up_tiles=float(rng.choice([4.0, 16.0])),
        up_rounds=int(rng.choice([1, 2])),
        down_rounds=int(rng.choice([2, 4])),
        min_replicas=1, max_replicas=5)
    srv = ShardedOverlayServer(
        n_replicas=int(rng.choice([1, 2, 3])), bank_capacity=4,
        round_kernels=2, max_inflight=int(rng.choice([1, 2])),
        steal=bool(rng.rand() < 0.5), autoscaler=auto)
    initial = srv.n_replicas
    pending: set[int] = set()
    delivered: set[int] = set()

    def claim(results):
        for t in results:
            assert t not in delivered, "ticket delivered twice"
            delivered.add(t)
            pending.discard(t)

    for _step in range(30):
        action = rng.choice(
            ["submit", "burst", "drain", "result", "grow", "shrink"],
            p=[0.35, 0.15, 0.2, 0.1, 0.1, 0.1])
        if action in ("submit", "burst"):
            for _ in range(1 if action == "submit"
                           else int(rng.randint(4, 9))):
                k = kernels[names[rng.randint(len(names))]]
                xs = _xs(k, int(rng.choice([33, 64, 96])),
                         int(rng.randint(1 << 30)))
                pending.add(srv.submit(k, xs, tenant=f"t{rng.randint(3)}"))
        elif action == "drain" and pending:
            mode = rng.choice(["flush", "flush_sync", "as_completed"])
            if mode == "flush":
                claim(srv.flush())
            elif mode == "flush_sync":
                claim(srv.flush_sync())
            else:
                claim(dict(srv.as_completed()))
            assert not pending, "a drain left tickets undelivered"
        elif action == "result" and pending:
            t = list(pending)[rng.randint(len(pending))]
            claim({t: srv.result(t)})
        elif action == "grow" and srv.n_replicas < 6:
            srv.add_replica()
        elif action == "shrink" and srv.n_replicas > 1:
            srv.drain_replica(int(rng.randint(srv.n_replicas)))
        _assert_fleet_conserved(srv, initial)

    # forced mutation pair + final barrier: everything delivered AND the
    # ledgers close exactly
    srv.add_replica()
    srv.drain_replica(0)
    _assert_fleet_conserved(srv, initial)
    claim(srv.flush())
    _assert_fleet_conserved(srv, initial)
    assert not pending and srv.pending == 0
    c = srv.telemetry.counter
    assert c("fleet.claims") == c("fleet.submits"), (
        "final barrier: every submitted ticket must be claimed exactly once")
    assert len(srv._orphaned) == 0
    # the stats() surface reads the same sink (read-through, no fork)
    st = srv.stats()
    assert st["submits"] == int(c("fleet.submits"))
    assert st["requests"] == int(c("engine.delivered"))
    assert st["scale_ups"] == int(c("fleet.scale_ups"))
    assert st["claims"] == int(c("fleet.claims"))


# ========================================================== gateway churn
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_gateway_churn_conservation(kernels, seed):
    async def scenario():
        rng = np.random.RandomState(0xED6E + seed)
        names = list(kernels)
        auto = PressureAutoscaler(up_tiles=4.0, up_rounds=1, down_rounds=3,
                                  min_replicas=1, max_replicas=3)
        srv = ShardedOverlayServer(n_replicas=1, bank_capacity=4,
                                   round_kernels=2, autoscaler=auto)
        gw = OverlayGateway(srv, max_fleet_tiles=48, overflow="wait")
        c = gw.telemetry.counter         # one sink: edge + fleet + engine

        def edge_conserved():
            parked = sum(1 for w in gw._edge_waiters if not w.future.done())
            assert (c("edge.attempts")
                    == c("edge.submitted") + c("edge.shed")
                    + c("edge.park_cancelled") + c("edge.submit_errors")
                    + parked), "edge ledger broke"
            assert c("edge.submitted") == c("fleet.submits"), (
                "every edge submit must become exactly one fleet submit")

        async with gw:
            outstanding: dict[str, list] = {}
            dropped_sessions: list[str] = []
            for phase in range(5):
                conns = [gw.connect(tenant=f"t{i}",
                                    session=f"s{seed}-{phase}-{i}")
                         for i in range(3)]
                tickets: dict[int, list] = {}
                for i, conn in enumerate(conns):
                    for j in range(int(rng.randint(2, 5))):
                        k = kernels[names[rng.randint(len(names))]]
                        xs = _xs(k, int(rng.choice([33, 64])),
                                 seed * 7919 + phase * 101 + i * 13 + j)
                        t = await conn.submit(k, xs)
                        tickets.setdefault(i, []).append(t)
                edge_conserved()
                if phase == 2:
                    # check-and-drain atomically: the autoscaler retires
                    # replicas from pump ticks under this same lock, so a
                    # count read outside it can go stale before the drain
                    with gw.pump._lock:
                        if srv.n_replicas > 1:
                            srv.drain_replica(0)
                for i, conn in enumerate(conns):
                    if rng.rand() < 0.3:
                        # drop with work in flight: tickets park under
                        # the session, a later phase reclaims them
                        dropped_sessions.append(conn.session)
                        outstanding[conn.session] = tickets.get(i, [])
                        await conn.close()
                    else:
                        for t in tickets.get(i, []):
                            await conn.result(t)
                        await conn.close()
                edge_conserved()
                # reclaim one parked session per phase, if any
                if dropped_sessions and rng.rand() < 0.8:
                    sess = dropped_sessions.pop(0)
                    async with gw.connect(tenant="reclaimer",
                                          session=sess) as rc:
                        got = await rc.reclaim()
                        want = outstanding.pop(sess)
                        assert set(got) == set(want)
                edge_conserved()
            # final barrier: bulk-drain the fleet, then reclaim the rest
            await gw.flush_sync()
            for sess in dropped_sessions:
                async with gw.connect(tenant="reclaimer",
                                      session=sess) as rc:
                    got = await rc.reclaim()
                    assert set(got) == set(outstanding.pop(sess))
            edge_conserved()
            assert not outstanding
            assert srv.pending == 0
            assert c("fleet.submits") == c("engine.delivered")
            assert gw.stats()["orphan_sessions"] == 0
        # closing the gateway must not invent or lose edge traffic
        edge_conserved()

    asyncio.run(scenario())
