"""Asyncio gateway: lifecycle edge cases, edge backpressure, autoscaler
coupling, and the connect/disconnect/autoscale churn soak.

Four layers, mirroring the other differential suites:

* PLUMBING — submit/await/streaming bit parity vs the ``flush_sync``
  barrier oracle; ``flush_sync`` THROUGH the gateway equals the plain
  engine's barrier drain bit for bit.
* LIFECYCLE — double close is idempotent, submit-after-close raises
  cleanly, a dropped connection's tickets park under its session and a
  reconnect reclaims them EXACTLY once (including results the gateway
  had already claimed from the engine), anonymous connections leak
  nothing.
* EDGE BACKPRESSURE — the depth bound sheds (``overflow="shed"``) or
  parks (``overflow="wait"``) deterministically; the admission window
  widens while a (fake) autoscaler reports a scale-up pending and
  REVERTS the tick after the scale-up completes.
* SOAK — 4 seeds of connection churn over an autoscaled fleet with
  forced grow/drain mutations: every ticket ever admitted is delivered
  (await, reclaim, or bulk drain), bit-identical to the single-bank
  oracle.

Tests drive their own ``asyncio.run`` so the suite needs no async pytest
plugin.
"""

import asyncio

import numpy as np
import pytest

from repro.core.overlay import compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.launch.gateway import (GatewayClosedError, GatewayError,
                                  GatewayOverloadedError, OverlayGateway)
from repro.launch.serve import OverlayServer, ShardedOverlayServer
from repro.sched import AdmissionError, PressureAutoscaler

ALL_NAMES = BENCH_NAMES + ("gradient",)


@pytest.fixture(scope="module")
def kernels():
    return {n: compile_program(benchmark(n)) for n in ALL_NAMES}


def _xs(kernel, batch, seed):
    rng = np.random.RandomState(seed)
    return [rng.uniform(-2, 2, (batch,)).astype(np.float32)
            for _ in kernel.dfg.inputs]


def _mixed(kernels, n, seed=0, batch_pool=(48, 64, 96)):
    rng = np.random.RandomState(seed)
    names = list(kernels)
    return [(kernels[names[i % len(names)]],
             _xs(kernels[names[i % len(names)]],
                 int(rng.choice(batch_pool)), seed * 1000 + i))
            for i in range(n)]


def _assert_parity(pairs, got, want):
    """pairs: (gateway ticket, oracle ticket); got/want: result dicts."""
    assert set(got) >= {gt for gt, _ in pairs}
    for gt, ot in pairs:
        for y, w in zip(got[gt], want[ot]):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))


class FakeAutoscaler:
    """Injectable autoscaler surface for the window-coupling tests."""

    def __init__(self):
        self.scale_up_pending = False
        self.saturated = False


# ============================================================== plumbing
def test_submit_await_bit_parity(kernels):
    oracle = OverlayServer(bank_capacity=16)
    reqs = _mixed(kernels, 12, seed=1)

    async def main():
        async with OverlayGateway(OverlayServer(bank_capacity=16),
                                  poll_interval=0.001) as gw:
            async with gw.connect(tenant="alice") as conn:
                pairs = [(await conn.submit(k, xs), oracle.submit(k, xs))
                         for k, xs in reqs]
                got = {t: await conn.result(t) for t, _ in pairs}
        return pairs, got

    pairs, got = asyncio.run(main())
    _assert_parity(pairs, got, oracle.flush_sync())


def test_streaming_results_pick_up_new_submits(kernels):
    oracle = OverlayServer(bank_capacity=16)
    reqs = _mixed(kernels, 8, seed=2)

    async def main():
        async with OverlayGateway(OverlayServer(bank_capacity=16),
                                  poll_interval=0.001) as gw:
            async with gw.connect() as conn:
                pairs = [(await conn.submit(k, xs), oracle.submit(k, xs))
                         for k, xs in reqs[:4]]
                got, injected = {}, False
                async for t, outs in conn.results():
                    got[t] = outs
                    if not injected:
                        # mid-stream submits must be picked up
                        injected = True
                        pairs.extend([(await conn.submit(k, xs),
                                       oracle.submit(k, xs))
                                      for k, xs in reqs[4:]])
                assert conn.outstanding == frozenset()
        return pairs, got

    pairs, got = asyncio.run(main())
    assert len(got) == len(pairs) == 8
    _assert_parity(pairs, got, oracle.flush_sync())


def test_flush_sync_through_gateway_is_barrier_oracle(kernels):
    """The asyncio layer must not perturb the engine's barrier drain."""
    from repro.sched import AutoPump
    oracle = OverlayServer(bank_capacity=16)
    reqs = _mixed(kernels, 10, seed=3)
    # a STOPPED pump: nothing races the explicit barrier, so the
    # gateway's flush_sync must return exactly the engine's barrier
    # drain — every ticket, bit for bit
    pump = AutoPump(OverlayServer(bank_capacity=16))
    pump.close()

    async def main():
        async with OverlayGateway(pump) as gw:
            async with gw.connect() as conn:
                pairs = [(await conn.submit(k, xs), oracle.submit(k, xs))
                         for k, xs in reqs]
                results = await gw.flush_sync()
                # the barrier drain also resolves the live awaits
                awaited = {t: await conn.result(t) for t, _ in pairs}
        return pairs, results, awaited

    pairs, results, awaited = asyncio.run(main())
    want = oracle.flush_sync()
    _assert_parity(pairs, results, want)
    _assert_parity(pairs, awaited, want)


# ============================================================= lifecycle
def test_double_close_is_idempotent(kernels):
    async def main():
        gw = OverlayGateway(OverlayServer(bank_capacity=4),
                            poll_interval=0.001)
        async with gw:
            conn = gw.connect(tenant="a", session="s1")
            await conn.close()
            await conn.close()                      # no-op
            assert gw.stats()["disconnects"] == 1   # counted once
        await gw.aclose()                           # second gateway close
        assert gw.stats()["connections"] == 0

    asyncio.run(main())


def test_submit_after_close_raises(kernels):
    k = kernels[ALL_NAMES[0]]

    async def main():
        async with OverlayGateway(OverlayServer(bank_capacity=4),
                                  poll_interval=0.001) as gw:
            conn = gw.connect(tenant="a")
            await conn.close()
            with pytest.raises(GatewayClosedError):
                await conn.submit(k, _xs(k, 32, 0))
        # and on a closed gateway: connect() itself refuses
        with pytest.raises(GatewayClosedError):
            gw.connect(tenant="b")

    asyncio.run(main())


def test_reconnect_reclaims_exactly_once(kernels):
    oracle = OverlayServer(bank_capacity=16)
    reqs = _mixed(kernels, 6, seed=4)

    async def main():
        async with OverlayGateway(OverlayServer(bank_capacity=16),
                                  poll_interval=0.001) as gw:
            conn = gw.connect(tenant="a", session="sess-1")
            pairs = [(await conn.submit(k, xs), oracle.submit(k, xs))
                     for k, xs in reqs]
            await conn.close()          # dropped with everything in flight
            assert gw.orphaned_tickets("sess-1") == \
                frozenset(t for t, _ in pairs)

            re1 = gw.connect(tenant="a", session="sess-1")
            got = await re1.reclaim()
            assert set(got) == {t for t, _ in pairs}
            assert await re1.reclaim() == {}        # same connection again
            await re1.close()

            re2 = gw.connect(tenant="a", session="sess-1")
            assert await re2.reclaim() == {}        # and a fresh reconnect
            await re2.close()
            assert gw.stats()["orphan_sessions"] == 0
        return pairs, got

    pairs, got = asyncio.run(main())
    _assert_parity(pairs, got, oracle.flush_sync())


def test_reclaim_covers_engine_claimed_results(kernels):
    """Drop a connection AFTER the pump delivered (the gateway already
    claimed the engine-side result into a future nobody awaited): the
    value must survive the drop and come back on reclaim."""
    oracle = OverlayServer(bank_capacity=16)
    reqs = _mixed(kernels, 4, seed=5)

    async def main():
        async with OverlayGateway(OverlayServer(bank_capacity=16),
                                  poll_interval=0.001) as gw:
            conn = gw.connect(tenant="a", session="sess-2")
            pairs = [(await conn.submit(k, xs), oracle.submit(k, xs))
                     for k, xs in reqs]
            # wait until every future is resolved, then drop WITHOUT
            # awaiting any of them
            while any(not f.done() for f in conn._futures.values()):
                await asyncio.sleep(0.002)
            await conn.close()
            assert gw.stats()["orphaned_results_held"] == len(pairs)
            re = gw.connect(tenant="a", session="sess-2")
            got = await re.reclaim()
            assert await re.reclaim() == {}
            assert gw.stats()["orphaned_results_held"] == 0
        return pairs, got

    pairs, got = asyncio.run(main())
    assert set(got) == {t for t, _ in pairs}
    _assert_parity(pairs, got, oracle.flush_sync())


def test_gateway_close_never_loses_results(kernels):
    """aclose() with a live session connection: no result is lost —
    tickets the gateway had already claimed from the engine survive in
    its orphan store, the rest stay claimable engine-side."""
    reqs = _mixed(kernels, 4, seed=6)
    srv = OverlayServer(bank_capacity=16)
    oracle = OverlayServer(bank_capacity=16)

    async def main():
        gw = OverlayGateway(srv, poll_interval=0.001)
        async with gw:
            conn = gw.connect(tenant="a", session="sess-3")
            pairs = [(await conn.submit(k, xs), oracle.submit(k, xs))
                     for k, xs in reqs]
        # gateway closed mid-flight (it owned the pump, so the pump is
        # stopped too): drain the engine directly and account for every
        # ticket across the two retention stores
        flushed = srv.flush()               # claims whatever was left
        got = {}
        for t, _ in pairs:
            if t in gw._orphan_results:     # claimed pre-close by a tick
                got[t] = gw._orphan_results[t]
            else:
                got[t] = flushed[t]
        return pairs, got

    pairs, got = asyncio.run(main())
    assert all(v is not None for v in got.values())
    _assert_parity(pairs, got, oracle.flush_sync())


# ====================================================== edge backpressure
def test_shed_overflow_raises_overloaded(kernels):
    k = kernels[ALL_NAMES[0]]

    async def main():
        async with OverlayGateway(OverlayServer(bank_capacity=4),
                                  max_fleet_tiles=1, overflow="shed",
                                  poll_interval=0.001) as gw:
            async with gw.connect() as conn:
                with pytest.raises(GatewayOverloadedError) as ei:
                    # 256-batch = 2 tiles > bound 1: sheds even on an
                    # empty fleet — deterministic, no timing involved
                    await conn.submit(k, _xs(k, 256, 0))
                assert ei.value.retry_after >= 0
                assert gw.stats()["edge_shed"] == 1

    asyncio.run(main())


def test_wait_overflow_parks_then_delivers(kernels):
    oracle = OverlayServer(bank_capacity=16)
    reqs = _mixed(kernels, 10, seed=7, batch_pool=(256,))

    async def main():
        async with OverlayGateway(OverlayServer(bank_capacity=16),
                                  max_fleet_tiles=4, overflow="wait",
                                  poll_interval=0.001) as gw:
            async with gw.connect() as conn:
                # a gather floods the capacity check far faster than the
                # pump can drain: most of these MUST park at the edge
                tickets = await asyncio.gather(
                    *(conn.submit(k, xs) for k, xs in reqs))
                pairs = [(t, oracle.submit(k, xs))
                         for t, (k, xs) in zip(tickets, reqs)]
                got = await conn.drain()
            st = gw.stats()
        return pairs, got, st

    pairs, got, st = asyncio.run(main())
    assert st["edge_queued"] >= 1
    assert st["peak_fleet_tiles"] <= 4
    assert len(got) == len(pairs)
    _assert_parity(pairs, got, oracle.flush_sync())


def test_edge_waiters_cap_sheds(kernels):
    k = kernels[ALL_NAMES[0]]

    async def main():
        async with OverlayGateway(OverlayServer(bank_capacity=4),
                                  max_fleet_tiles=1, overflow="wait",
                                  max_edge_waiters=2,
                                  poll_interval=30.0) as gw:
            async with gw.connect() as conn:
                waits = [asyncio.ensure_future(
                    conn.submit(k, _xs(k, 256, i))) for i in range(2)]
                await asyncio.sleep(0)      # let both park
                with pytest.raises(GatewayOverloadedError):
                    await conn.submit(k, _xs(k, 256, 9))
                for w in waits:
                    w.cancel()

    asyncio.run(main())


def test_per_connection_admission_precedes_edge(kernels):
    k = kernels[ALL_NAMES[0]]

    async def main():
        async with OverlayGateway(
                OverlayServer(bank_capacity=4),
                default_admission=(1.0, 1.0),   # 1-tile burst per conn
                poll_interval=0.001) as gw:
            async with gw.connect(tenant="limited") as conn:
                t = await conn.submit(k, _xs(k, 32, 0))     # 1 tile: ok
                with pytest.raises(AdmissionError):
                    await conn.submit(k, _xs(k, 32, 1))     # bucket empty
                await conn.result(t)
            # admission is PER CONNECTION: a fresh connection for the
            # same tenant gets a fresh bucket at this edge
            async with gw.connect(tenant="limited") as conn2:
                await conn2.result(await conn2.submit(k, _xs(k, 32, 2)))

    asyncio.run(main())


def test_window_widens_pending_and_reverts_on_completion(kernels):
    """The coupling contract: scale-up pending => window widens (deeper
    edge bound, cheaper admission); scale-up completed (or saturated)
    => window reverts to 1.0 on the next tick."""
    fake = FakeAutoscaler()
    srv = OverlayServer(bank_capacity=4)
    srv.autoscaler = fake       # duck-typed surface the gateway reads

    async def main():
        async with OverlayGateway(srv, max_fleet_tiles=10,
                                  widen_factor=2.5,
                                  poll_interval=0.001) as gw:
            conn = gw.connect(tenant="a")
            assert gw.window == 1.0 and gw._edge_bound() == 10

            fake.scale_up_pending = True
            assert gw.window == 2.5 and gw._edge_bound() == 25
            gw._tick()          # tick applies it to every connection
            assert conn.admission.window == 2.5
            assert gw.stats()["widened_ticks"] == 1

            # saturated: wants to grow but can't — no widening, the edge
            # sheds/queues instead of stretching
            fake.saturated = True
            assert gw.window == 1.0
            gw._tick()
            assert conn.admission.window == 1.0

            # scale-up lands: pending drops (hot streak reset) — reverted
            fake.saturated = False
            fake.scale_up_pending = False
            assert gw.window == 1.0
            gw._tick()
            assert conn.admission.window == 1.0
            await conn.close()

    asyncio.run(main())


def test_real_autoscaler_pending_and_saturation_flags():
    """The live PressureAutoscaler exposes the coupling flags with the
    documented lifecycle: pending while evidence accrues below the cap,
    cleared when the 'up' lands, saturated at max_replicas."""

    class _Rep:
        def __init__(self, tiles):
            self.queued_tiles = self.pending_tiles = tiles

    class _Fleet:
        def __init__(self, n):
            self.replicas = [_Rep(100) for _ in range(n)]

    a = PressureAutoscaler(up_tiles=8, up_rounds=2, max_replicas=2)
    assert not a.scale_up_pending
    assert a.observe(_Fleet(1)) == []       # 1st hot round: evidence
    assert a.scale_up_pending and not a.saturated
    actions = a.observe(_Fleet(1))          # 2nd: decision fires
    assert any(act[0] == "up" for act in actions)
    assert not a.scale_up_pending           # streak reset: widening ends
    a.observe(_Fleet(2))                    # at cap, still hot
    a.observe(_Fleet(2))
    assert a.saturated and not a.scale_up_pending
    assert a.stats()["saturated_observations"] >= 1


def test_gateway_binds_to_one_loop(kernels):
    k = kernels[ALL_NAMES[0]]
    gw = OverlayGateway(OverlayServer(bank_capacity=4),
                        poll_interval=0.001)

    async def use():
        async with gw.connect() as conn:
            await conn.result(await conn.submit(k, _xs(k, 32, 0)))

    asyncio.run(use())

    async def other_loop():
        with pytest.raises(GatewayError):
            await gw.connect().submit(k, _xs(k, 32, 1))

    asyncio.run(other_loop())
    asyncio.run(gw.aclose())


# ==================================================== edge lifecycle fixes
def test_shed_retry_after_is_defensive():
    """Regression: the shed hint snapshots ``pump.poll_interval`` —
    a stopped pump or an unset/invalid interval must fall back to
    ``DEFAULT_RETRY_AFTER``, never leak ``inf``/``None``/stale state
    into a client-facing hint."""
    from repro.launch.gateway import DEFAULT_RETRY_AFTER
    srv = OverlayServer(bank_capacity=4)
    gw = OverlayGateway(srv, poll_interval=0.003)
    try:
        assert gw._retry_after() == pytest.approx(0.003)
        for bad in (float("inf"), 0.0, -1.0, None, "soon"):
            gw.pump.poll_interval = bad
            assert gw._retry_after() == DEFAULT_RETRY_AFTER, bad
        gw.pump.poll_interval = 0.25
        assert gw._retry_after() == pytest.approx(0.25)
    finally:
        gw.pump.close()
    # a closed pump no longer predicts anything, whatever its interval
    assert gw.pump.closed
    assert gw._retry_after() == DEFAULT_RETRY_AFTER


def test_shed_carries_retry_after_hint(kernels):
    k = kernels["chebyshev"]

    async def main():
        async with OverlayGateway.local(max_fleet_tiles=1,
                                        overflow="shed",
                                        poll_interval=0.004) as gw:
            async with gw.connect() as conn:
                await conn.submit(k, _xs(k, 64, 0))
                with pytest.raises(GatewayOverloadedError) as ei:
                    await conn.submit(k, _xs(k, 512, 1))
                assert ei.value.retry_after == pytest.approx(0.004)
                await gw.flush_sync()

    asyncio.run(main())


def test_orphan_sessions_lru_capped():
    """Regression: sessions that never reclaim must not grow the orphan
    stores without bound — the coldest session expires past
    ``max_orphan_sessions``, dropping its held results, counted and
    evented; re-parking bumps a session to most-recently-used."""

    async def main():
        async with OverlayGateway.local(max_orphan_sessions=2) as gw:
            gw._require_loop()
            gw.park_result("a", 101, ["va"])
            gw.park_result("b", 102, ["vb"])
            gw.park_result("a", 103, ["va2"])       # bump a: order b, a
            gw.park_result("c", 104, ["vc"])        # expires b, not a
            assert list(gw._orphan_sessions) == ["a", "c"]
            assert gw.n_orphans_expired == 1
            assert 102 not in gw._orphan_results    # held value dropped
            st = gw.stats()
            assert st["orphans_expired"] == 1
            assert st["max_orphan_sessions"] == 2
            evs = gw.telemetry.events("orphans_expired")
            assert [e["session"] for e in evs] == ["b"]
            assert evs[0]["tickets"] == 1 and evs[0]["held_results"] == 1
            # the expired session reclaims nothing; survivors reclaim
            # everything they parked
            async with gw.connect(session="b") as rb:
                assert await rb.reclaim() == {}
            async with gw.connect(session="a") as ra:
                got = await ra.reclaim()
            assert {t: v for t, v in got.items()} == {101: ["va"],
                                                      103: ["va2"]}
            # anonymous connections never park
            gw.park_result(None, 105, ["anon"])
            assert 105 not in gw._orphan_results

    asyncio.run(main())


def test_orphan_cap_none_disables_expiry():
    async def main():
        async with OverlayGateway.local(max_orphan_sessions=None) as gw:
            gw._require_loop()
            for i in range(64):
                gw.park_result(f"s{i}", 1000 + i, ["v"])
            assert len(gw._orphan_sessions) == 64
            assert gw.n_orphans_expired == 0

    asyncio.run(main())
    with pytest.raises(ValueError):
        OverlayGateway(OverlayServer(bank_capacity=4),
                       max_orphan_sessions=0)


# ================================================================== soak
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_gateway_churn_soak(kernels, seed):
    """Connect/disconnect/autoscale churn, differential vs the
    single-bank oracle: every admitted ticket is delivered exactly once
    (await, reclaim, or mid-soak barrier), bit-identical to the oracle,
    across forced fleet grow/drain and a live autoscaler."""
    rng = np.random.RandomState(seed)
    oracle = OverlayServer(bank_capacity=16)
    srv = ShardedOverlayServer(
        n_replicas=1, bank_capacity=4, round_kernels=2,
        autoscaler=PressureAutoscaler(up_tiles=8, up_rounds=2,
                                      down_rounds=20, max_replicas=3))
    names = list(kernels)

    async def main():
        got, pairs, dropped = {}, [], []
        async with OverlayGateway(srv, max_fleet_tiles=64,
                                  overflow="wait",
                                  poll_interval=0.001) as gw:
            req_i = 0
            for phase in range(6):
                conns = [gw.connect(tenant=f"t{i % 3}",
                                    session=f"s{seed}-{phase}-{i}")
                         for i in range(3)]
                for conn in conns:
                    for _ in range(int(rng.randint(2, 5))):
                        k = kernels[names[req_i % len(names)]]
                        xs = _xs(k, int(rng.choice((48, 64, 96))),
                                 seed * 10000 + req_i)
                        req_i += 1
                        pairs.append((await conn.submit(k, xs),
                                      oracle.submit(k, xs),
                                      conn.session))
                # forced fleet churn under the pump lock — deterministic
                # grow/drain regardless of autoscaler timing (the live
                # autoscaler keeps observing throughout)
                if phase == 2:
                    with gw.pump._lock:
                        srv.add_replica()
                if phase == 4 and srv.n_replicas > 1:
                    with gw.pump._lock:
                        srv.drain_replica(srv.n_replicas - 1)
                for conn in conns:
                    r = rng.rand()
                    if r < 0.4:
                        got.update(await conn.drain())
                        await conn.close()
                    else:           # dropped with work in flight
                        await conn.close()
                        dropped.append(conn.session)
                if phase == 3:
                    # a mid-soak barrier drain: claims everything,
                    # including parked sessions' results
                    got.update({t: o for t, o in
                                (await gw.flush_sync()).items()
                                if t not in got})
                elif rng.rand() < 0.4:
                    await asyncio.sleep(0.02)       # idle lull
            for sid in dropped:
                re = gw.connect(tenant="reclaimer", session=sid)
                got.update(await re.reclaim())
                assert await re.reclaim() == {}
                await re.close()
            st = gw.stats()
        return got, pairs, st

    got, pairs, st = asyncio.run(main())
    assert {t for t, _, _ in pairs} == set(got), "ticket lost or invented"
    want = oracle.flush_sync()
    for gt, ot, _ in pairs:
        for y, w in zip(got[gt], want[ot]):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))
    assert st["orphan_sessions"] == 0
    assert st["orphaned_results_held"] == 0
    assert st["peak_fleet_tiles"] <= 64 * 2.0       # bound * widen_factor


# =================================================== bench trajectory tool
def test_bench_trajectory_append_and_gate(tmp_path):
    """The cross-PR ledger: append is idempotent per sha, the check gate
    passes baselines vacuously and fails >15% throughput drops."""
    import json
    import sys
    sys.path.insert(0, "tools")
    try:
        import bench_trajectory as bt
    finally:
        sys.path.pop(0)

    art = tmp_path / "bench"
    art.mkdir()
    ledger = tmp_path / "traj.json"
    (art / "gateway.json").write_text(json.dumps(
        {"gateway_rps": 100.0, "connections": 8, "replicas": 2,
         "n_shed": 1, "n_edge_queued": 0, "peak_fleet_tiles": 9}))

    def run(*argv):
        return bt.main(["--ledger", str(ledger), *argv])

    assert run("append", "--artifacts", str(art), "--sha", "aaa") == 0
    assert run("check") == 0                        # baseline only
    assert run("append", "--artifacts", str(art), "--sha", "aaa") == 0
    led = json.loads(ledger.read_text())
    assert len(led["benchmarks"]["gateway"]) == 1   # idempotent per sha

    (art / "gateway.json").write_text(json.dumps({"gateway_rps": 90.0}))
    assert run("append", "--artifacts", str(art), "--sha", "bbb") == 0
    assert run("check") == 0                        # -10%: within 15%

    (art / "gateway.json").write_text(json.dumps({"gateway_rps": 50.0}))
    assert run("append", "--artifacts", str(art), "--sha", "ccc") == 0
    assert run("check") == 1                        # -44% vs bbb: gate
    assert run("check", "--tolerance", "0.5") == 0
    assert run("show") == 0
    # no artifacts at all: append fails unless explicitly allowed
    empty = tmp_path / "empty"
    empty.mkdir()
    assert run("append", "--artifacts", str(empty)) == 1
    assert run("append", "--artifacts", str(empty), "--allow-empty") == 0
