"""Schema-drift lockdown for the serving layers' ``stats()`` dicts.

`repro.telemetry.schema` is the single source of truth for which keys
each layer's ``stats()`` exposes.  These tests exercise every layer in
its meaningful configurations (steal on/off, autoscaler on/off, bare
vs pump-wrapped, gateway) and assert the emitted dicts match the
schema EXACTLY — a key renamed, dropped, or silently added anywhere in
serve/pump/autoscale/gateway fails here with the drift named.

Also: edge-case coverage for `tenant_latency_summary`, the one shared
reducer behind every ``tenant_latency`` stats entry and the SLO study.
"""

import asyncio

import numpy as np
import pytest

from repro.core.overlay import compile_program
from repro.core.paper_bench import benchmark
from repro.launch.gateway import OverlayGateway
from repro.launch.serve import (
    OverlayServer,
    ShardedOverlayServer,
    tenant_latency_summary,
)
from repro.sched import AutoPump, PressureAutoscaler
from repro.telemetry import (
    AUTOSCALER_STATS_KEYS,
    PUMP_STATS_KEYS,
    STEAL_STATS_KEYS,
    check_stats,
)


@pytest.fixture(scope="module")
def kernel():
    return compile_program(benchmark("poly5"))


def _xs(kernel, batch=33, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.uniform(-2, 2, (batch,)).astype(np.float32)
            for _ in kernel.dfg.inputs]


def _work(srv, kernel, n=3):
    for i in range(n):
        srv.submit(kernel, _xs(kernel, seed=i), tenant=f"t{i % 2}")
    srv.flush()


# ============================================================ engine stats
def test_engine_stats_schema(kernel):
    srv = OverlayServer(bank_capacity=4, round_kernels=2, slo_s=0.5)
    check_stats("engine", srv.stats())          # cold: no traffic yet
    _work(srv, kernel)
    check_stats("engine", srv.stats())


def test_engine_stats_schema_under_pump(kernel):
    srv = OverlayServer(bank_capacity=4, round_kernels=2)
    with AutoPump(srv, poll_interval=0.001) as pump:
        pump.submit(kernel, _xs(kernel))
        pump.wait_idle(timeout=30.0)
        st = pump.stats()
        check_stats("engine", st)               # pump keys are optional
        assert PUMP_STATS_KEYS <= set(st)       # ...but all present via pump


# ============================================================= fleet stats
@pytest.mark.parametrize("steal", [False, True], ids=["nosteal", "steal"])
@pytest.mark.parametrize("autoscale", [False, True], ids=["fixed", "auto"])
def test_fleet_stats_schema(kernel, steal, autoscale):
    auto = (PressureAutoscaler(up_tiles=8.0, min_replicas=1, max_replicas=3)
            if autoscale else None)
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=4,
                               round_kernels=2, steal=steal,
                               autoscaler=auto)
    st = srv.stats()
    check_stats("fleet", st)
    assert ("stolen_requests" in st) == steal
    assert (AUTOSCALER_STATS_KEYS <= set(st)) == autoscale
    _work(srv, kernel, n=5)
    srv.add_replica()
    srv.drain_replica(0)
    check_stats("fleet", srv.stats())           # churn must not drift keys
    for rep_stats in srv.stats()["per_replica"]:
        check_stats("engine", rep_stats)        # nested engine dicts too


# =========================================================== gateway stats
def test_gateway_stats_schema(kernel):
    async def scenario():
        srv = ShardedOverlayServer(n_replicas=1, bank_capacity=4,
                                   round_kernels=2)
        async with OverlayGateway(srv, max_fleet_tiles=64,
                                  overflow="wait") as gw:
            check_stats("gateway", gw.stats())
            async with gw.connect(tenant="t0", session="s0") as conn:
                t = await conn.submit(kernel, _xs(kernel))
                await conn.result(t)
            st = gw.stats()
            check_stats("gateway", st)
            check_stats("fleet", st["fleet"])   # nested pump-over-fleet dict
            assert PUMP_STATS_KEYS <= set(st["fleet"])
    asyncio.run(scenario())


def test_check_stats_names_the_drift():
    srv = OverlayServer(bank_capacity=4, round_kernels=2)
    st = srv.stats()
    broken = dict(st)
    del broken["rounds"]
    with pytest.raises(AssertionError, match="missing.*rounds"):
        check_stats("engine", broken)
    broken = dict(st)
    broken["surprise_key"] = 1
    with pytest.raises(AssertionError, match="undeclared.*surprise_key"):
        check_stats("engine", broken)
    with pytest.raises(ValueError, match="unknown stats kind"):
        check_stats("nope", st)


# ============================================== tenant_latency_summary edges
def test_latency_summary_empty():
    assert tenant_latency_summary([]) == {}
    assert tenant_latency_summary([], slo_s=0.1) == {}


def test_latency_summary_no_slo():
    out = tenant_latency_summary([("a", 0.1), ("a", 0.3), ("b", 0.2)])
    assert set(out) == {"a", "b"}
    assert out["a"]["n"] == 2 and out["b"]["n"] == 1
    assert out["a"]["mean"] == pytest.approx(0.2)
    assert "slo_attainment" not in out["a"]


def test_latency_summary_zero_slo():
    # slo_s=0.0 is a real (if brutal) target, not falsy-None: nothing
    # with positive latency attains it
    out = tenant_latency_summary([("a", 0.1), ("a", 0.2)], slo_s=0.0)
    assert out["a"]["slo_attained"] == 0
    assert out["a"]["slo_attainment"] == 0.0
    assert out["a"]["slo_total"] == 2


def test_latency_summary_per_tenant_dict():
    samples = [("lat", 0.01), ("lat", 0.04), ("bulk", 0.5), ("mystery", 0.2)]
    out = tenant_latency_summary(samples, slo_s={"lat": 0.05, "bulk": 0.1})
    assert out["lat"]["slo_attainment"] == 1.0
    assert out["bulk"]["slo_attainment"] == 0.0
    # tenant absent from the dict gets percentiles but no SLO fields
    assert "slo_attainment" not in out["mystery"]
    assert out["mystery"]["n"] == 1


def test_latency_summary_orphan_only_records(kernel):
    """Latency records written by a drained replica survive as part of
    the fleet's tenant_latency stats even when every one of its results
    was orphaned (claimed later through the orphan path)."""
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=4,
                               round_kernels=2, slo_s=60.0)
    tickets = [srv.submit(kernel, _xs(kernel, seed=i), tenant="orphan-t")
               for i in range(4)]
    for rep in srv.replicas:
        rep._fill_pipeline()                   # launch rounds -> pins held
    srv.drain_replica(0)                       # in-flight results orphaned
    assert srv.stats()["orphaned_results"] > 0
    out = {t: srv.result(t) for t in tickets}  # mixed orphan/live claims
    assert set(out) == set(tickets)
    tl = srv.stats()["tenant_latency"]
    assert tl["orphan-t"]["n"] == 4
    assert tl["orphan-t"]["slo_attainment"] == 1.0
    check_stats("fleet", srv.stats())
