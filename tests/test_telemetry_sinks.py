"""Conformance tests for the telemetry sinks themselves.

The invariants suite (test_telemetry_invariants.py) trusts the sink to
be an exact, thread-safe ledger; this file earns that trust:

- protocol conformance for all three sink classes,
- counter/peak/reset semantics (including prefix resets),
- event/step bounds, filtering, and injectable-clock stamping,
- JSONL round-trip fidelity (events, steps, counter snapshots) and
  crash-safe flush behaviour,
- MultiSink fan-out writes vs first-child reads/resets,
- exact totals under free-threaded hammering,
- zero event/counter loss across the AutoPump tick path and across
  add_replica/drain_replica churn,
- a bounded-overhead smoke: serving with a JSONL sink attached stays
  within a loose constant factor of the default in-memory sink.
"""

import json
import threading
import time

import numpy as np
import pytest

from repro.core.overlay import compile_program
from repro.core.paper_bench import benchmark
from repro.launch.serve import OverlayServer, ShardedOverlayServer
from repro.sched import AutoPump
from repro.telemetry import (
    InMemorySink,
    JsonlSink,
    MultiSink,
    Telemetry,
    adopt_counters,
    read_jsonl,
)


@pytest.fixture(scope="module")
def kernel():
    return compile_program(benchmark("poly5"))


def _xs(kernel, batch=33, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.uniform(-2, 2, (batch,)).astype(np.float32)
            for _ in kernel.dfg.inputs]


# ========================================================== protocol/basics
def test_sinks_satisfy_protocol(tmp_path):
    sinks = [InMemorySink(), JsonlSink(tmp_path / "t.jsonl"),
             MultiSink(InMemorySink(), InMemorySink())]
    for s in sinks:
        assert isinstance(s, Telemetry)
        s.close()


def test_counter_basics():
    s = InMemorySink()
    assert s.counter("a.b") == 0.0          # never-written reads as zero
    assert s.inc("a.b") == 1.0
    assert s.inc("a.b", 2.5) == 3.5
    assert s.counter("a.b") == 3.5
    assert s.peak("a.max", 4.0) == 4.0
    assert s.peak("a.max", 2.0) == 4.0      # monotone: lower values ignored
    assert s.peak("a.max", 9.0) == 9.0
    s.inc("other.c", 7.0)
    assert s.counters("a.") == {"a.b": 3.5, "a.max": 9.0}
    assert set(s.counters()) == {"a.b", "a.max", "other.c"}


def test_reset_by_name_and_prefix():
    s = InMemorySink()
    for n in ("x.one", "x.two", "y.one"):
        s.inc(n, 5.0)
    s.reset(names=("x.one",))
    assert s.counter("x.one") == 0.0 and s.counter("x.two") == 5.0
    s.reset(prefix="x.")
    assert s.counter("x.two") == 0.0 and s.counter("y.one") == 5.0


def test_events_bounded_filtered_and_clock_stamped():
    t = [100.0]
    s = InMemorySink(clock=lambda: t[0], max_events=8)
    for i in range(20):
        t[0] = 100.0 + i
        s.event("tick" if i % 2 else "tock", i=i)
    evs = s.events()
    assert len(evs) == 8                      # bounded deque kept the tail
    assert [e["i"] for e in evs] == list(range(12, 20))
    assert all(e["t"] == 100.0 + e["i"] for e in evs)
    assert all(e["name"] == "tick" for e in s.events("tick"))
    s.log_step(3, loss=0.5)
    assert s.steps() == [{"t": t[0], "step": 3, "loss": 0.5}]


# ================================================================== JSONL
def test_jsonl_round_trip(tmp_path):
    path = tmp_path / "run.jsonl"
    s = JsonlSink(path, clock=lambda: 1.5)
    s.event("deliver", tenant="a", cost=3)
    s.log_step(0, tiles=4, wall_s=0.01)
    s.inc("engine.rounds", 2.0)
    s.peak("edge.peak", 7.0)
    s.flush()                                 # snapshot + fsync
    recs = read_jsonl(path)
    kinds = [r["kind"] for r in recs]
    assert kinds == ["event", "step", "counters"]
    assert recs[0] == {"kind": "event", "t": 1.5, "name": "deliver",
                       "tenant": "a", "cost": 3}
    assert recs[1] == {"kind": "step", "t": 1.5, "step": 0,
                       "tiles": 4, "wall_s": 0.01}
    assert recs[2]["counters"] == {"engine.rounds": 2.0, "edge.peak": 7.0}
    # flush() already fsynced: a reader sees the data before close()
    with open(path, encoding="utf-8") as f:
        assert len(f.readlines()) == 3
    s.inc("engine.rounds")
    s.close()                                 # second snapshot on close
    recs = read_jsonl(path)
    assert recs[-1]["counters"]["engine.rounds"] == 3.0
    # every line is standalone-parseable JSON (crash-safe format)
    with open(path, encoding="utf-8") as f:
        for line in f:
            json.loads(line)


def test_jsonl_close_and_flush_are_idempotent(tmp_path):
    """Regression: flush()/close() after close() must be no-ops, never a
    ValueError on the dead handle — shutdown paths routinely close a
    shared sink from more than one layer."""
    path = tmp_path / "idem.jsonl"
    s = JsonlSink(path, clock=lambda: 0.0)
    s.inc("edge.shed", 3.0)
    s.event("shed", tenant="a")
    s.close()
    assert s.closed
    n_lines = len(read_jsonl(path))
    s.close()                                 # all no-ops from here on
    s.flush()
    s.close()
    assert len(read_jsonl(path)) == n_lines   # no extra snapshots
    # writes post-close are dropped on the floor, but reads stay live
    s.event("late", tenant="b")
    s.log_step(1, tiles=2)
    assert s.counter("edge.shed") == 3.0
    assert len(read_jsonl(path)) == n_lines
    # a handle closed OUT-OF-BAND (crash cleanup, GC order) must not
    # break flush/close either
    s2 = JsonlSink(tmp_path / "oob.jsonl")
    s2.inc("x", 1.0)
    s2._f.close()
    s2.flush()
    s2.close()
    assert s2.closed


def test_jsonl_counter_reads_stay_in_memory(tmp_path):
    path = tmp_path / "hot.jsonl"
    s = JsonlSink(path)
    for _ in range(1000):
        s.inc("hot.counter")
    assert s.counter("hot.counter") == 1000.0
    # no flush yet -> the hot path wrote zero lines
    with open(path, encoding="utf-8") as f:
        assert f.read() == ""
    s.close()


# =============================================================== MultiSink
def test_multisink_fan_out_and_first_child_reads():
    own, shared = InMemorySink(), InMemorySink()
    m = MultiSink(own, shared)
    assert m.inc("n", 2.0) == 2.0             # returns the FIRST child's total
    shared.inc("n", 10.0)                     # out-of-band fleet activity
    m.inc("n")
    assert m.counter("n") == 3.0              # reads the first child only
    assert shared.counter("n") == 13.0        # ...but writes hit both
    m.event("e", k=1)
    assert len(own.events("e")) == len(shared.events("e")) == 1
    m.log_step(0, a=1)
    assert len(own.steps()) == len(shared.steps()) == 1
    m.reset(names=("n",))                     # reset stays local to primary
    assert m.counter("n") == 0.0 and shared.counter("n") == 13.0
    with pytest.raises(ValueError):
        MultiSink()


def test_adopt_counters_folds_prebinding_history():
    private, shared = InMemorySink(), InMemorySink()
    private.inc("router.hits", 4.0)
    private.inc("router.misses", 0.0)         # zero-valued: skipped
    shared.inc("router.hits", 1.0)
    adopt_counters(shared, private)
    assert shared.counter("router.hits") == 5.0
    assert "router.misses" not in shared.counters()


# =========================================================== thread safety
@pytest.mark.parametrize("make", [
    lambda tmp: InMemorySink(),
    lambda tmp: JsonlSink(tmp / "c.jsonl"),
    lambda tmp: MultiSink(InMemorySink(), InMemorySink()),
], ids=["memory", "jsonl", "multi"])
def test_counters_exact_under_threads(tmp_path, make):
    s = make(tmp_path)
    N, T = 2000, 8

    def hammer(i):
        for j in range(N):
            s.inc("hammer.count")
            s.peak("hammer.peak", float(i * N + j))
            if j % 100 == 0:
                s.event("beat", worker=i)
    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert s.counter("hammer.count") == float(N * T)
    assert s.counter("hammer.peak") == float(N * T - 1)
    assert len(s.events("beat")) == T * (N // 100)
    s.close()


def test_no_loss_through_autopump_tick_path(kernel):
    """Caller threads submit while the pump thread drives rounds; the
    shared sink's ledger must close exactly (pump + engine + callers all
    write the same store concurrently)."""
    srv = OverlayServer(bank_capacity=4, round_kernels=2)
    with AutoPump(srv, poll_interval=0.001) as pump:
        tickets: list[int] = []
        lock = threading.Lock()

        def client(seed):
            for j in range(6):
                t = pump.submit(kernel, _xs(kernel, seed=seed * 31 + j),
                                tenant=f"t{seed}")
                with lock:
                    tickets.append(t)
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        pump.wait_idle(timeout=30.0)
        tele = srv.telemetry
        assert tele.counter("engine.submits") == float(len(tickets)) == 24.0
        assert tele.counter("engine.delivered") == float(len(tickets))
        assert pump.n_pump_rounds >= 1
        assert tele.counter("pump.ticks") >= tele.counter("pump.rounds")
        assert {t: pump.try_result(t) for t in tickets}  # all claimable


def test_no_loss_across_replica_churn(kernel):
    """Counters written by replicas that later drain must survive in the
    fleet sink: grow, work, drain the original, work more — the fleet
    ledger still closes."""
    srv = ShardedOverlayServer(n_replicas=1, bank_capacity=4,
                               round_kernels=2)
    tickets = [srv.submit(kernel, _xs(kernel, seed=i)) for i in range(5)]
    srv.add_replica()
    tickets += [srv.submit(kernel, _xs(kernel, seed=10 + i))
                for i in range(5)]
    srv.drain_replica(0)                       # retires work already counted
    tickets += [srv.submit(kernel, _xs(kernel, seed=20 + i))
                for i in range(5)]
    out = srv.flush()
    assert set(out) == set(tickets)
    c = srv.telemetry.counter
    assert c("fleet.submits") == 15.0
    assert c("engine.submits") == 15.0         # replica sinks fanned out here
    assert c("engine.delivered") == 15.0
    assert c("engine.rounds") == float(srv.stats()["rounds"]) > 0
    # per-request deliver events also survived the churn
    assert len(srv.telemetry.events("deliver")) == 15


# ======================================================= overhead (smoke)
def test_jsonl_sink_overhead_bounded(kernel, tmp_path):
    """Serving with a JSONL fan-out attached must stay within a loose
    constant factor of the default in-memory sink (best-of-3 each; the
    bound is generous on purpose — this is a regression tripwire for
    accidental per-inc file writes, not a microbenchmark)."""
    def run(sink):
        srv = OverlayServer(bank_capacity=4, round_kernels=2,
                            telemetry=sink)
        for i in range(12):
            srv.submit(kernel, _xs(kernel, seed=i))
        srv.flush_sync()

    def best_of(n, factory):
        walls = []
        for _ in range(n):
            sink = factory()
            t0 = time.perf_counter()
            run(sink)
            walls.append(time.perf_counter() - t0)
            sink.close()
        return min(walls)

    run(InMemorySink())                        # warm compile caches
    base = best_of(3, InMemorySink)
    k = [0]

    def jsonl_factory():
        k[0] += 1
        return MultiSink(InMemorySink(),
                         JsonlSink(tmp_path / f"ovh{k[0]}.jsonl"))
    withj = best_of(3, jsonl_factory)
    assert withj <= max(base * 3.0, base + 0.25), (
        f"jsonl sink overhead blew the bound: {withj:.4f}s vs "
        f"{base:.4f}s baseline")
