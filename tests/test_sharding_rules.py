"""Sharding rules: structural + divisibility guarantees for all archs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models import init_params, model as M
from repro.runtime import sharding as S


def _fake_mesh(shape=(16, 16), axes=("data", "model")):
    """Mesh over fake device objects — good enough for spec derivation."""
    devs = np.asarray(jax.devices() * int(np.prod(shape)))[
        : int(np.prod(shape))].reshape(shape)
    return Mesh(devs, axes)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_sharding_tree_matches_params(arch):
    """Spec tree and param tree must have identical structure, and after
    sanitize every spec divides its dim."""
    cfg = get_config(arch)
    mesh = _fake_mesh()
    params_sds = jax.eval_shape(
        lambda k: M.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    spec = S.param_shardings(cfg, mesh, S.for_mesh(mesh))
    a = jax.tree.structure(params_sds)
    b = jax.tree.structure(spec, is_leaf=lambda x: isinstance(x, P))
    assert a == b, f"{arch}: structure drift between init and sharding"
    fixed = S.sanitize(spec, params_sds, mesh)
    for (path, p), sds in zip(
            jax.tree_util.tree_flatten_with_path(
                fixed, is_leaf=lambda x: isinstance(x, P))[0],
            jax.tree.leaves(params_sds)):
        for d, e in zip(sds.shape, tuple(p) + (None,) * len(sds.shape)):
            if e is None:
                continue
            size = (np.prod([mesh.shape[a_] for a_ in e])
                    if isinstance(e, tuple) else mesh.shape[e])
            assert d % size == 0, (arch, path, sds.shape, p)


@pytest.mark.parametrize("layout", ["2d", "fsdp"])
def test_layout_axes(layout):
    mesh = _fake_mesh()
    ax = S.for_mesh(mesh, layout)
    dp, tp = ax.sizes(mesh)
    if layout == "fsdp":
        assert dp == 256 and tp == 1 and ax.tp is None
    else:
        assert dp == 16 and tp == 16


def test_cache_sharding_seq_parallel_for_batch1():
    cfg = get_config("gemma3-4b")
    mesh = _fake_mesh()
    specs = S.cache_shardings(cfg, mesh, global_batch=1)
    leaf = specs[0][0]["k"]   # [count, B, S, KH, hd]
    assert leaf[1] is None          # batch=1 cannot shard batch
    assert leaf[2] in ("data", ("data",))   # sequence takes the DP axes


def test_multi_pod_axes():
    mesh = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    ax = S.for_mesh(mesh)
    assert ax.batch == ("pod", "data")
    dp, tp = ax.sizes(mesh)
    assert dp == 32 and tp == 16
