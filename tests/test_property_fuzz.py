"""Property/fuzz tests: random DFGs through the whole mapping flow.

Random straight-line kernels are compiled (schedule -> encode -> context)
and executed on every backend; results must match the dfg_eval oracle
BIT-FOR-BIT on f32 — the VM performs the same elementwise f32 ops in the
same order, so there is no legitimate source of drift.  Covers the jnp VM,
the Pallas TMFU kernel (interpret mode), and the multi-context bank path.

Runs with or without hypothesis installed (repro.testing falls back to a
seeded-random strategy shim).
"""

import jax.numpy as jnp
import numpy as np

from repro.core.frontend import build_dfg
from repro.core.isa import EncodeError, encode
from repro.core.overlay import Overlay, compile_program
from repro.core.schedule import schedule
from repro.core.vm import dfg_eval, make_context, pad_inputs, vm_exec
from repro.kernels.tmfu import tmfu_pipeline
from repro.testing import given, settings, st


def random_dfg(seed: int, max_stmts: int = 16, name: str = "fuzz"):
    """A random valid straight-line kernel (dead code folded into output)."""
    rng = np.random.RandomState(seed)
    n_in = int(rng.randint(1, 6))
    n_stmt = int(rng.randint(1, max_stmts + 1))
    names = [f"x{i}" for i in range(n_in)]
    used: set = set()
    lines = []
    for i in range(n_stmt):
        op = rng.choice(["+", "-", "*"])
        a = names[rng.randint(len(names))]
        used.add(a)
        if rng.rand() < 0.3:
            b = str(rng.randint(1, 9))
        else:
            b = names[rng.randint(len(names))]
            used.add(b)
        t = f"t{i}"
        lines.append(f"{t} = {a} {op} {b}")
        names.append(t)
    out = f"t{n_stmt - 1}"
    for j, d in enumerate(n for n in names[:-1] if n not in used):
        lines.append(f"f{j} = {out} + {d}")
        out = f"f{j}"
    dfg = build_dfg(name, [f"x{i}" for i in range(n_in)],
                    "\n".join(lines), [out])
    return dfg


def _compile_or_none(dfg):
    """None when the kernel legally exceeds FU capacity (not a bug)."""
    try:
        encode(schedule(dfg))
    except EncodeError:
        return None
    return compile_program(dfg)


def _inputs(dfg, seed, batch=128):
    rng = np.random.RandomState(seed ^ 0x5A5A)
    return [rng.uniform(-1.5, 1.5, (batch,)).astype(np.float32)
            for _ in dfg.inputs]


def _oracle(dfg, xs):
    ref = dfg_eval(dfg, {n: jnp.asarray(v)
                         for n, v in zip(dfg.inputs, xs)})
    return [np.asarray(ref[o]) for o in dfg.outputs]


# ----------------------------------------------------------------- jnp VM
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_fuzz_jnp_vm_bitexact(seed):
    dfg = random_dfg(seed)
    k = _compile_or_none(dfg)
    if k is None:
        return
    ov = Overlay(s_max=max(16, dfg.depth))
    ctx = ov.load(k)
    xs = _inputs(dfg, seed)
    ys = ov(ctx, xs)
    for y, want in zip(ys, _oracle(dfg, xs)):
        np.testing.assert_array_equal(np.asarray(y), want)


# ------------------------------------------------------- pallas (interpret)
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_fuzz_pallas_interpret_bitexact(seed):
    dfg = random_dfg(seed, max_stmts=12)
    k = _compile_or_none(dfg)
    if k is None or dfg.depth > 16:
        return
    ctx = make_context(k.program, dtype=jnp.float32)
    xs = _inputs(dfg, seed)
    x = pad_inputs([jnp.asarray(v) for v in xs])
    got = tmfu_pipeline(ctx, x, block_batch=128, interpret=True)
    for j, want in enumerate(_oracle(dfg, xs)):
        np.testing.assert_array_equal(np.asarray(got[j]), want)


# ------------------------------------------------------- multi-context bank
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_fuzz_multi_context_dispatch_bitexact(seed):
    """A bank of random kernels served as one mixed batch == per-kernel VM."""
    rng = np.random.RandomState(seed ^ 0xBEEF)
    kernels = []
    i = 0
    while len(kernels) < 4:
        dfg = random_dfg(int(rng.randint(2 ** 31)), max_stmts=10,
                         name=f"fz{seed}_{i}")
        i += 1
        k = _compile_or_none(dfg)
        if k is not None and dfg.depth <= 16:
            kernels.append(k)
    ov = Overlay()
    bank = ov.load_many(kernels)
    reqs = []
    for j, k in enumerate(kernels * 2):
        # batch widths from a small pool so dispatch's pow2 tile buckets
        # repeat across examples (retraces would dominate the runtime)
        xs = _inputs(k.dfg, seed + j,
                     batch=int(rng.choice([33, 64, 128, 200])))
        reqs.append((k, xs))
    outs = ov.dispatch(bank, reqs)
    for (k, xs), ys in zip(reqs, outs):
        for y, want in zip(ys, _oracle(k.dfg, xs)):
            np.testing.assert_array_equal(np.asarray(y), want)
    # the bank path must also agree bit-for-bit with the single-context VM
    # (one fixed batch width, so the solo executor compiles exactly once)
    k = kernels[0]
    xs = _inputs(k.dfg, seed, batch=128)
    ctx = ov.load(k)
    solo = vm_exec(ctx.tree(), ctx.out_idx,
                   pad_inputs([jnp.asarray(v) for v in xs]))
    [ys] = ov.dispatch(bank, [(k, xs)])
    for j, y in enumerate(ys):
        np.testing.assert_array_equal(np.asarray(y), np.asarray(solo[j]))
