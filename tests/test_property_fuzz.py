"""Property/fuzz tests: random DFGs through the whole mapping flow.

Random straight-line kernels are compiled (schedule -> encode -> context)
and executed on every backend; results must match the dfg_eval oracle
BIT-FOR-BIT on f32 — the VM performs the same elementwise f32 ops in the
same order, so there is no legitimate source of drift.  Covers the jnp VM,
the Pallas TMFU kernel (interpret mode), and the multi-context bank path.

Runs with or without hypothesis installed (repro.testing falls back to a
seeded-random strategy shim).

The sharded-serving fuzz (bottom of this file) drives random
interleavings of ``submit`` / ``flush`` / ``flush_sync`` /
``as_completed`` / ``result`` / direct ``bank.load`` churn — plus FLEET
MUTATION (``add_replica`` / ``drain_replica`` mixed into the stream, so
elastic autoscaling's evacuation/orphan/directory-compaction paths are
covered per example, not just in tests/test_autoscale.py) — across a
random replica fleet and holds every delivered ticket to the per-request
single-bank oracle — including the router's stale-directory fallback,
which each example provokes deliberately (direct loads bump the banks'
residency generations behind the directory's back).
"""

import jax.numpy as jnp
import numpy as np

from repro.core.frontend import build_dfg
from repro.core.isa import EncodeError, encode
from repro.core.overlay import Overlay, compile_program
from repro.core.schedule import schedule
from repro.core.vm import dfg_eval, make_context, pad_inputs, vm_exec
from repro.kernels.tmfu import tmfu_pipeline
from repro.testing import given, settings, st


def random_dfg(seed: int, max_stmts: int = 16, name: str = "fuzz"):
    """A random valid straight-line kernel (dead code folded into output)."""
    rng = np.random.RandomState(seed)
    n_in = int(rng.randint(1, 6))
    n_stmt = int(rng.randint(1, max_stmts + 1))
    names = [f"x{i}" for i in range(n_in)]
    used: set = set()
    lines = []
    for i in range(n_stmt):
        op = rng.choice(["+", "-", "*"])
        a = names[rng.randint(len(names))]
        used.add(a)
        if rng.rand() < 0.3:
            b = str(rng.randint(1, 9))
        else:
            b = names[rng.randint(len(names))]
            used.add(b)
        t = f"t{i}"
        lines.append(f"{t} = {a} {op} {b}")
        names.append(t)
    out = f"t{n_stmt - 1}"
    for j, d in enumerate(n for n in names[:-1] if n not in used):
        lines.append(f"f{j} = {out} + {d}")
        out = f"f{j}"
    dfg = build_dfg(name, [f"x{i}" for i in range(n_in)],
                    "\n".join(lines), [out])
    return dfg


def _compile_or_none(dfg):
    """None when the kernel legally exceeds FU capacity (not a bug)."""
    try:
        encode(schedule(dfg))
    except EncodeError:
        return None
    return compile_program(dfg)


def _inputs(dfg, seed, batch=128):
    rng = np.random.RandomState(seed ^ 0x5A5A)
    return [rng.uniform(-1.5, 1.5, (batch,)).astype(np.float32)
            for _ in dfg.inputs]


def _oracle(dfg, xs):
    ref = dfg_eval(dfg, {n: jnp.asarray(v)
                         for n, v in zip(dfg.inputs, xs)})
    return [np.asarray(ref[o]) for o in dfg.outputs]


# ----------------------------------------------------------------- jnp VM
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_fuzz_jnp_vm_bitexact(seed):
    dfg = random_dfg(seed)
    k = _compile_or_none(dfg)
    if k is None:
        return
    ov = Overlay(s_max=max(16, dfg.depth))
    ctx = ov.load(k)
    xs = _inputs(dfg, seed)
    ys = ov(ctx, xs)
    for y, want in zip(ys, _oracle(dfg, xs)):
        np.testing.assert_array_equal(np.asarray(y), want)


# ------------------------------------------------------- pallas (interpret)
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_fuzz_pallas_interpret_bitexact(seed):
    dfg = random_dfg(seed, max_stmts=12)
    k = _compile_or_none(dfg)
    if k is None or dfg.depth > 16:
        return
    ctx = make_context(k.program, dtype=jnp.float32)
    xs = _inputs(dfg, seed)
    x = pad_inputs([jnp.asarray(v) for v in xs])
    got = tmfu_pipeline(ctx, x, block_batch=128, interpret=True)
    for j, want in enumerate(_oracle(dfg, xs)):
        np.testing.assert_array_equal(np.asarray(got[j]), want)


# ------------------------------------------------------- multi-context bank
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_fuzz_multi_context_dispatch_bitexact(seed):
    """A bank of random kernels served as one mixed batch == per-kernel VM."""
    rng = np.random.RandomState(seed ^ 0xBEEF)
    kernels = []
    i = 0
    while len(kernels) < 4:
        dfg = random_dfg(int(rng.randint(2 ** 31)), max_stmts=10,
                         name=f"fz{seed}_{i}")
        i += 1
        k = _compile_or_none(dfg)
        if k is not None and dfg.depth <= 16:
            kernels.append(k)
    ov = Overlay()
    bank = ov.load_many(kernels)
    reqs = []
    for j, k in enumerate(kernels * 2):
        # batch widths from a small pool so dispatch's pow2 tile buckets
        # repeat across examples (retraces would dominate the runtime)
        xs = _inputs(k.dfg, seed + j,
                     batch=int(rng.choice([33, 64, 128, 200])))
        reqs.append((k, xs))
    outs = ov.dispatch(bank, reqs)
    for (k, xs), ys in zip(reqs, outs):
        for y, want in zip(ys, _oracle(k.dfg, xs)):
            np.testing.assert_array_equal(np.asarray(y), want)
    # the bank path must also agree bit-for-bit with the single-context VM
    # (one fixed batch width, so the solo executor compiles exactly once)
    k = kernels[0]
    xs = _inputs(k.dfg, seed, batch=128)
    ctx = ov.load(k)
    solo = vm_exec(ctx.tree(), ctx.out_idx,
                   pad_inputs([jnp.asarray(v) for v in xs]))
    [ys] = ov.dispatch(bank, [(k, xs)])
    for j, y in enumerate(ys):
        np.testing.assert_array_equal(np.asarray(y), np.asarray(solo[j]))


# ------------------------------------------------------- sharded interleaving
def _random_kernel_pool(rng, n=4):
    kernels = []
    i = 0
    while len(kernels) < n:
        dfg = random_dfg(int(rng.randint(2 ** 31)), max_stmts=10,
                         name=f"shfz_{rng.randint(1 << 30)}_{i}")
        i += 1
        k = _compile_or_none(dfg)
        if k is not None and dfg.depth <= 16:
            kernels.append(k)
    return kernels


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_fuzz_sharded_interleaving_bitexact(seed):
    """Random submit/flush/load/result interleavings across a random
    replica fleet == the single-bank oracle, ticket by ticket."""
    from repro.launch.serve import ShardedOverlayServer

    rng = np.random.RandomState(seed ^ 0x51A2)
    kernels = _random_kernel_pool(rng, n=4)
    n_replicas = int(rng.choice([2, 3, 4]))
    srv = ShardedOverlayServer(
        n_replicas=n_replicas, bank_capacity=3, round_kernels=2,
        max_inflight=int(rng.choice([1, 2, 3])),
        quantum_tiles=float(rng.choice([2.0, 8.0])) if rng.rand() < 0.5
        else None,
        migrate_min_tiles=int(rng.choice([2, 10_000])))
    ov = Overlay()

    def oracle(k, xs):
        [ys] = ov.dispatch(ov.load_many([k], capacity=4), [(k, xs)])
        return [np.asarray(y) for y in ys]

    pending: dict[int, tuple] = {}      # ticket -> (kernel, xs)
    delivered: dict[int, list] = {}

    def check(results):
        for t, ys in results.items():
            k, xs = pending.pop(t)
            delivered[t] = ys
            for y, want in zip(ys, oracle(k, xs)):
                np.testing.assert_array_equal(np.asarray(y), want)

    for _step in range(24):
        action = rng.choice(["submit", "drain", "load", "result",
                             "grow", "shrink"],
                            p=[0.5, 0.13, 0.12, 0.09, 0.08, 0.08])
        if action == "submit":
            k = kernels[rng.randint(len(kernels))]
            xs = _inputs(k.dfg, int(rng.randint(1 << 30)),
                         batch=int(rng.choice([33, 64, 128])))
            t = srv.submit(k, xs, tenant=f"t{rng.randint(3)}")
            pending[t] = (k, xs)
        elif action == "drain" and pending:
            mode = rng.choice(["flush", "flush_sync", "as_completed"])
            if mode == "flush":
                check(srv.flush())
            elif mode == "flush_sync":
                check(srv.flush_sync())
            else:
                check(dict(srv.as_completed()))
        elif action == "load":
            # directly churn a random replica's bank: evictions bump the
            # residency generation and stale out the directory's entries
            bank = srv.banks[rng.randint(len(srv.banks))]
            try:
                bank.load(kernels[rng.randint(len(kernels))])
            except Exception:       # all-pinned bank mid-flight is legal
                pass
        elif action == "result" and pending:
            t = list(pending)[rng.randint(len(pending))]
            k, xs = pending[t]
            check({t: srv.result(t)})
        elif action == "grow" and len(srv.replicas) < 6:
            srv.add_replica()
        elif action == "shrink" and len(srv.replicas) > 1:
            # elastic drain mid-churn: queued work must evacuate, results
            # must orphan, and the directory must compact — all while the
            # per-ticket oracle parity below keeps holding
            srv.drain_replica(int(rng.randint(len(srv.replicas))))
    # deterministic fleet-mutation coverage in EVERY example: one forced
    # grow + drain pair before the final drain
    srv.add_replica()
    if len(srv.replicas) > 1:
        srv.drain_replica(0)
    check(srv.flush())
    assert not pending and srv.pending == 0
    for bank in srv.banks:
        assert bank.n_pinned == 0
    for ent in srv.directory._map.values():
        assert 0 <= ent.replica < len(srv.replicas)

    # deterministic stale-fallback coverage in EVERY example: publish a
    # residency, evict it behind the directory's back, and require the
    # router to detect the generation mismatch and re-route
    k_stale = kernels[0]
    t = srv.submit(k_stale, _inputs(k_stale.dfg, seed, batch=64))
    rep = srv.record(t)["replica"]
    srv.flush()
    bank = srv.banks[rep]
    while bank.peek(k_stale) is not None:   # churn until evicted
        bank.load(kernels[rng.randint(1, len(kernels))])
    n_stale0 = srv.directory.n_stale
    xs = _inputs(k_stale.dfg, seed + 1, batch=64)
    t2 = srv.submit(k_stale, xs)
    assert srv.directory.n_stale == n_stale0 + 1
    for y, want in zip(srv.flush()[t2], oracle(k_stale, xs)):
        np.testing.assert_array_equal(np.asarray(y), want)
