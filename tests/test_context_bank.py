"""Multi-tenant serving engine tests: ContextBank + vm_exec_multi + dispatch.

Covers the PR acceptance bar: a bank of >= 8 resident kernels serves a
mixed-kernel request batch through a SINGLE compiled vm_exec_multi
executable (zero retraces after warmup, asserted on the jit cache), with
every output matching the dfg_eval oracle; plus LRU eviction / slot-id
reuse semantics and the Pallas multi-context path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import vm as vm_mod
from repro.core.bank import BankError, ContextBank, context_key
from repro.core.frontend import build_dfg
from repro.core.overlay import Overlay, compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.core.vm import dfg_eval
from repro.launch.serve import OverlayServer

ALL_NAMES = BENCH_NAMES + ("gradient",)


@pytest.fixture(scope="module")
def kernels():
    return {n: compile_program(benchmark(n)) for n in ALL_NAMES}


def _requests(kernels, names, batches, seed=0):
    rng = np.random.RandomState(seed)
    reqs = []
    for n, b in zip(names, batches):
        k = kernels[n]
        xs = [rng.uniform(-2, 2, (b,)).astype(np.float32)
              for _ in k.dfg.inputs]
        reqs.append((k, xs))
    return reqs


def _check_against_oracle(reqs, outs, rtol=1e-6, atol=1e-6):
    for (k, xs), ys in zip(reqs, outs):
        assert len(ys) == len(k.dfg.outputs)
        ref = dfg_eval(k.dfg, {m: jnp.asarray(v)
                               for m, v in zip(k.dfg.inputs, xs)})
        for o, y in zip(k.dfg.outputs, ys):
            assert y.shape == np.shape(xs[0])
            np.testing.assert_allclose(np.asarray(y), np.asarray(ref[o]),
                                       rtol=rtol, atol=atol)


# ------------------------------------------------------------- acceptance bar
def test_bank_of_9_serves_mixed_batch_single_executable(kernels):
    """>= 8 resident kernels, one executor, zero retraces after warmup."""
    ov = Overlay()
    bank = ov.load_many(kernels.values(), capacity=len(kernels))
    assert len(bank) == 9 >= 8
    names = list(ALL_NAMES) * 2
    batches = [64, 100, 128, 300, 17, 256, 90, 128, 1][::-1] + [128] * 9
    reqs = _requests(kernels, names, batches)
    outs = ov.dispatch(bank, reqs)          # warmup launch
    _check_against_oracle(reqs, outs)
    n0 = vm_mod.vm_exec_multi._cache_size()
    reqs2 = _requests(kernels, names, batches, seed=7)
    outs2 = ov.dispatch(bank, reqs2)
    _check_against_oracle(reqs2, outs2)
    assert vm_mod.vm_exec_multi._cache_size() == n0, \
        "mixed-kernel dispatch retraced after warmup"


def test_dispatch_pallas_backend_matches_oracle(kernels):
    names = ("chebyshev", "poly6", "gradient", "mibench", "qspline")
    ov = Overlay(backend="pallas")
    bank = ov.load_many([kernels[n] for n in names])
    reqs = _requests(kernels, names, [200, 64, 128, 33, 256], seed=3)
    outs = ov.dispatch(bank, reqs)
    _check_against_oracle(reqs, outs, rtol=1e-5, atol=1e-5)


def test_vm_exec_multi_agrees_with_vm_exec(kernels):
    """Gathering context c from the bank == running context c standalone."""
    ov = Overlay()
    bank = ov.load_many(kernels.values(), capacity=len(kernels))
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.uniform(-2, 2, (len(ALL_NAMES), 32, 128))
                    .astype(np.float32))
    ids = jnp.arange(len(ALL_NAMES), dtype=jnp.int32)
    ys = vm_mod.vm_exec_multi(bank.tree(), bank.out_idx, ids, x)
    for slot in range(len(ALL_NAMES)):
        k = kernels[bank.meta(slot)["name"]]
        ctx = ov.load(k)
        want = vm_mod.vm_exec(ctx.tree(), ctx.out_idx, x[slot])
        np.testing.assert_allclose(
            np.asarray(ys[slot, :ctx.n_outputs]), np.asarray(want),
            rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------ LRU / eviction
def test_bank_eviction_is_lru_and_reuses_slots(kernels):
    bank = ContextBank(capacity=2)
    s_a = bank.load(kernels["chebyshev"])
    s_b = bank.load(kernels["poly5"])
    assert bank.resident == ("chebyshev", "poly5")
    # touch chebyshev so poly5 becomes LRU
    assert bank.load(kernels["chebyshev"]) == s_a
    s_c = bank.load(kernels["poly6"])       # evicts poly5, reuses its slot
    assert s_c == s_b
    assert "poly5" not in bank and bank.n_evictions == 1
    assert bank.resident == ("chebyshev", "poly6")
    # reloading the evicted kernel evicts the (new) LRU = chebyshev
    s_d = bank.load(kernels["poly5"])
    assert s_d == s_a and bank.n_evictions == 2
    assert bank.resident == ("poly6", "poly5")


def test_bank_eviction_keeps_results_correct(kernels):
    """After an evict + reload cycle the served numerics stay oracle-exact."""
    ov = Overlay()
    bank = ContextBank(capacity=2)
    for round_names in (("chebyshev", "poly5"), ("poly6", "gradient"),
                        ("chebyshev", "poly6")):
        reqs = _requests(kernels, round_names, [128, 64], seed=11)
        _check_against_oracle(reqs, ov.dispatch(bank, reqs))
    assert bank.n_evictions >= 2


def test_bank_capacity_and_output_guards(kernels):
    with pytest.raises(BankError):
        ContextBank(capacity=0)
    bank = ContextBank(capacity=1, max_outputs=0)
    with pytest.raises(BankError):
        bank.load(kernels["chebyshev"])
    ov = Overlay()
    small = ov.load_many([kernels["chebyshev"], kernels["poly5"]],
                         capacity=2)
    reqs = _requests(kernels, ("chebyshev", "poly5", "poly6"),
                     [64, 64, 64])
    with pytest.raises(BankError):
        ov.dispatch(small, reqs)            # 3 kernels > capacity 2


def test_same_name_different_program_are_distinct_tenants():
    """Residency keys on context CONTENT: a name collision must never serve
    the wrong program."""
    k_add = compile_program(build_dfg("same", ["x"], "y = x + x", ["y"]))
    k_mul = compile_program(build_dfg("same", ["x"], "y = x * x", ["y"]))
    assert context_key(k_add) != context_key(k_mul)
    ov = Overlay()
    bank = ContextBank(capacity=4)
    xs = [np.full(64, 3.0, np.float32)]
    outs = ov.dispatch(bank, [(k_add, xs), (k_mul, xs)])
    np.testing.assert_array_equal(np.asarray(outs[0][0]), np.full(64, 6.0))
    np.testing.assert_array_equal(np.asarray(outs[1][0]), np.full(64, 9.0))
    assert len(bank) == 2 and bank.resident == ("same", "same")
    # content-identical reload is still a hit, not a new tenant
    assert bank.load(k_add) == bank.load(compile_program(
        build_dfg("same", ["x"], "y = x + x", ["y"])))


def test_dispatch_zero_length_requests(kernels):
    """Degenerate empty batches must not crash the dispatcher."""
    ov = Overlay()
    bank = ContextBank(capacity=2)
    k = kernels["chebyshev"]
    empty = [np.zeros(0, np.float32)]
    outs = ov.dispatch(bank, [(k, empty)])
    assert [y.shape for y in outs[0]] == [(0,)]
    # mixed empty + non-empty
    xs = [np.ones(64, np.float32)]
    p5 = kernels["poly5"]
    p5_empty = [np.zeros(0, np.float32) for _ in p5.dfg.inputs]
    outs = ov.dispatch(bank, [(k, empty), (k, xs), (p5, p5_empty)])
    assert outs[0][0].shape == (0,) and outs[2][0].shape == (0,)
    assert outs[1][0].shape == (64,)


def test_eviction_reload_uses_encode_cache(kernels):
    bank = ContextBank(capacity=1)
    bank.load(kernels["chebyshev"])
    bank.load(kernels["poly5"])          # evicts chebyshev
    assert "chebyshev" not in bank
    bank.load(kernels["chebyshev"])      # reload: pure device write
    assert bank.n_evictions == 2
    assert set(k[0] for k in bank._ctx_cache) == {"chebyshev", "poly5"}


# ------------------------------------------------------------- OverlayServer
def test_server_round_robins_working_set_larger_than_bank(kernels):
    srv = OverlayServer(bank_capacity=3)
    rng = np.random.RandomState(13)
    tickets = {}
    for i in range(18):                     # 9 kernels x 2 requests
        k = kernels[ALL_NAMES[i % len(ALL_NAMES)]]
        xs = [rng.uniform(-2, 2, (96,)).astype(np.float32)
              for _ in k.dfg.inputs]
        tickets[srv.submit(k, xs)] = (k, xs)
    results = srv.flush()
    assert srv.pending == 0
    assert set(results) == set(tickets)
    assert srv.n_rounds == 3                # ceil(9 kernels / bank 3)
    assert srv.bank.n_evictions >= 9 - 3
    for t, (k, xs) in tickets.items():
        _check_against_oracle([(k, xs)], [results[t]])


def test_server_stats_and_empty_flush():
    srv = OverlayServer(bank_capacity=2)
    assert srv.flush() == {}
    st = srv.stats()
    assert st["requests"] == 0 and st["capacity"] == 2
