"""Zero-copy round pipeline tests (PR 9).

Three layers of defence around host-buffer reuse — precisely the kind of
optimisation that silently corrupts a delivered-but-unclaimed result:

* RoundArena unit behaviour: bucketed recycling, dirty-row scrubbing,
  free-list caps, leak-visible counters.
* Bit parity: the single-pass scatter ``assemble`` and the live-rows
  ``collect`` must reproduce the seed's ``assemble_reference`` /
  ``collect_reference`` buffers EXACTLY, with and without recycled
  (previously dirtied) blocks, donation on and off, both backends.
* Aliasing safety under the engines: results delivered from round N stay
  bit-stable (deep-compared snapshots) while rounds N+1..N+k reuse the
  arena — across all three round policies, pipelined flush, and the
  autoscale grow/drain path.
"""

import numpy as np
import pytest

from repro.core.arena import RoundArena
from repro.core.overlay import Overlay, compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.launch.serve import OverlayServer, ShardedOverlayServer

ALL_NAMES = BENCH_NAMES + ("gradient",)


@pytest.fixture(scope="module")
def kernels():
    return {n: compile_program(benchmark(n)) for n in ALL_NAMES}


def _xs(kernel, batch, seed):
    rng = np.random.RandomState(seed)
    return [rng.uniform(-2, 2, (batch,)).astype(np.float32)
            for _ in kernel.dfg.inputs]


def _requests(kernels, n, seed, batch_pool=(17, 48, 64, 96, 200)):
    rng = np.random.RandomState(seed)
    names = list(kernels)
    out = []
    for i in range(n):
        k = kernels[names[i % len(names)]]
        out.append((k, _xs(k, int(rng.choice(batch_pool)), seed * 997 + i)))
    return out


# ============================================================ arena units
def test_checkout_recycle_reuses_block():
    a = RoundArena()
    b1 = a.checkout(8, 128, np.float32)
    assert b1.x.shape == (8, 32, 128) and b1.ids.shape == (8,)
    a.recycle(b1)
    b2 = a.checkout(8, 128, np.float32)
    assert b2 is b1                      # same pooled block, no realloc
    s = a.stats()
    assert s["allocations"] == 1 and s["checkouts"] == 2
    assert s["recycles"] == 1 and s["outstanding"] == 1


def test_distinct_buckets_do_not_share():
    a = RoundArena()
    b1 = a.checkout(8, 128, np.float32)
    a.recycle(b1)
    assert a.checkout(16, 128, np.float32) is not b1
    assert a.checkout(8, 256, np.float32) is not b1
    assert a.checkout(8, 128, np.float64) is not b1
    assert a.stats()["allocations"] == 4


def test_recycled_block_is_scrubbed_to_zeros():
    a = RoundArena()
    b = a.checkout(4, 128, np.float32)
    b.x[:, :5, :] = 7.0                  # a round dirties rows [0, 5)
    b.dirty_rows = 5
    b.ids[:] = 3
    a.recycle(b)
    b2 = a.checkout(4, 128, np.float32)
    assert b2 is b
    assert not b2.x.any()                # bit-identical to fresh zeros
    assert b2.dirty_rows == 0            # ids need no scrub: assemble
    # fully overwrites them every round


def test_scrub_honors_high_water_mark_only():
    a = RoundArena()
    b = a.checkout(4, 128, np.float32)
    b.dirty_rows = 2
    # simulate an out-of-contract write ABOVE the declared mark: scrub
    # must not be expected to clean it (documents the invariant)
    b.x[:, 3, :] = 9.0
    a.recycle(b)
    b2 = a.checkout(4, 128, np.float32)
    assert b2.x[:, 3, :].any()           # row 3 was never declared dirty


def test_free_list_cap_discards_excess():
    a = RoundArena(max_free_per_bucket=1)
    b1 = a.checkout(4, 128, np.float32)
    b2 = a.checkout(4, 128, np.float32)
    a.recycle(b1)
    a.recycle(b2)
    s = a.stats()
    assert s["recycles"] == 1 and s["discards"] == 1
    assert s["free_blocks"] == 1 and s["outstanding"] == 0


def test_recycle_none_is_noop():
    a = RoundArena()
    a.recycle(None)
    assert a.stats()["outstanding"] == 0


def test_outstanding_counts_leaks():
    a = RoundArena()
    a.checkout(4, 128, np.float32)
    a.checkout(4, 128, np.float32)
    s = a.stats()
    assert s["outstanding"] == 2 and s["peak_outstanding"] == 2
    assert s["pooled_bytes"] == 0


# ====================================================== bitwise stage parity
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("donate", [False, True])
def test_arena_pipeline_bitwise_matches_reference(kernels, backend, donate):
    ov = Overlay(backend=backend, arena=RoundArena(), donate=donate)
    ref = Overlay(backend=backend)
    bank = ov.load_many(kernels.values(), capacity=len(kernels))
    for seed in range(3):                # round 2+ exercises recycled blocks
        reqs = _requests(kernels, 10, seed=seed)
        p = ov.plan(bank, reqs, pin=True)
        batch = ov.assemble(p)
        p_ref = ref.plan(bank, reqs)
        batch_ref = ref.assemble_reference(p_ref)
        np.testing.assert_array_equal(np.asarray(batch[0]),
                                      np.asarray(batch_ref[0]))
        np.testing.assert_array_equal(np.asarray(batch[1]),
                                      np.asarray(batch_ref[1]))
        ys = ov.execute(bank, batch)
        ys_ref = ref.execute(bank, batch_ref)
        got = ov.collect(p, ys, host=True)
        want = ref.collect_reference(p_ref, ys_ref, host=True)
        lazy = ref.collect_reference(p_ref, ys_ref, host=False)
        for g, w, l in zip(got, want, lazy):
            for a, b, c in zip(g, w, l):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
                np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
        p.release(bank)
    s = ov.arena.stats()
    assert s["outstanding"] == 0 and s["recycles"] >= 2


def test_dispatch_recycles_its_block(kernels):
    ov = Overlay(arena=RoundArena())
    bank = ov.load_many(kernels.values(), capacity=len(kernels))
    ref = Overlay()
    for seed in range(2):
        reqs = _requests(kernels, 6, seed=seed)
        got = ov.dispatch(bank, reqs)
        want = ref.dispatch(bank, reqs)
        for g, w in zip(got, want):
            for a, b in zip(g, w):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s = ov.arena.stats()
    assert s["outstanding"] == 0         # the sync oracle must not leak


def test_empty_round_skips_arena(kernels):
    ov = Overlay(arena=RoundArena())
    bank = ov.load_many(kernels.values(), capacity=len(kernels))
    k = kernels["poly5"]
    p = ov.plan(bank, [(k, [np.zeros(0, np.float32)
                            for _ in k.dfg.inputs])])
    assert ov.assemble(p) is None
    assert ov.arena.stats()["checkouts"] == 0
    outs = ov.collect(p, None)
    assert outs[0][0].shape == (0,)


def test_assemble_reassembly_does_not_leak(kernels):
    ov = Overlay(arena=RoundArena())
    bank = ov.load_many(kernels.values(), capacity=len(kernels))
    p = ov.plan(bank, _requests(kernels, 4, seed=0))
    ov.assemble(p)
    ov.assemble(p)                       # re-assembled plan recycles first
    assert ov.arena.stats()["outstanding"] == 1
    p.release(bank)
    assert ov.arena.stats()["outstanding"] == 0


# =========================================================== device routing
def test_assemble_places_on_device_execute_skips_put(kernels, monkeypatch):
    """The redundant per-round ``device_put`` in execute is gone: a batch
    assembled by a device-pinned overlay is already resident."""
    import jax

    from repro.core import overlay as overlay_mod
    dev = jax.devices()[0]
    ov = Overlay(device=dev, arena=RoundArena())
    bank = ov.load_many(kernels.values(), capacity=len(kernels))
    p = ov.plan(bank, _requests(kernels, 4, seed=1))
    batch = ov.assemble(p)
    assert batch[0].sharding.device_set == {dev}
    assert batch[1].sharding.device_set == {dev}
    calls = []
    orig = jax.device_put
    monkeypatch.setattr(overlay_mod.jax, "device_put",
                        lambda *a, **kw: calls.append(a) or orig(*a, **kw))
    ys = ov.execute(bank, batch)
    assert calls == []                   # no placement on the hot path
    assert ys is not None
    p.release(bank)


def test_execute_still_places_foreign_batches(kernels):
    """A batch built off-device (e.g. by a plain overlay) must still be
    co-located with the bank — the skip is residency-aware, not blind."""
    import jax
    dev = jax.devices()[0]
    plain = Overlay()                    # no device pin: default placement
    ov = Overlay(device=dev)
    bank = ov.load_many(kernels.values(), capacity=len(kernels))
    p = plain.plan(bank, _requests(kernels, 4, seed=2))
    batch = plain.assemble_reference(p)
    ys = ov.execute(bank, batch)         # must not raise a placement error
    assert np.asarray(ys).shape[0] == p.g_pad


# ======================================================== engine integration
def test_engine_stats_expose_arena_and_stage_walls(kernels):
    srv = OverlayServer(bank_capacity=8)
    for i in range(6):
        k = kernels[list(kernels)[i % len(kernels)]]
        srv.submit(k, _xs(k, 64, i))
    srv.flush()
    s = srv.stats()
    assert s["arena"] is not None
    assert s["arena"]["checkouts"] > 0
    assert s["arena"]["outstanding"] == 0          # all rounds retired
    walls = s["stage_walls"]
    assert set(walls) == {"plan_s", "assemble_s", "execute_s", "collect_s"}
    assert walls["assemble_s"] > 0 and walls["collect_s"] > 0


def test_unattached_bank_reports_arena_none():
    from repro.core.bank import ContextBank
    assert ContextBank(2).stats()["arena"] is None


# ================================================= aliasing-safety property
@pytest.mark.parametrize("policy", ["drr", "coalesce", "dynamic"])
def test_round_n_results_bitstable_while_arena_reused(kernels, policy):
    """Results delivered from round N are deep-snapshot-stable while
    rounds N+1..N+k check the same arena blocks back out — across all
    three round policies, with the pipelined flush path live."""
    srv = OverlayServer(bank_capacity=8, round_policy=policy,
                        max_inflight=2, round_kernels=4)
    oracle = OverlayServer(bank_capacity=16)
    names = list(kernels)
    rng = np.random.RandomState(42)
    snapshots = {}
    live = {}
    for wave in range(5):
        pairs = []
        for i in range(8):
            k = kernels[names[int(rng.randint(len(names)))]]
            xs = _xs(k, int(rng.choice((48, 96, 130))), wave * 100 + i)
            pairs.append((srv.submit(k, xs), oracle.submit(k, xs)))
        got, want = srv.flush(), oracle.flush_sync()
        for gt, ot in pairs:
            ys = got[gt]
            live[gt] = ys                          # keep the views alive
            snapshots[gt] = ([np.array(y, copy=True) for y in ys],
                             [np.asarray(w) for w in want[ot]])
        # every PREVIOUS wave's delivered views must still hold the
        # bytes they held at delivery (and the oracle's bytes)
        for t, (snap, orc) in snapshots.items():
            for y, s, w in zip(live[t], snap, orc):
                np.testing.assert_array_equal(np.asarray(y), s)
                np.testing.assert_array_equal(np.asarray(y), w)
    assert srv.stats()["arena"]["recycles"] > 0    # reuse actually happened
    assert srv.stats()["arena"]["outstanding"] == 0


def test_results_bitstable_across_autoscale_grow_drain(kernels):
    """The grow/drain path must not disturb delivered bytes either: the
    drained replica's in-flight rounds retire through the same
    release/recycle protocol, and new replicas get their own arenas."""
    srv = ShardedOverlayServer(n_replicas=1, bank_capacity=6,
                               round_kernels=3, max_inflight=2)
    oracle = OverlayServer(bank_capacity=16)
    names = list(kernels)
    rng = np.random.RandomState(7)

    def submit_wave(n, seed):
        pairs = []
        for i in range(n):
            k = kernels[names[i % len(names)]]
            xs = _xs(k, int(rng.choice((48, 64, 96))), seed * 1000 + i)
            pairs.append((srv.submit(k, xs), oracle.submit(k, xs)))
        return pairs

    snapshots = {}
    live = {}

    def deliver_and_check(pairs):
        got, want = srv.flush(), oracle.flush_sync()
        for gt, ot in pairs:
            live[gt] = got[gt]
            snapshots[gt] = ([np.array(y, copy=True) for y in got[gt]],
                             [np.asarray(w) for w in want[ot]])
        for t, (snap, orc) in snapshots.items():
            for y, s, w in zip(live[t], snap, orc):
                np.testing.assert_array_equal(np.asarray(y), s)
                np.testing.assert_array_equal(np.asarray(y), w)

    deliver_and_check(submit_wave(10, seed=1))
    srv.add_replica()                              # grow under live results
    deliver_and_check(submit_wave(12, seed=2))
    # launch rounds so the drain path walks in-flight retirement
    pairs = submit_wave(12, seed=3)
    for rep in srv.replicas:
        rep._fill_pipeline()
    srv.drain_replica(0)                           # drain under live results
    deliver_and_check(pairs)
    for bank in srv.banks:
        arena = bank.stats()["arena"]
        assert arena is not None and arena["outstanding"] == 0
