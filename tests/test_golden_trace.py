"""Golden test: Schedule.cycle_trace reproduces the paper's Table I exactly.

The gradient kernel (Fig. 1 / Table I) on the 4-FU linear overlay: II = 11,
FU0 streams 5 loads then issues its 4 SUBs; FU1's first load lands
DSP_LATENCY-1 cycles after FU0's first arithmetic issue (the DSP48E1's
3-stage internal pipeline); every subsequent iteration repeats with period
II.  The first steady-state iteration is frozen line-by-line below.
"""

from repro.core.paper_bench import gradient
from repro.core.schedule import DSP_LATENCY, schedule

#: one full iteration of Table I — (cycle, {fu_index: activity})
GOLDEN_ITER1 = [
    (1, {0: "Load R0"}),
    (2, {0: "Load R1"}),
    (3, {0: "Load R2"}),
    (4, {0: "Load R3"}),
    (5, {0: "Load R4"}),
    (6, {0: "SUB (R0 R2)"}),
    (7, {0: "SUB (R1 R2)"}),
    (8, {0: "SUB (R2 R3)", 1: "Load R0"}),
    (9, {0: "SUB (R2 R4)", 1: "Load R1"}),
    (10, {1: "Load R2"}),
    (11, {1: "Load R3"}),
    (12, {1: "SQR (R0 R0)"}),
    (13, {1: "SQR (R1 R1)"}),
    (14, {1: "SQR (R2 R2)", 2: "Load R0"}),
    (15, {1: "SQR (R3 R3)", 2: "Load R1"}),
    (16, {2: "Load R2"}),
    (17, {2: "Load R3"}),
    (18, {2: "ADD (R0 R1)"}),
    (19, {2: "ADD (R2 R3)"}),
    (20, {3: "Load R0"}),
    (21, {3: "Load R1"}),
    (22, {3: "ADD (R0 R1)"}),
]


def test_gradient_trace_matches_golden_line_by_line():
    sch = schedule(gradient())
    assert sch.ii == 11
    got = sch.cycle_trace(n_iters=1)
    assert len(got) == len(GOLDEN_ITER1)
    for (gc, gacts), (wc, wacts) in zip(got, GOLDEN_ITER1):
        assert gc == wc, f"cycle numbering diverges at {gc} vs {wc}"
        assert gacts == wacts, f"cycle {gc}: {gacts} != {wacts}"


def test_fu1_first_load_at_dsp_latency_offset():
    """FU1 starts loading DSP_LATENCY-1 cycles after FU0's first issue."""
    sch = schedule(gradient())
    rows = dict(sch.cycle_trace(n_iters=1))
    fu0_first_issue = min(c for c, a in rows.items()
                          if 0 in a and not a[0].startswith("Load"))
    fu1_first_load = min(c for c, a in rows.items() if 1 in a)
    assert fu0_first_issue == 6                    # 5 loads then first SUB
    assert fu1_first_load == fu0_first_issue + DSP_LATENCY - 1 == 8


def test_trace_is_periodic_with_ii():
    sch = schedule(gradient())
    rows = dict(sch.cycle_trace(n_iters=3))
    ii = sch.ii
    for c, acts in GOLDEN_ITER1:
        for k in (1, 2):
            shifted = rows.get(c + k * ii, {})
            for fu, act in acts.items():
                assert shifted.get(fu) == act, (c, k, fu)
