"""Elastic fleet autoscaling: policy units + a chaos/soak differential
harness.

Three layers, mirroring the other differential suites:

* POLICY UNITS — ``PressureAutoscaler`` driven against a fake fleet with
  an injectable clock: hysteresis streaks, cooldown, min/max bounds,
  idle-streak bookkeeping across fleet mutation.
* ENGINE — ``ShardedOverlayServer.add_replica``/``drain_replica`` under
  live traffic: loss-free evacuation (bit parity vs the single-bank
  oracle), orphaned-result claims through every delivery path, directory
  hygiene (no entry ever resolves to a decommissioned replica —
  generation-validated fallback regression), pin safety, telemetry.
* CHAOS/SOAK — a seeded random scenario driver interleaving bursty
  submits, every drain flavour, and forced grow/drain calls with the
  autoscaler live, asserting ticket-by-ticket bit parity, full delivery,
  directory validity, and that pinned contexts are never evicted
  mid-flight.
"""

import numpy as np
import pytest

from repro.core.bank import BankDirectory, BankError, ContextBank
from repro.core.overlay import compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.launch.serve import OverlayServer, ShardedOverlayServer
from repro.sched import AutoPump, AutoscalePolicy, PressureAutoscaler

ALL_NAMES = BENCH_NAMES + ("gradient",)


@pytest.fixture(scope="module")
def kernels():
    return {n: compile_program(benchmark(n)) for n in ALL_NAMES}


def _xs(kernel, batch, seed):
    rng = np.random.RandomState(seed)
    return [rng.uniform(-2, 2, (batch,)).astype(np.float32)
            for _ in kernel.dfg.inputs]


# ======================================================= policy unit tests
class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


class FakeReplica:
    def __init__(self, queued_tiles=0, pending_tiles=0):
        self.queued_tiles = queued_tiles
        self.pending_tiles = pending_tiles


class FakeFleet:
    def __init__(self, *replicas):
        self.replicas = list(replicas)


def _hot(n=1, tiles=100):
    return FakeFleet(*(FakeReplica(queued_tiles=tiles, pending_tiles=tiles)
                       for _ in range(n)))


def _idle(n=1):
    return FakeFleet(*(FakeReplica() for _ in range(n)))


def test_autoscaler_is_policy_protocol():
    assert isinstance(PressureAutoscaler(), AutoscalePolicy)


def test_no_action_below_threshold():
    auto = PressureAutoscaler(up_tiles=50, up_rounds=1, clock=FakeClock())
    fleet = _hot(1, tiles=10)
    for _ in range(5):
        assert auto.observe(fleet) == []


def test_up_after_exactly_up_rounds():
    auto = PressureAutoscaler(up_tiles=8, up_rounds=3, clock=FakeClock())
    fleet = _hot(1, tiles=100)
    assert auto.observe(fleet) == []
    assert auto.observe(fleet) == []
    assert auto.observe(fleet) == [("up", None)]
    assert auto.n_up_decisions == 1


def test_hot_streak_resets_on_cool_observation():
    auto = PressureAutoscaler(up_tiles=8, up_rounds=2, clock=FakeClock())
    assert auto.observe(_hot(1)) == []
    assert auto.observe(_idle(1)) == []        # streak broken
    assert auto.observe(_hot(1)) == []         # streak restarts at 1
    assert auto.observe(_hot(1)) == [("up", None)]


def test_pressure_is_mean_per_replica():
    """The same backlog spread over more replicas is less pressure."""
    auto = PressureAutoscaler(up_tiles=50, up_rounds=1, clock=FakeClock())
    # 120 queued tiles over 4 replicas = 30/replica < 50: no action
    fleet = FakeFleet(*(FakeReplica(queued_tiles=30, pending_tiles=30)
                        for _ in range(4)))
    assert auto.observe(fleet) == []
    # the same 120 tiles on 2 replicas = 60/replica: up
    fleet2 = FakeFleet(*(FakeReplica(queued_tiles=60, pending_tiles=60)
                         for _ in range(2)))
    assert auto.observe(fleet2) == [("up", None)]


def test_max_replicas_bound_blocks_up():
    auto = PressureAutoscaler(up_tiles=8, up_rounds=1, max_replicas=2,
                              clock=FakeClock())
    assert auto.observe(_hot(2)) == []
    assert auto.n_up_decisions == 0


def test_cooldown_blocks_then_releases():
    clock = FakeClock()
    auto = PressureAutoscaler(up_tiles=8, up_rounds=1, cooldown_s=10.0,
                              clock=clock)
    assert auto.observe(_hot(1)) == [("up", None)]
    clock.t = 5.0
    assert auto.observe(_hot(1)) == []         # inside cooldown
    clock.t = 10.0
    assert auto.observe(_hot(1)) == [("up", None)]


def test_evidence_accrues_during_cooldown():
    """Cooldown gates ACTIONS, not streaks: pressure observed during the
    cooldown counts, so the action fires the moment the timer clears."""
    clock = FakeClock()
    auto = PressureAutoscaler(up_tiles=8, up_rounds=3, cooldown_s=10.0,
                              clock=clock)
    auto._last_action = 0.0                    # just acted
    fleet = _hot(1)
    clock.t = 1.0
    for _ in range(3):
        assert auto.observe(fleet) == []       # streak builds under cooldown
    clock.t = 10.0
    assert auto.observe(fleet) == [("up", None)]


def test_down_after_down_rounds_idle():
    auto = PressureAutoscaler(down_rounds=3, clock=FakeClock())
    fleet = _idle(2)
    assert auto.observe(fleet) == []
    assert auto.observe(fleet) == []
    acts = auto.observe(fleet)
    assert acts and acts[0][0] == "down" and acts[0][1] in (0, 1)
    assert auto.n_down_decisions == 1


def test_idle_streak_resets_when_replica_gets_work():
    auto = PressureAutoscaler(down_rounds=3, clock=FakeClock())
    rep_idle, rep_busy = FakeReplica(), FakeReplica(pending_tiles=5)
    fleet = FakeFleet(rep_idle, rep_busy)
    assert auto.observe(fleet) == []           # idle: 1
    assert auto.observe(fleet) == []           # idle: 2
    rep_idle.pending_tiles = 4                 # work lands on it
    assert auto.observe(fleet) == []           # idle: 0 (reset)
    rep_idle.pending_tiles = 0
    assert auto.observe(fleet) == []           # idle: 1 again
    assert auto.observe(fleet) == []           # idle: 2
    assert auto.observe(fleet) == [("down", 0)]


def test_min_replicas_bound_blocks_down():
    auto = PressureAutoscaler(down_rounds=1, min_replicas=2,
                              clock=FakeClock())
    fleet = _idle(2)
    for _ in range(5):
        assert auto.observe(fleet) == []
    assert auto.n_down_decisions == 0


def test_longest_idle_replica_drains_first():
    clock = FakeClock()
    auto = PressureAutoscaler(down_rounds=2, cooldown_s=10.0, clock=clock)
    auto._last_action = 0.0                    # hold actions under cooldown
    young, old = FakeReplica(pending_tiles=5), FakeReplica()
    fleet = FakeFleet(young, old)
    auto.observe(fleet)                        # old: idle 1
    young.pending_tiles = 0
    auto.observe(fleet)                        # old: 2, young: 1
    auto.observe(fleet)                        # old: 3, young: 2 (both ripe)
    clock.t = 10.0
    assert auto.observe(fleet) == [("down", 1)]   # old's streak is longer


def test_up_takes_precedence_over_down():
    """A hot fleet with one idle replica grows first — the pressure is
    fleet-wide, the idle replica is about to get fed."""
    auto = PressureAutoscaler(up_tiles=8, up_rounds=1, down_rounds=1,
                              clock=FakeClock())
    fleet = FakeFleet(FakeReplica(queued_tiles=100, pending_tiles=100),
                      FakeReplica())
    assert auto.observe(fleet) == [("up", None)]


def test_idle_bookkeeping_keyed_by_object_not_index():
    """After a drain compacts indices, another replica must not inherit
    the drained replica's idle streak."""
    auto = PressureAutoscaler(down_rounds=3, min_replicas=1,
                              clock=FakeClock())
    a, b = FakeReplica(), FakeReplica(pending_tiles=9)
    fleet = FakeFleet(a, b)
    auto.observe(fleet)
    auto.observe(fleet)                        # a: idle 2
    fleet.replicas = [b]                       # a decommissioned externally
    b.pending_tiles = 0
    assert auto.observe(fleet) == []           # b starts at 1, not a's 2+1
    assert a not in auto._idle


@pytest.mark.parametrize("kw", [
    dict(up_tiles=0), dict(up_tiles=-1), dict(up_rounds=0),
    dict(down_rounds=0), dict(cooldown_s=-0.1),
    dict(min_replicas=0), dict(min_replicas=3, max_replicas=2),
])
def test_invalid_knobs_raise(kw):
    with pytest.raises(ValueError):
        PressureAutoscaler(**kw)


def test_stats_and_reset():
    clock = FakeClock()
    auto = PressureAutoscaler(up_tiles=8, up_rounds=1, cooldown_s=5.0,
                              clock=clock)
    auto.observe(_hot(1))
    st = auto.stats()
    assert st["up_decisions"] == 1 and st["observations"] == 1
    assert st["max_replicas"] == 8 and st["autoscaler"] == "PressureAutoscaler"
    auto.reset_metrics()
    assert auto.n_up_decisions == 0 and auto.n_observations == 0
    # control state survives the reset: still inside cooldown
    clock.t = 1.0
    assert auto.observe(_hot(1)) == []


# ===================================================== bank/directory units
def test_bank_retire_clears_residency_and_bumps_generation(kernels):
    bank = ContextBank(4)
    k = kernels["poly5"]
    bank.load(k)
    gen = bank.generation
    bank.retire()
    assert bank.peek(k) is None and len(bank) == 0
    assert bank.generation == gen + 1
    assert bank.stats()["free"] == 4


def test_bank_retire_refuses_pinned(kernels):
    bank = ContextBank(2)
    bank.pin(kernels["poly5"])
    with pytest.raises(BankError, match="pinned"):
        bank.retire()
    bank.unpin(kernels["poly5"])
    bank.retire()


def test_directory_remove_replica_drops_and_renumbers(kernels):
    banks = [ContextBank(4) for _ in range(3)]
    d = BankDirectory()
    ka, kb, kc = (kernels[n] for n in ("poly5", "qspline", "chebyshev"))
    for k, rep in ((ka, 0), (kb, 1), (kc, 2)):
        banks[rep].load(k)
        d.publish_current(k, rep, banks[rep])
    assert d.remove_replica(1) == 1
    assert d.n_unpublished == 1 and len(d) == 2
    banks.pop(1)
    # survivor entries renumbered to keep pointing at the SAME bank
    assert d.locate(ka, banks) == 0
    assert d.locate(kc, banks) == 1
    assert d.locate(kb, banks) is None         # unpublished -> miss path


def test_generation_validated_fallback_after_retire(kernels):
    """REGRESSION: an entry that escapes the unpublish (stale fleet view)
    must fail generation validation against the retired bank and fall
    back, never resolve to a decommissioned replica."""
    banks = [ContextBank(4), ContextBank(4)]
    d = BankDirectory()
    k = kernels["poly5"]
    banks[1].load(k)
    d.publish_current(k, 1, banks[1])
    banks[1].retire()                          # drain forgot to unpublish
    n_stale0 = d.n_stale
    assert d.locate(k, banks) is None
    assert d.n_stale == n_stale0 + 1
    assert len(d) == 0                         # stale entry dropped


# ========================================================== engine: grow
def _mixed_submit(srv, oracle, kernels, n, seed=0, batch_pool=(48, 64, 96)):
    rng = np.random.RandomState(seed)
    names = list(kernels)
    pairs = []
    for i in range(n):
        k = kernels[names[i % len(names)]]
        xs = _xs(k, int(rng.choice(batch_pool)), seed * 1000 + i)
        t = f"tenant{i % 3}"
        pairs.append((srv.submit(k, xs, tenant=t),
                      oracle.submit(k, xs, tenant=t)))
    return pairs


def _assert_parity(pairs, got, want):
    assert set(got) >= {gt for gt, _ in pairs}
    for gt, ot in pairs:
        for y, w in zip(got[gt], want[ot]):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))


def _assert_directory_valid(srv):
    """No directory entry may point outside the live fleet — the
    acceptance bar's "never resolves to a decommissioned replica"
    invariant.  Entries staled by ordinary LRU eviction are legal (locate
    drops them and falls back), but an entry must never claim a
    generation its bank has not reached, and a VALIDATING entry must
    genuinely have its context resident at the published generation."""
    for key, ent in srv.directory._map.items():
        assert 0 <= ent.replica < srv.n_replicas, (key, ent)
        bank = srv.banks[ent.replica]
        assert ent.generation <= bank.generation, (key, ent)
        if key in bank._lru:
            resident_gen = bank._key_gen[key]
            assert resident_gen >= ent.generation, (key, ent, resident_gen)


def test_add_replica_grows_and_serves(kernels):
    srv = ShardedOverlayServer(n_replicas=1, bank_capacity=4,
                               round_kernels=2)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 12, seed=1)
    i = srv.add_replica()
    assert i == 1 and srv.n_replicas == 2 and len(srv.banks) == 2
    assert srv.n_scale_ups == 1
    pairs += _mixed_submit(srv, oracle, kernels, 12, seed=2)
    _assert_parity(pairs, srv.flush(), oracle.flush_sync())
    assert srv.pending == 0
    _assert_directory_valid(srv)


def test_add_replica_picks_least_shared_device(kernels, device_count):
    srv = ShardedOverlayServer(n_replicas=1, bank_capacity=4)
    added = [srv.devices[srv.add_replica()] for _ in range(3)]
    if device_count >= 4:
        # each newcomer lands on a fresh physical device before any wraps
        assert len({d.id for d in srv.devices}) == 4
    else:
        from repro.launch.mesh import device_sharing
        sharing = device_sharing(srv.devices)
        assert max(sharing.values()) - min(sharing.values()) <= 1, sharing
    assert len(added) == 3 and srv.n_replicas == 4


def test_new_replica_attracts_traffic_via_fallback(kernels):
    """A grown replica is not decorative: least-loaded fallback routes
    misses to it, and it ends up serving requests."""
    srv = ShardedOverlayServer(n_replicas=1, bank_capacity=4,
                               round_kernels=2)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 8, seed=3)
    srv.add_replica()
    pairs += _mixed_submit(srv, oracle, kernels, 24, seed=4)
    _assert_parity(pairs, srv.flush(), oracle.flush_sync())
    assert srv.replicas[1].n_requests > 0


# ========================================================= engine: drain
def test_drain_replica_loss_free_queued(kernels):
    """Every ticket queued on the drained replica is delivered with
    oracle-identical bytes."""
    srv = ShardedOverlayServer(n_replicas=3, bank_capacity=4,
                               round_kernels=2)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 30, seed=5)
    queued_before = sum(rep.queued for rep in srv.replicas)
    assert queued_before == 30
    info = srv.drain_replica(1)
    assert srv.n_replicas == 2 and srv.n_scale_downs == 1
    assert info["evacuated_requests"] > 0
    assert srv.n_evacuated_tiles == info["evacuated_tiles"] > 0
    assert sum(rep.queued for rep in srv.replicas) == 30  # nothing lost
    _assert_parity(pairs, srv.flush(), oracle.flush_sync())
    _assert_directory_valid(srv)


def test_drain_replica_with_inflight_rounds_orphans_results(kernels):
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=6,
                               round_kernels=2, max_inflight=2)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 16, seed=6)
    for rep in srv.replicas:
        rep._fill_pipeline()                   # launch rounds -> pins held
    assert any(rep._inflight for rep in srv.replicas)
    srv.drain_replica(0)
    assert srv.stats()["orphaned_results"] > 0
    _assert_parity(pairs, srv.flush(), oracle.flush_sync())
    assert srv.stats()["orphaned_results"] == 0
    for bank in srv.banks:
        assert bank.n_pinned == 0


def test_orphaned_results_claimable_via_result(kernels):
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=6)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 8, seed=7)
    for rep in srv.replicas:
        rep._fill_pipeline()
    srv.drain_replica(0)
    want = oracle.flush_sync()
    for gt, ot in pairs:
        ys = srv.result(gt)
        for y, w in zip(ys, want[ot]):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))
    assert srv.pending == 0


def test_orphaned_results_claimable_via_try_result_and_as_completed(kernels):
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=6)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 10, seed=8)
    for rep in srv.replicas:
        rep._fill_pipeline()
    while srv.replicas[0]._inflight:           # deliver into _done
        srv.replicas[0]._retire_oldest()
    srv.drain_replica(0)
    orphans = dict(srv._orphaned)
    assert orphans
    t0 = next(iter(orphans))
    out = srv.try_result(t0)
    assert out is not None
    got = dict(srv.as_completed())
    got[t0] = out
    _assert_parity(pairs, got, oracle.flush_sync())


def test_orphan_double_claim_raises(kernels):
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=6)
    k = kernels["poly5"]
    t = srv.submit(k, _xs(k, 64, 9))
    for rep in srv.replicas:
        rep._fill_pipeline()
    rep = srv.record(t)["replica"]
    srv.drain_replica(rep)
    srv.result(t)
    with pytest.raises(KeyError, match="already claimed"):
        srv.result(t)
    with pytest.raises(KeyError, match="already claimed"):
        srv.try_result(t)


def test_orphan_record_and_latency_survive_drain(kernels):
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=6)
    k = kernels["qspline"]
    t = srv.submit(k, _xs(k, 64, 10), tenant="alice")
    for rep in srv.replicas:
        rep._fill_pipeline()
    rep = srv.record(t)["replica"]
    srv.drain_replica(rep)
    rec = srv.record(t)
    assert rec["tenant"] == "alice" and rec["replica"] is None
    assert rec["t_done"] is not None
    assert t in srv.latencies()
    srv.result(t)


def test_drain_last_replica_raises(kernels):
    srv = ShardedOverlayServer(n_replicas=1, bank_capacity=4)
    with pytest.raises(ValueError, match="last replica"):
        srv.drain_replica(0)
    with pytest.raises(IndexError):
        srv.drain_replica(5)
    assert srv.n_replicas == 1


def test_drain_remaps_higher_replica_tickets(kernels):
    """Tickets owned by replicas ABOVE the drained index must survive the
    index compaction."""
    srv = ShardedOverlayServer(n_replicas=3, bank_capacity=6)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 18, seed=11)
    by_rep = {}
    for gt, _ in pairs:
        by_rep.setdefault(srv.record(gt)["replica"], []).append(gt)
    assert len(by_rep) >= 2                    # traffic actually spread
    victim = min(by_rep)                       # drain the LOWEST index
    srv.drain_replica(victim)
    want = oracle.flush_sync()
    got = {gt: srv.result(gt) for gt, _ in pairs}
    _assert_parity(pairs, got, want)


def test_drain_never_resolves_directory_to_dead_replica(kernels):
    """The acceptance bar: after any drain, no directory lookup may
    resolve to a decommissioned replica."""
    srv = ShardedOverlayServer(n_replicas=4, bank_capacity=4)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 24, seed=12)
    for _ in range(3):
        srv.drain_replica(srv.n_replicas - 1)
        _assert_directory_valid(srv)
        for k in kernels.values():
            owner = srv.directory.locate(k, srv.banks)
            assert owner is None or 0 <= owner < srv.n_replicas
    _assert_parity(pairs, srv.flush(), oracle.flush_sync())


def test_drain_pin_safety_probed_live(kernels):
    """While a drain evacuates around in-flight rounds elsewhere, pinned
    contexts must stay resident (eviction never touches them)."""
    srv = ShardedOverlayServer(n_replicas=3, bank_capacity=3,
                               round_kernels=2, max_inflight=2)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 27, seed=13)
    for rep in srv.replicas:
        rep._fill_pipeline()
    srv.drain_replica(0)
    for bank in srv.banks:
        for key in bank._pins:
            assert key in bank._lru, "pinned context evicted mid-flight"
    _assert_parity(pairs, srv.flush(), oracle.flush_sync())


def test_flush_sync_claims_orphans(kernels):
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=6)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 8, seed=14)
    for rep in srv.replicas:
        rep._fill_pipeline()
    srv.drain_replica(0)
    _assert_parity(pairs, srv.flush_sync(), oracle.flush_sync())
    assert srv.stats()["orphaned_results"] == 0


# ================================================= engine: autoscaler wired
def test_autoscaler_scales_up_during_flush(kernels):
    auto = PressureAutoscaler(up_tiles=2.0, up_rounds=1, down_rounds=10 ** 6,
                              max_replicas=4)
    srv = ShardedOverlayServer(n_replicas=1, bank_capacity=4,
                               round_kernels=2, steal=True, autoscaler=auto)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 36, seed=15)
    _assert_parity(pairs, srv.flush(), oracle.flush_sync())
    assert srv.n_scale_ups >= 1
    assert srv.n_replicas > 1
    st = srv.stats()
    assert st["scale_ups"] == srv.n_scale_ups
    assert st["up_decisions"] == auto.n_up_decisions


def test_autoscaler_scales_down_on_idle_pump_ticks(kernels):
    auto = PressureAutoscaler(up_tiles=10 ** 9, down_rounds=3,
                              min_replicas=1)
    srv = ShardedOverlayServer(n_replicas=3, bank_capacity=4,
                               autoscaler=auto)
    for _ in range(20):
        srv.pump_once()
    assert srv.n_replicas == 1
    assert srv.n_scale_downs == 2
    assert srv.stats()["replicas_retired"] == 2
    assert srv.stats()["retired_lifetime_s"] >= 0


def test_autoscaler_respects_min_during_as_completed(kernels):
    auto = PressureAutoscaler(up_tiles=10 ** 9, down_rounds=1,
                              min_replicas=2)
    srv = ShardedOverlayServer(n_replicas=3, bank_capacity=4,
                               autoscaler=auto)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 12, seed=16)
    got = dict(srv.as_completed())
    _assert_parity(pairs, got, oracle.flush_sync())
    assert srv.n_replicas >= 2


def test_autopump_background_scaling(kernels):
    """The AutoPump tick observes the autoscaler: a fleet left idle under
    a pump shrinks to min_replicas with no explicit drain call."""
    auto = PressureAutoscaler(up_tiles=10 ** 9, down_rounds=2,
                              min_replicas=1)
    srv = ShardedOverlayServer(n_replicas=3, bank_capacity=4,
                               autoscaler=auto)
    oracle = OverlayServer(bank_capacity=16)
    import time as _time
    with AutoPump(srv, poll_interval=0.002) as pump:
        k = kernels["poly5"]
        xs = _xs(k, 64, 17)
        t = pump.submit(k, xs)
        ot = oracle.submit(k, xs)
        got = pump.result(t, timeout=30)
        pump.wait_idle(timeout=30)
        # idle pump ticks (poll_interval cadence) must now shrink the
        # fleet to min_replicas with no explicit call from this thread
        deadline = 400
        while srv.n_replicas > 1 and deadline:
            _time.sleep(0.005)
            deadline -= 1
    for y, w in zip(got, oracle.flush_sync()[ot]):
        np.testing.assert_array_equal(np.asarray(y), np.asarray(w))
    assert srv.n_replicas == 1
    assert srv.n_scale_downs == 2


def test_flush_sync_never_scales(kernels):
    """The oracle drain must not mutate the fleet even with a trigger-
    happy autoscaler attached."""
    auto = PressureAutoscaler(up_tiles=0.001, up_rounds=1, down_rounds=1,
                              max_replicas=8)
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=4,
                               autoscaler=auto)
    oracle = OverlayServer(bank_capacity=16)
    pairs = _mixed_submit(srv, oracle, kernels, 12, seed=18)
    _assert_parity(pairs, srv.flush_sync(), oracle.flush_sync())
    assert srv.n_scale_ups == 0 and srv.n_scale_downs == 0
    assert srv.n_replicas == 2


# ============================================================= chaos/soak
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_chaos_soak_differential(kernels, seed):
    """The satellite harness: seeded random interleavings of bursty
    submits, every drain flavour, and forced grow/drain calls with the
    autoscaler live.  Invariants: ticket-by-ticket bit parity vs the
    single-bank oracle, every ticket delivered exactly once, no
    directory entry resolving off-fleet, pins never evicted mid-flight.
    """
    rng = np.random.RandomState(0xE1A5 + seed)
    names = list(kernels)
    auto = PressureAutoscaler(
        up_tiles=float(rng.choice([4.0, 16.0])),
        up_rounds=int(rng.choice([1, 2])),
        down_rounds=int(rng.choice([2, 4])),
        min_replicas=1, max_replicas=5)
    srv = ShardedOverlayServer(
        n_replicas=int(rng.choice([1, 2, 3])), bank_capacity=4,
        round_kernels=2, max_inflight=int(rng.choice([1, 2])),
        steal=bool(rng.rand() < 0.5), autoscaler=auto)
    oracle = OverlayServer(bank_capacity=16)
    pending: dict[int, int] = {}               # sharded ticket -> oracle's
    delivered: set = set()
    oracle_results: dict[int, list] = {}       # oracle outputs, kept across
                                               # partial sharded drains

    def probe():
        for bank in srv.banks:
            for key in bank._pins:
                assert key in bank._lru, "pinned context evicted"
        _assert_directory_valid(srv)

    def check(results):
        oracle_results.update(oracle.flush_sync())
        for t, ys in results.items():
            assert t not in delivered, "ticket delivered twice"
            delivered.add(t)
            ot = pending.pop(t)
            for y, w in zip(ys, oracle_results.pop(ot)):
                np.testing.assert_array_equal(np.asarray(y), np.asarray(w))

    for _step in range(40):
        action = rng.choice(
            ["submit", "burst", "drain", "result", "grow", "shrink"],
            p=[0.35, 0.15, 0.2, 0.1, 0.1, 0.1])
        if action == "submit" or action == "burst":
            for _ in range(1 if action == "submit" else int(rng.randint(4, 9))):
                k = kernels[names[rng.randint(len(names))]]
                xs = _xs(k, int(rng.choice([33, 64, 96])),
                         int(rng.randint(1 << 30)))
                t = srv.submit(k, xs, tenant=f"t{rng.randint(3)}")
                pending[t] = oracle.submit(k, xs, tenant=f"t{rng.randint(3)}")
        elif action == "drain" and pending:
            mode = rng.choice(["flush", "flush_sync", "as_completed"])
            if mode == "flush":
                check(srv.flush())
            elif mode == "flush_sync":
                check(srv.flush_sync())
            else:
                check(dict(srv.as_completed()))
            assert not pending, "a drain left tickets undelivered"
        elif action == "result" and pending:
            t = list(pending)[rng.randint(len(pending))]
            check({t: srv.result(t)})
        elif action == "grow" and srv.n_replicas < 6:
            srv.add_replica()
        elif action == "shrink" and srv.n_replicas > 1:
            srv.drain_replica(int(rng.randint(srv.n_replicas)))
        probe()
    # deterministic coverage per example: one forced grow + drain pair,
    # then a final drain must deliver everything
    srv.add_replica()
    srv.drain_replica(0)
    probe()
    check(srv.flush())
    assert not pending and srv.pending == 0
    assert srv.stats()["orphaned_results"] == 0
    for bank in srv.banks:
        assert bank.n_pinned == 0
    assert 1 <= srv.n_replicas <= 6
