"""TMFU Pallas kernel vs pure-jnp oracle: shape/dtype sweeps + benchmarks.

Kernels run in interpret mode on CPU (the TPU is the target, not the host);
the oracle is ref.py, cross-checked against the DFG evaluator.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.overlay import Overlay, compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.core.vm import dfg_eval, make_context, pad_inputs
from repro.kernels.tmfu import tmfu_pipeline, tmfu_ref
from repro.kernels.tmfu.ops import _imm_to_i32


def _ctx_and_inputs(name, batch, dtype, seed=0):
    dfg = benchmark(name)
    ctx = make_context(compile_program(dfg).program, dtype=dtype)
    rng = np.random.RandomState(seed)
    if jnp.issubdtype(dtype, jnp.integer):
        xs = [rng.randint(-6, 7, size=(batch,)).astype(np.int32)
              for _ in dfg.inputs]
    else:
        xs = [rng.uniform(-2, 2, (batch,)).astype(np.float32)
              for _ in dfg.inputs]
    x = pad_inputs([jnp.asarray(v, dtype) for v in xs])
    return dfg, ctx, xs, x


@pytest.mark.parametrize("name", BENCH_NAMES + ("gradient",))
def test_kernel_matches_ref_all_benchmarks(name):
    dfg, ctx, xs, x = _ctx_and_inputs(name, 256, jnp.float32)
    got = tmfu_pipeline(ctx, x, block_batch=128, interpret=True)
    ref_rf = tmfu_ref(np.asarray(ctx.op), np.asarray(ctx.src_a),
                      np.asarray(ctx.src_b), np.asarray(ctx.imm), x)
    want = ref_rf[np.asarray(ctx.out_idx)]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # and against the DFG semantics
    env = {n: jnp.asarray(v) for n, v in zip(dfg.inputs, xs)}
    oracle = dfg_eval(dfg, env)
    for j, o in enumerate(dfg.outputs):
        np.testing.assert_allclose(np.asarray(got[j]),
                                   np.asarray(oracle[o]),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("batch,block", [(128, 128), (384, 128),
                                         (1024, 512), (100, 128),
                                         (777, 256)])
def test_kernel_shape_sweep(batch, block):
    """Odd batches are padded up; results must match the oracle exactly."""
    dfg, ctx, xs, x = _ctx_and_inputs("poly6", batch, jnp.float32, seed=3)
    got = tmfu_pipeline(ctx, x, block_batch=block, interpret=True)
    env = {n: jnp.asarray(v) for n, v in zip(dfg.inputs, xs)}
    oracle = dfg_eval(dfg, env)
    np.testing.assert_allclose(np.asarray(got[0]),
                               np.asarray(oracle[dfg.outputs[0]]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_kernel_dtype_sweep(dtype):
    dfg, ctx, xs, x = _ctx_and_inputs("mibench", 256, dtype, seed=5)
    got = tmfu_pipeline(ctx, x, block_batch=128, interpret=True)
    ref_rf = tmfu_ref(np.asarray(ctx.op), np.asarray(ctx.src_a),
                      np.asarray(ctx.src_b), np.asarray(ctx.imm), x)
    want = ref_rf[np.asarray(ctx.out_idx)]
    if dtype == jnp.bfloat16:
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2, atol=2e-2)
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_backend_in_overlay():
    """Overlay(backend='pallas') must agree with the jnp VM backend."""
    dfg = benchmark("qspline")
    k = compile_program(dfg)
    rng = np.random.RandomState(11)
    xs = [rng.uniform(-1, 1, (128,)).astype(np.float32) for _ in dfg.inputs]
    ov_jnp = Overlay(backend="jnp")
    ov_pl = Overlay(backend="pallas")
    y1 = ov_jnp(ov_jnp.load(k), xs)
    y2 = ov_pl(ov_pl.load(k), xs)
    np.testing.assert_allclose(np.asarray(y1[0]), np.asarray(y2[0]),
                               rtol=1e-6)


def test_kernel_traces_and_interpret_lowers():
    """Structural check: abstract-eval/trace of the pallas_call succeeds and
    the interpret path lowers inside jit.

    Mosaic compilation itself requires real TPU hardware (the CPU backend
    rejects interpret=False outright), so grid/BlockSpec coherence is
    validated via tracing + the interpret executions above.
    """
    dfg, ctx, xs, x = _ctx_and_inputs("chebyshev", 1024, jnp.float32)
    from repro.kernels.tmfu.kernel import tmfu_pipeline_rf

    def f(op, a, b, imm, xx):
        return tmfu_pipeline_rf(op, a, b, imm, xx,
                                block_batch=512, interpret=True)

    args = (ctx.op, ctx.src_a, ctx.src_b, _imm_to_i32(ctx.imm), x)
    shape = jax.eval_shape(f, *args)
    assert shape.shape == (32, 1024)
    txt = jax.jit(f).lower(*args).as_text()
    assert "while" in txt or "func" in txt  # lowered module exists
