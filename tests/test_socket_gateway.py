"""Socket transport: handshake, register-once, error mapping, reclaim,
and the 4-seed loopback churn soak.

Everything runs over REAL sockets on 127.0.0.1 (ephemeral ports), in
four layers:

* PLUMBING — submit/await and ``flush_sync`` through a
  ``RemoteOverlayClient`` are bit-identical to the single-bank barrier
  oracle; kernels register ONCE server-wide (the second client's first
  submit ships only the key).
* PROTOCOL — a hello from another protocol generation is refused with a
  ``version`` error (and the client surfaces
  :class:`ProtocolVersionError`); unregistered keys, digest-mismatched
  registrations, and over-cap frames are rejected with typed error
  frames and counted as ``wire.rejects``.
* ERROR MAPPING — server-side ``GatewayOverloadedError`` (with its
  ``retry_after`` hint) and ``AdmissionError`` cross the wire and
  re-raise as the same exception types client-side.
* SOAK — 4 seeds of connect/drop/reclaim churn over an autoscaled
  sharded fleet with forced grow/drain: every ticket ever admitted is
  delivered exactly-or-at-least once (await, reclaim, or barrier),
  bit-identical to the oracle — zero ticket loss over a real wire.

Tests drive their own ``asyncio.run``; no async pytest plugin.
"""

import asyncio

import numpy as np
import pytest

from repro.core.overlay import compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.launch.gateway import GatewayOverloadedError, OverlayGateway
from repro.launch.serve import OverlayServer, ShardedOverlayServer
from repro.launch.socket_gateway import (OverlaySocketServer,
                                         RemoteOverlayClient, dfg_from_wire,
                                         dfg_to_wire)
from repro.launch.transport import (PROTOCOL_VERSION, ProtocolVersionError,
                                    read_frame, write_frame)
from repro.sched import AdmissionError, PressureAutoscaler

ALL_NAMES = BENCH_NAMES + ("gradient",)


@pytest.fixture(scope="module")
def kernels():
    return {n: compile_program(benchmark(n)) for n in ALL_NAMES}


def _xs(kernel, batch, seed):
    rng = np.random.RandomState(seed)
    return [rng.uniform(-2, 2, (batch,)).astype(np.float32)
            for _ in kernel.dfg.inputs]


def _assert_parity(pairs, got, want):
    assert set(got) >= {gt for gt, _ in pairs}
    for gt, ot in pairs:
        for y, w in zip(got[gt], want[ot]):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))


async def _hello(port, **over):
    """Open a raw connection and send a (possibly doctored) hello."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    msg = {"type": "hello", "proto": PROTOCOL_VERSION, "tenant": "raw",
           "session": None, "codecs": ["json"]}
    msg.update(over)
    await write_frame(writer, msg, "json")
    return reader, writer, await read_frame(reader)


# ============================================================== plumbing
def test_socket_submit_flush_parity_and_register_once(kernels):
    oracle = OverlayServer(bank_capacity=16)
    names = list(kernels)[:4]

    async def main():
        async with OverlaySocketServer.local(
                bank_capacity=8, poll_interval=0.001) as srv:
            pairs = []
            async with RemoteOverlayClient("127.0.0.1", srv.port,
                                           tenant="a") as c1:
                for i, n in enumerate(names * 2):
                    k = kernels[n]
                    xs = _xs(k, 64, i)
                    pairs.append((await c1.submit(k, xs),
                                  oracle.submit(k, xs)))
                got = await c1.flush_sync()
                assert not c1.outstanding
            regs_after_c1 = srv.stats()["wire_registers"]
            # second client reuses the server-wide registry: same kernels,
            # zero new registrations
            async with RemoteOverlayClient("127.0.0.1", srv.port,
                                           tenant="b") as c2:
                for i, n in enumerate(names):
                    k = kernels[n]
                    xs = _xs(k, 48, 100 + i)
                    pairs.append((await c2.submit(k, xs),
                                  oracle.submit(k, xs)))
                got.update(await c2.drain())
            st = srv.stats()
            assert st["wire_registers"] == regs_after_c1 == len(names)
            assert st["registered_kernels"] == len(names)
            assert st["wire_rejects"] == 0
            return got, pairs

    got, pairs = asyncio.run(main())
    _assert_parity(pairs, got, oracle.flush_sync())


def test_streaming_results_over_socket(kernels):
    k = kernels["chebyshev"]

    async def main():
        async with OverlaySocketServer.local(poll_interval=0.001) as srv:
            async with RemoteOverlayClient("127.0.0.1", srv.port) as c:
                tickets = [await c.submit(k, _xs(k, 64, i))
                           for i in range(6)]
                seen = [t async for t, _ in c.results()]
                assert sorted(seen) == sorted(tickets)
                assert not c.outstanding

    asyncio.run(main())


# ============================================================== protocol
def test_version_mismatch_refused():
    async def main():
        async with OverlaySocketServer.local() as srv:
            _, writer, resp = await _hello(srv.port, proto=99)
            assert resp["type"] == "error" and resp["kind"] == "version"
            assert "99" in resp["message"]
            writer.close()
            assert srv.stats()["wire_rejects"] == 1
            assert srv.stats()["wire_handshakes"] == 0

    asyncio.run(main())


def test_frame_level_version_mismatch_refused():
    from repro.launch import transport as tp

    async def main():
        async with OverlaySocketServer.local() as srv:
            reader, writer = await asyncio.open_connection("127.0.0.1",
                                                           srv.port)
            # a frame stamped with a future protocol generation
            writer.write(tp._HEADER.pack(tp.MAGIC, PROTOCOL_VERSION + 1,
                                         tp._CODEC_IDS["json"], 2) + b"{}")
            await writer.drain()
            resp = await read_frame(reader)
            assert resp["type"] == "error" and resp["kind"] == "version"
            writer.close()

    asyncio.run(main())


def test_client_raises_protocol_version_error():
    """A server-side version refusal surfaces client-side as the same
    exception type the codec uses locally."""

    async def refuse(reader, writer):
        await read_frame(reader)
        await write_frame(writer, {"type": "error", "kind": "version",
                                   "message": "server speaks v99"}, "json")
        writer.close()

    async def main():
        server = await asyncio.start_server(refuse, "127.0.0.1", 0)
        port = server.sockets[0].getsockname()[1]
        try:
            with pytest.raises(ProtocolVersionError, match="v99"):
                await RemoteOverlayClient("127.0.0.1", port).connect()
        finally:
            server.close()
            await server.wait_closed()

    asyncio.run(main())


def test_unregistered_key_rejected():
    async def main():
        async with OverlaySocketServer.local() as srv:
            reader, writer, welcome = await _hello(srv.port)
            assert welcome["type"] == "welcome"
            await write_frame(writer, {"type": "submit", "req": 0,
                                       "key": ["ghost", "0" * 40],
                                       "xs": []}, "json")
            resp = await read_frame(reader)
            assert resp["type"] == "error"
            assert resp["kind"] == "unregistered" and resp["req"] == 0
            writer.close()
            assert srv.stats()["wire_rejects"] == 1

    asyncio.run(main())


def test_digest_mismatch_registration_refused(kernels):
    k = kernels["chebyshev"]

    async def main():
        async with OverlaySocketServer.local() as srv:
            reader, writer, _ = await _hello(srv.port)
            await write_frame(writer, {
                "type": "register", "req": 7,
                "key": [k.dfg.name, "f" * 40],       # wrong digest
                "dfg": dfg_to_wire(k.dfg)}, "json")
            resp = await read_frame(reader)
            assert resp["type"] == "error"
            assert resp["kind"] == "key_mismatch" and resp["req"] == 7
            writer.close()
            st = srv.stats()
            assert st["wire_rejects"] == 1
            assert st["registered_kernels"] == 0    # nothing cached

    asyncio.run(main())


def test_oversized_frame_dropped():
    async def main():
        gw = OverlayGateway.local()
        async with gw:
            async with OverlaySocketServer(gw, max_frame_bytes=512) as srv:
                reader, writer, welcome = await _hello(srv.port)
                assert welcome["type"] == "welcome"
                await write_frame(writer, {"type": "submit", "req": 0,
                                           "key": ["k", "d"],
                                           "pad": "x" * 4096}, "json")
                resp = await read_frame(reader)
                assert resp["type"] == "error"
                assert resp["kind"] == "malformed"
                assert await read_frame(reader) is None     # dropped
                writer.close()
                assert srv.stats()["wire_rejects"] == 1

    asyncio.run(main())


def test_dfg_wire_roundtrip(kernels):
    for k in kernels.values():
        d2 = dfg_from_wire(dfg_to_wire(k.dfg))
        assert d2.name == k.dfg.name
        assert list(d2.inputs) == list(k.dfg.inputs)
        assert list(d2.outputs) == list(k.dfg.outputs)
        from repro.core.bank import context_key
        assert context_key(compile_program(d2)) == context_key(k)


# ========================================================== error mapping
def test_overload_shed_maps_with_retry_after(kernels):
    k = kernels["chebyshev"]

    async def main():
        async with OverlaySocketServer.local(
                max_fleet_tiles=1, overflow="shed",
                poll_interval=0.001) as srv:
            async with RemoteOverlayClient("127.0.0.1", srv.port) as c:
                sheds = 0
                for i in range(6):
                    try:
                        await c.submit(k, _xs(k, 512, i))    # 4 tiles
                    except GatewayOverloadedError as e:
                        sheds += 1
                        assert e.retry_after > 0
                assert sheds >= 1
                await c.flush_sync()

    asyncio.run(main())


def test_admission_error_maps(kernels):
    k = kernels["chebyshev"]

    async def main():
        async with OverlaySocketServer.local(
                admission={"limited": (0.0001, 1)},
                poll_interval=0.001) as srv:
            async with RemoteOverlayClient("127.0.0.1", srv.port,
                                           tenant="limited") as c:
                await c.submit(k, _xs(k, 64, 0))     # burst of 1
                with pytest.raises(AdmissionError) as ei:
                    await c.submit(k, _xs(k, 64, 1))
                assert ei.value.tenant == "limited"
                await c.flush_sync()

    asyncio.run(main())


# ================================================================ reclaim
def test_drop_and_reclaim_over_socket(kernels):
    oracle = OverlayServer(bank_capacity=16)
    k = kernels["mibench"]

    async def main():
        async with OverlaySocketServer.local(poll_interval=0.001) as srv:
            c1 = await RemoteOverlayClient("127.0.0.1", srv.port,
                                           session="s-1").connect()
            pairs = []
            for i in range(5):
                xs = _xs(k, 64, i)
                pairs.append((await c1.submit(k, xs), oracle.submit(k, xs)))
            await c1.aclose()                   # dropped with work in flight
            await asyncio.sleep(0.05)           # pump keeps delivering
            c2 = await RemoteOverlayClient("127.0.0.1", srv.port,
                                           session="s-1").connect()
            got = await c2.reclaim()
            assert await c2.reclaim() == {}     # exactly once
            await c2.aclose()
            gw_stats = srv.stats()["gateway"]
            assert gw_stats["orphan_sessions"] == 0
            assert gw_stats["orphaned_results_held"] == 0
            return got, pairs

    got, pairs = asyncio.run(main())
    assert set(got) == {t for t, _ in pairs}
    _assert_parity(pairs, got, oracle.flush_sync())


# =================================================================== soak
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_loopback_churn_soak(kernels, seed):
    """Connect/drop/reclaim churn over real loopback sockets against an
    elastic fleet with forced grow/drain: zero ticket loss, bit parity
    vs the single-bank oracle."""
    rng = np.random.RandomState(seed)
    oracle = OverlayServer(bank_capacity=16)
    srv = ShardedOverlayServer(
        n_replicas=1, bank_capacity=4, round_kernels=2,
        autoscaler=PressureAutoscaler(up_tiles=8, up_rounds=2,
                                      down_rounds=20, max_replicas=3))
    names = list(kernels)

    async def main():
        got, pairs, dropped = {}, [], []
        async with OverlayGateway(srv, max_fleet_tiles=64,
                                  overflow="wait",
                                  poll_interval=0.001) as gw:
            async with OverlaySocketServer(gw) as sock:
                req_i = 0
                for phase in range(5):
                    clients = [await RemoteOverlayClient(
                        "127.0.0.1", sock.port, tenant=f"t{i % 3}",
                        session=f"s{seed}-{phase}-{i}").connect()
                        for i in range(3)]
                    for c in clients:
                        for _ in range(int(rng.randint(2, 5))):
                            k = kernels[names[req_i % len(names)]]
                            xs = _xs(k, int(rng.choice((48, 64, 96))),
                                     seed * 10000 + req_i)
                            req_i += 1
                            pairs.append((await c.submit(k, xs),
                                          oracle.submit(k, xs), c.session))
                    # forced fleet churn under the pump lock, same as the
                    # in-process soak: deterministic grow/drain
                    if phase == 2:
                        with gw.pump._lock:
                            srv.add_replica()
                    if phase == 4 and srv.n_replicas > 1:
                        with gw.pump._lock:
                            srv.drain_replica(srv.n_replicas - 1)
                    for c in clients:
                        if rng.rand() < 0.4:
                            got.update(await c.drain())
                            await c.aclose()
                        else:           # dropped with work in flight
                            await c.aclose()
                            dropped.append(c.session)
                    if phase == 3:
                        # a mid-soak barrier through a fresh client: the
                        # server-side flush claims parked sessions' work
                        # into the gateway's carry store
                        async with RemoteOverlayClient(
                                "127.0.0.1", sock.port) as fc:
                            await fc.flush_sync()
                    elif rng.rand() < 0.4:
                        await asyncio.sleep(0.02)
                for sid in dropped:
                    rc = await RemoteOverlayClient(
                        "127.0.0.1", sock.port, tenant="reclaimer",
                        session=sid).connect()
                    got.update(await rc.reclaim())
                    assert await rc.reclaim() == {}
                    await rc.aclose()
                st = sock.stats()
        return got, pairs, st

    got, pairs, st = asyncio.run(main())
    assert {t for t, _, _ in pairs} == set(got), "ticket lost or invented"
    want = oracle.flush_sync()
    for gt, ot, _ in pairs:
        for y, w in zip(got[gt], want[ot]):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))
    gws = st["gateway"]
    assert gws["orphan_sessions"] == 0
    assert gws["orphaned_results_held"] == 0
    assert gws["peak_fleet_tiles"] <= 64 * 2.0      # bound * widen_factor
    assert st["wire_rejects"] == 0
    assert st["open_connections"] == 0
    assert st["registered_kernels"] <= len(kernels)


# ================================================================== stats
def test_socket_stats_schema(kernels):
    from repro.telemetry import check_stats
    k = kernels["chebyshev"]

    async def main():
        async with OverlaySocketServer.local(poll_interval=0.001) as srv:
            async with RemoteOverlayClient("127.0.0.1", srv.port) as c:
                await c.submit(k, _xs(k, 64, 0))
                await c.flush_sync()
                cs = c.stats()
                assert cs["codec"] in ("json", "msgpack")
                assert cs["delivered"] == 1
            st = srv.stats()
            check_stats("socket", st)
            check_stats("gateway", st["gateway"])
            assert st["wire_frames_in"] > 0 and st["wire_bytes_out"] > 0
            assert st["wire_handshakes"] == 1

    asyncio.run(main())
