"""Training-as-a-tenant differential suite (PR 10).

Locks down the co-scheduling contract from three sides:

1. DIFFERENTIAL BIT-IDENTITY — a training run sliced into micro-rounds
   and co-scheduled through a serving engine (``TrainingTenant``) is
   bit-identical — params, opt_state, loss trace — to a standalone
   ``run_training`` loop on the same seed, under every round policy and
   under fleet grow/drain churn.
2. EXACTLY-ONCE PREEMPT/RESUME — random preemption points (seeded
   ``should_yield`` hooks, 4-seed matrix) never lose or double-apply a
   step: optimizer state, error-feedback ``ef``, and the data cursor
   survive every yield.
3. STARVATION IS ONE-DIRECTIONAL — saturated serving drives training
   throughput to zero (no bulk round forms while a latency flow is
   queued) while every serving request is still delivered; training
   never delays a latency round, so serving p99 under co-scheduling
   stays within a calibrated bound of the dedicated-engine control.

Plus the seed-matrix determinism regression for ``runtime/steps.py``
and the CLI-vs-library trace differential for ``launch/train.py``.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.bank import ContextBank
from repro.core.overlay import Overlay, compile_program
from repro.core.paper_bench import benchmark
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.launch.serve import OverlayServer, ShardedOverlayServer
from repro.launch.train import run_training
from repro.launch.trainer_tenant import TrainingTenant
from repro.models import init_params
from repro.runtime import optim as O
from repro.runtime.steps import make_train_step
from repro.sched import (BULK_PREFIX, CoalescingPolicy, DeficitRoundRobin,
                         DynamicTilePolicy, PreemptibleTier, WorkRequest,
                         make_round_policy)
from repro.telemetry import check_stats

ROOT = pathlib.Path(__file__).resolve().parent.parent

POLICIES = {
    "drr": lambda: DeficitRoundRobin(quantum_tiles=2.0),
    "coalesce": lambda: CoalescingPolicy(quantum_tiles=2.0,
                                         coalesce_tiles=8),
    "dynamic": lambda: DynamicTilePolicy(quantum_tiles=2.0, init_tiles=8,
                                         min_tiles=2),
}

STEPS = 8


@pytest.fixture(scope="module")
def cfgs():
    cfg = get_smoke_config("deepseek-7b")
    oc = O.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    dc = DataConfig(global_batch=2, seq_len=32, vocab=cfg.vocab)
    return cfg, oc, dc


@pytest.fixture(scope="module")
def step_fn(cfgs):
    """One shared jit: every arm of the differential reuses the same
    compiled step, so the comparison isolates the SCHEDULING."""
    cfg, oc, _ = cfgs
    return jax.jit(make_train_step(cfg, oc))


@pytest.fixture(scope="module")
def step_fn_compress(cfgs):
    cfg, oc, _ = cfgs
    return jax.jit(make_train_step(cfg, oc, compress_grads=True))


def _standalone(cfgs, *, steps=STEPS, step_fn=None, compress=False):
    """The reference: a plain ``run_training`` loop, no engine."""
    cfg, oc, dc = cfgs
    params, opt, losses = None, None, []
    for rec in run_training(cfg, oc, dc, steps=steps, yield_every=1,
                            compress_grads=compress, step_fn=step_fn):
        params, opt = rec["params"], rec["opt_state"]
        losses.append(rec["loss"])
    return params, opt, losses


@pytest.fixture(scope="module")
def ref(cfgs, step_fn):
    return _standalone(cfgs, step_fn=step_fn)


@pytest.fixture(scope="module")
def ref_compress(cfgs, step_fn_compress):
    return _standalone(cfgs, step_fn=step_fn_compress, compress=True)


@pytest.fixture(scope="module")
def kernel():
    return compile_program(benchmark("poly5"))


def _xs(kernel, batch, seed):
    rng = np.random.RandomState(seed)
    return [rng.uniform(-2, 2, (batch,)).astype(np.float32)
            for _ in kernel.dfg.inputs]


def _oracle(k, xs):
    [want] = Overlay().dispatch(ContextBank(4), [(k, xs)])
    return want


def _assert_tree_equal(got, want):
    la, lb = jax.tree.leaves(got), jax.tree.leaves(want)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _drive_cosched(server, tenant, kernel, *, beats_cap=64, serve=True):
    """Tick the tenant between serving beats until training finishes;
    every serving request must come back same-beat and bit-exact."""
    beat = 0
    lat = []
    while not tenant.done:
        if serve:
            xs = _xs(kernel, 4, beat)
            t = server.submit(kernel, xs, tenant="alice")
        tenant.tick()
        res = server.flush()
        if serve:
            assert t in res, "serving request starved by training"
            np.testing.assert_array_equal(np.asarray(res[t][0]),
                                          np.asarray(_oracle(kernel, xs)[0]))
        beat += 1
        assert beat < beats_cap, "co-scheduled run failed to finish"
        lat.append(beat)
    return beat


# =================================================== PreemptibleTier units


def test_preemptible_tier_construction():
    tier = PreemptibleTier()                      # default inner DRR
    assert isinstance(tier.inner, DeficitRoundRobin)
    tier = PreemptibleTier("coalesce", quantum_tiles=4.0)
    assert isinstance(tier.inner, CoalescingPolicy)
    inner = DynamicTilePolicy(quantum_tiles=2.0, init_tiles=8, min_tiles=2)
    assert PreemptibleTier(inner).inner is inner
    with pytest.raises(ValueError):
        PreemptibleTier(inner, quantum_tiles=4.0)  # instance + knob
    with pytest.raises(ValueError):
        PreemptibleTier(PreemptibleTier())         # no double wrap


def test_preemptible_tier_is_bulk():
    tier = PreemptibleTier(bulk_tenants={"batchq"})
    assert tier.is_bulk("batchq")
    assert tier.is_bulk(BULK_PREFIX + "anything")
    assert not tier.is_bulk("alice")
    tier.add_bulk({"alice"})
    assert tier.is_bulk("alice")


def test_preemptible_tier_stats_and_quantum():
    tier = PreemptibleTier(DeficitRoundRobin(
        quantum_tiles=2.0, tenant_quanta={"bulk:train": 0.5}))
    assert tier.quantum_for("bulk:train") == 0.5
    s = tier.stats()
    assert s["tier_policy"] == "DeficitRoundRobin"
    assert s["latency_rounds"] == 0 and s["bulk_rounds"] == 0


def test_make_preemptible_idempotent(kernel):
    srv = OverlayServer(bank_capacity=4)
    tier = srv.make_preemptible(bulk_tenants={"b1"})
    tier2 = srv.make_preemptible(bulk_tenants={"b2"})
    assert tier is tier2 and tier is srv.round_policy
    assert tier.is_bulk("b1") and tier.is_bulk("b2")


# ====================================================== submit_work engine


def test_submit_work_mixed_round(kernel):
    srv = OverlayServer(bank_capacity=4)
    ran = []
    xs = _xs(kernel, 4, 0)
    t_k = srv.submit(kernel, xs, tenant="alice")
    t_w = srv.submit_work(lambda: ran.append(1) or "done", tenant="bulk:w")
    res = srv.flush()
    assert res[t_w] == "done" and ran == [1]
    np.testing.assert_array_equal(np.asarray(res[t_k][0]),
                                  np.asarray(_oracle(kernel, xs)[0]))
    check_stats("engine", srv.stats())


def test_submit_work_flush_sync_parity(kernel):
    """The barrier oracle drains work requests identically."""
    outs = {}
    for drain in ("flush", "flush_sync"):
        srv = OverlayServer(bank_capacity=4)
        xs = _xs(kernel, 4, 1)
        t_k = srv.submit(kernel, xs, tenant="alice")
        t_w = srv.submit_work(lambda: 42, tenant="bulk:w")
        res = getattr(srv, drain)()
        outs[drain] = (np.asarray(res[t_k][0]), res[t_w])
    np.testing.assert_array_equal(outs["flush"][0], outs["flush_sync"][0])
    assert outs["flush"][1] == outs["flush_sync"][1] == 42


def test_work_request_exported():
    r = WorkRequest(ticket=0, kernel=None, xs=[], tenant="bulk:x",
                    key=None, cost=1, t_submit=0.0, fn=lambda: 1,
                    label="probe")
    assert r.name == "probe" and r.batch == 0


# ============================================== differential: bit-identity


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_cosched_bit_identity_policies(cfgs, step_fn, ref, kernel, policy):
    """Co-scheduled == standalone, bit for bit, under every round policy,
    with latency traffic interleaved every beat."""
    cfg, oc, dc = cfgs
    srv = OverlayServer(bank_capacity=8, round_policy=POLICIES[policy]())
    tenant = TrainingTenant(srv, cfg, oc, dc, steps=STEPS, yield_every=3,
                            step_fn=step_fn)
    assert isinstance(srv.round_policy, PreemptibleTier)
    _drive_cosched(srv, tenant, kernel)
    ref_params, ref_opt, ref_losses = ref
    assert tenant.losses == ref_losses
    assert tenant.step_trace == list(range(STEPS))
    _assert_tree_equal(tenant.params, ref_params)
    _assert_tree_equal(tenant.opt_state, ref_opt)
    st = tenant.stats()
    check_stats("train", st)
    assert st["steps"] == STEPS and st["done"]


def test_cosched_bit_identity_fleet_churn(cfgs, step_fn, ref, kernel):
    """Same differential on a sharded fleet with forced add_replica /
    drain_replica churn between micro-rounds."""
    cfg, oc, dc = cfgs
    fleet = ShardedOverlayServer(n_replicas=2, bank_capacity=6)
    tenant = TrainingTenant(fleet, cfg, oc, dc, steps=STEPS, yield_every=2,
                            step_fn=step_fn)
    beat = 0
    while not tenant.done:
        if beat == 1:
            fleet.add_replica()
        if beat == 3:
            fleet.drain_replica(0)
        xs = _xs(kernel, 4, beat)
        t = fleet.submit(kernel, xs, tenant="alice")
        tenant.tick()
        res = fleet.flush()
        assert t in res
        np.testing.assert_array_equal(np.asarray(res[t][0]),
                                      np.asarray(_oracle(kernel, xs)[0]))
        beat += 1
        assert beat < 64
    ref_params, ref_opt, ref_losses = ref
    assert tenant.losses == ref_losses
    _assert_tree_equal(tenant.params, ref_params)
    _assert_tree_equal(tenant.opt_state, ref_opt)
    check_stats("fleet", fleet.stats())
    # replicas added after make_preemptible inherit the tier
    for rep in fleet.replicas:
        assert isinstance(rep.round_policy, PreemptibleTier)


# ===================================== exactly-once preempt/resume property


def _random_yield(seed):
    """Seeded preemption schedule: always preempt at the first poll
    (guarantees >= 1 preemption), then coin-flip every boundary."""
    rng = np.random.RandomState(seed)
    state = {"first": True}

    def should_yield():
        if state["first"]:
            state["first"] = False
            return True
        return bool(rng.rand() < 0.5)

    return should_yield


@pytest.mark.parametrize("seed", [0, 1, 2, 3],
                         ids=[f"seed{i}" for i in range(4)])
def test_preempt_resume_exactly_once(cfgs, step_fn, ref, kernel, seed):
    """Random preemption points never lose or double-apply a step:
    params/opt_state land bit-identical to the standalone run, the step
    trace is exactly 0..N-1 once each, and every preemption is paired
    with exactly one resume."""
    cfg, oc, dc = cfgs
    srv = OverlayServer(bank_capacity=8)
    tenant = TrainingTenant(srv, cfg, oc, dc, steps=STEPS, yield_every=4,
                            step_fn=step_fn, should_yield=_random_yield(seed))
    _drive_cosched(srv, tenant, kernel)
    ref_params, ref_opt, ref_losses = ref
    assert tenant.step_trace == list(range(STEPS)), "step lost or doubled"
    assert tenant.losses == ref_losses
    _assert_tree_equal(tenant.params, ref_params)
    _assert_tree_equal(tenant.opt_state, ref_opt)
    st = tenant.stats()
    check_stats("train", st)
    assert st["preemptions"] >= 1
    assert st["resumes"] == st["preemptions"], "unpaired preempt/resume"
    assert tenant.cursor == SyntheticCorpus(dc).cursor(STEPS)


@pytest.mark.parametrize("seed", [0, 1, 2, 3],
                         ids=[f"seed{i}" for i in range(4)])
def test_preempt_resume_exactly_once_compressed(cfgs, step_fn_compress,
                                                ref_compress, kernel, seed):
    """Same property with int8 grad compression: the error-feedback
    state in opt_state['ef'] survives every preempt/resume."""
    cfg, oc, dc = cfgs
    srv = OverlayServer(bank_capacity=8)
    tenant = TrainingTenant(srv, cfg, oc, dc, steps=STEPS, yield_every=4,
                            compress_grads=True, step_fn=step_fn_compress,
                            should_yield=_random_yield(seed))
    _drive_cosched(srv, tenant, kernel)
    ref_params, ref_opt, ref_losses = ref_compress
    assert tenant.step_trace == list(range(STEPS))
    assert tenant.losses == ref_losses
    assert "ef" in tenant.opt_state, "error-feedback state dropped"
    _assert_tree_equal(tenant.params, ref_params)
    _assert_tree_equal(tenant.opt_state, ref_opt)
    assert tenant.stats()["resumes"] == tenant.stats()["preemptions"] >= 1


# ================================================= starvation is one-sided


def test_serving_starves_training_never_reverse(cfgs, step_fn, kernel):
    """While a latency flow is continuously backlogged NO bulk round
    forms — training throughput is exactly zero — yet every serving
    request is delivered.  When the pressure stops, training completes.
    max_inflight=1 keeps launch/retire strictly alternating so the
    starvation window is exact."""
    cfg, oc, dc = cfgs
    srv = OverlayServer(bank_capacity=8, max_inflight=1)
    tenant = TrainingTenant(srv, cfg, oc, dc, steps=4, yield_every=2,
                            step_fn=step_fn)
    tenant.tick()                      # micro-round queued on the bulk tier
    tier = srv.round_policy
    tickets = [srv.submit(kernel, _xs(kernel, 4, i), tenant="alice")
               for i in range(2)]
    for i in range(12):
        # keep the latency queue NON-EMPTY across every form_round call
        tickets.append(srv.submit(kernel, _xs(kernel, 4, 10 + i),
                                  tenant="alice"))
        srv.pump_once()
        assert tier.n_bulk_rounds == 0, "bulk round formed under backlog"
        assert tenant.stats()["steps"] == 0, "training ran while starved"
    # serving made progress the whole time training was starved
    assert int(srv.telemetry.counter("engine.rounds")) >= 10
    res = srv.flush()                  # drain the tail (incl. the bulk round)
    assert all(t in res for t in tickets), "serving starved — never allowed"
    # pressure gone: training runs to completion
    tenant.run()
    assert tenant.done and tenant.stats()["steps"] == 4
    assert tier.n_bulk_rounds >= 1


def test_serving_p99_bounded_under_training(cfgs, step_fn, kernel):
    """Calibrated p99 bound: co-scheduled serving latency stays within a
    generous multiple of the dedicated-engine control (the tight <10%
    assertion lives in benchmarks/train_serve_study.py at matched load;
    this guards against structural regressions — e.g. a latency round
    retiring behind a bulk launch)."""
    cfg, oc, dc = cfgs

    def drive(with_training):
        srv = OverlayServer(bank_capacity=8, max_inflight=1)
        tenant = None
        if with_training:
            tenant = TrainingTenant(srv, cfg, oc, dc, steps=6,
                                    yield_every=2, step_fn=step_fn)
        for beat in range(12):
            xs = _xs(kernel, 4, beat)
            t = srv.submit(kernel, xs, tenant="alice")
            if tenant is not None:
                tenant.tick()
            res = srv.flush()
            assert t in res
        return srv.tenant_latency_percentiles()["alice"]["p99"]

    p99_dedicated = drive(with_training=False)
    p99_cosched = drive(with_training=True)
    assert p99_cosched <= p99_dedicated * 10 + 0.25, (
        f"serving p99 {p99_cosched:.4f}s vs dedicated "
        f"{p99_dedicated:.4f}s — training is delaying latency rounds")


# ============================================ telemetry: train.* counters


def test_train_counters_fan_out_to_server_sink(cfgs, step_fn):
    """The tenant's MultiSink writes train.* into the engine's sink too,
    so fleet-level stores see training alongside serving."""
    cfg, oc, dc = cfgs
    srv = OverlayServer(bank_capacity=4)
    tenant = TrainingTenant(srv, cfg, oc, dc, steps=2, yield_every=2,
                            step_fn=step_fn)
    tenant.run()
    assert int(srv.telemetry.counter("train.steps")) == 2
    assert int(tenant.telemetry.counter("train.steps")) == 2
    check_stats("train", tenant.stats())


# ====================================== seed-matrix determinism regression


@pytest.mark.parametrize("variant", ["plain", "compress", "mixed"])
def test_seed_matrix_step_determinism(cfgs, variant):
    """runtime/steps.py regression: same seed -> bit-identical params
    after N steps, with and without compress_grads / mixed precision."""
    cfg, oc, dc = cfgs
    compress = variant == "compress"
    mixed = variant == "mixed"

    def one_run():
        params = init_params(cfg, jax.random.PRNGKey(0))
        if mixed:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16), params)
        opt = O.init_opt_mixed(params) if mixed else O.init_opt(params)
        fn = jax.jit(make_train_step(cfg, oc, compress_grads=compress,
                                     mixed=mixed))
        last = None
        for rec in run_training(cfg, oc, dc, steps=4, params=params,
                                opt_state=opt, compress_grads=compress,
                                mixed=mixed, step_fn=fn):
            last = rec
        return last["params"], last["opt_state"], last["loss"]

    p1, o1, l1 = one_run()
    p2, o2, l2 = one_run()
    assert l1 == l2
    _assert_tree_equal(p1, p2)
    _assert_tree_equal(o1, o2)


# ============================================== CLI-vs-library differential


def test_cli_and_library_traces_identical(cfgs, tmp_path):
    """launch/train.py satellite: the CLI (subprocess, --trace-out) and
    the importable run_training loop produce IDENTICAL step/loss traces
    — the refactor left no behavioural fork between the two paths."""
    steps, batch, seq, lr = 4, 2, 32, 1e-3
    trace_file = tmp_path / "trace.json"
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"),
               JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    env.pop("JAX_DEVICES", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "deepseek-7b",
         "--smoke", "--steps", str(steps), "--batch", str(batch),
         "--seq", str(seq), "--lr", str(lr),
         "--trace-out", str(trace_file)],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=str(ROOT))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    got = json.loads(trace_file.read_text())

    cfg = get_smoke_config("deepseek-7b")
    oc = O.OptConfig(lr=lr, total_steps=max(steps, 10),
                     warmup_steps=max(2, steps // 20))
    dc = DataConfig(global_batch=batch, seq_len=seq, vocab=cfg.vocab)
    want = {"steps": [], "losses": []}
    for rec in run_training(cfg, oc, dc, steps=steps):
        want["steps"].append(rec["step"])
        want["losses"].append(rec["loss"])
    assert got["steps"] == want["steps"]
    assert got["losses"] == want["losses"], (
        "CLI and library step traces diverged")
