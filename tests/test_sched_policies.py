"""Scheduling-subsystem tests: policy conformance, golden-trace
extraction parity, work stealing, and the background autopump.

Four families:

* GOLDEN TRACE — ``repro.sched.rounds.DeficitRoundRobin`` is the
  pre-refactor engine scheduler extracted bit for bit:
  tests/golden/drr_rounds.json was recorded from the pre-``sched``
  engine (tools/record_golden_rounds.py) and the policy-driven engine
  must form IDENTICAL rounds and serve IDENTICAL result bytes on that
  trace.
* POLICY CONFORMANCE — every ``RoundPolicy`` implementation must (a)
  eventually serve every queued request, (b) bound a cold tenant's wait
  under a hot backlog, (c) deliver bits identical to the synchronous
  ``Overlay.dispatch`` oracle whatever rounds it forms.
* WORK STEALING — the ``WorkStealingRouter`` on 2/4/8 replicas: parity
  with the single-bank oracle on a skewed backlog, pins never touched,
  directory republished to the thief, balanced fleets and monolithic
  backlogs left alone.
* AUTOPUMP — concurrent ``submit`` makes progress with no explicit
  drain; in-flight rounds stay bounded; ``flush_sync`` through the pump
  is still the exact barrier; shutdown is clean and keeps queued work.
"""

import importlib.util
import json
import pathlib
import threading

import numpy as np
import pytest

from repro.core.bank import ContextBank
from repro.core.overlay import Overlay, compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.launch.serve import OverlayServer, ShardedOverlayServer
from repro.sched import (AutoPump, CoalescingPolicy, DeficitRoundRobin,
                         DynamicTilePolicy, Flow, OverlayRequest,
                         RoundPolicy, WorkStealingRouter, make_round_policy)
from collections import deque

ROOT = pathlib.Path(__file__).resolve().parent.parent
ALL_NAMES = BENCH_NAMES + ("gradient",)

POLICIES = {
    "drr": lambda: DeficitRoundRobin(quantum_tiles=2.0),
    "coalesce": lambda: CoalescingPolicy(quantum_tiles=2.0,
                                         coalesce_tiles=8),
    "dynamic": lambda: DynamicTilePolicy(quantum_tiles=2.0, init_tiles=8,
                                         min_tiles=2),
}


@pytest.fixture(scope="module")
def kernels():
    return {n: compile_program(benchmark(n)) for n in ALL_NAMES}


def _xs(kernel, batch, seed):
    rng = np.random.RandomState(seed)
    return [rng.uniform(-2, 2, (batch,)).astype(np.float32)
            for _ in kernel.dfg.inputs]


def _oracle(k, xs):
    [want] = Overlay().dispatch(ContextBank(4), [(k, xs)])
    return want


def _assert_bits(got, want):
    for y, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(y), np.asarray(w))


# ======================================================== golden extraction
def _load_recorder():
    spec = importlib.util.spec_from_file_location(
        "record_golden_rounds", ROOT / "tools" / "record_golden_rounds.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_drr_extraction_matches_recorded_golden_trace(kernels):
    """The extracted DeficitRoundRobin forms the EXACT rounds — and the
    engine serves the EXACT bytes — that the pre-refactor engine did on
    the recorded trace.  A mismatch means the extraction changed
    scheduling behaviour; do not regenerate the golden to make it pass."""
    rec = _load_recorder()
    golden = json.loads(
        (ROOT / "tests" / "golden" / "drr_rounds.json").read_text())
    trace = rec.build_trace(kernels)
    srv = OverlayServer(round_policy="drr", **rec.SERVER_KW)
    rounds, digests = rec.replay(srv, trace, kernels)
    assert rounds == golden["rounds"], "round formation drifted"
    assert {str(t): d for t, d in digests.items()} == golden["digests"], (
        "served bytes drifted")
    assert isinstance(srv.round_policy, DeficitRoundRobin)


# ===================================================== classic-DRR deficit
def _req(ticket, key, cost, tenant="t"):
    return OverlayRequest(ticket=ticket, kernel=None, xs=[np.zeros(1)],
                          tenant=tenant, key=(key, "h"), cost=cost)


def test_deficit_preserved_for_backlogged_flow():
    """Regression (classic-DRR semantics): a backlogged flow — queued
    work it could not afford this round — keeps its accumulated deficit.
    Resetting it (the deviation this guards against) would starve any
    request costing more than one quantum forever."""
    pol = DeficitRoundRobin(quantum_tiles=1.0)
    flows = {"hot": Flow(queue=deque([_req(i, "a", 1, "hot")
                                      for i in range(10)])),
             "big": Flow(queue=deque([_req(100, "b", 3, "big")]))}
    rr = deque(["hot", "big"])
    served_big_at = None
    for rnd in range(6):
        reqs = pol.form_round(flows, rr, round_kernels=4)
        assert reqs, "hot backlog keeps rounds non-empty"
        if any(r.ticket == 100 for r in reqs):
            served_big_at = rnd
            break
        # the backlogged flow's credit must GROW round over round
        assert flows["big"].deficit == pytest.approx(rnd + 1)
    # quantum 1, cost 3 => affordable exactly at the 3rd quantum
    assert served_big_at == 2
    assert flows["big"].deficit == pytest.approx(0.0)  # spent, then idle


def test_deficit_resets_only_when_idle():
    """The idle-flow reset is still standard DRR: a drained flow's
    deficit zeroes, so a returning tenant does not bank stale credit."""
    pol = DeficitRoundRobin(quantum_tiles=5.0)
    flows = {"t": Flow(queue=deque([_req(0, "a", 1)]))}
    rr = deque(["t"])
    reqs = pol.form_round(flows, rr, round_kernels=4)
    assert [r.ticket for r in reqs] == [0]
    assert flows["t"].deficit == 0.0          # drained => reset, not 4.0


def test_engine_serves_multi_quantum_request(kernels):
    """End-to-end: a request costing several quanta is served despite a
    competing hot flow (the engine-level consequence of deficit
    preservation)."""
    k_big, k_hot = kernels["poly6"], kernels["chebyshev"]
    srv = OverlayServer(bank_capacity=4, tile=64,
                        round_policy=DeficitRoundRobin(quantum_tiles=1.0))
    big_xs = _xs(k_big, 64 * 3, 0)            # cost 3 > quantum 1
    t_big = srv.submit(k_big, big_xs, tenant="big")
    for i in range(9):
        srv.submit(k_hot, _xs(k_hot, 64, 1 + i), tenant="hot")
    out = srv.flush()
    _assert_bits(out[t_big], _oracle(k_big, big_xs))
    assert srv.record(t_big)["round"] <= 3


# ====================================================== policy conformance
@pytest.mark.parametrize("policy_name", sorted(POLICIES))
def test_policy_serves_everything_bit_exact(kernels, policy_name):
    """Conformance: whatever rounds a policy forms, every queued request
    is served exactly once and every result is bit-identical to the
    synchronous dispatch oracle."""
    srv = OverlayServer(bank_capacity=4, round_kernels=2, max_inflight=2,
                        tile=64, round_policy=POLICIES[policy_name]())
    reqs = {}
    for i in range(24):
        k = kernels[ALL_NAMES[i % 7]]
        xs = _xs(k, 48 + 16 * (i % 4), seed=i)
        reqs[srv.submit(k, xs, tenant=f"t{i % 5}")] = (k, xs)
    got = srv.flush()
    assert set(got) == set(reqs)
    for t, (k, xs) in reqs.items():
        _assert_bits(got[t], _oracle(k, xs))
    assert srv.pending == 0 and srv.bank.n_pinned == 0


@pytest.mark.parametrize("policy_name", sorted(POLICIES))
def test_policy_starvation_bound(kernels, policy_name):
    """Conformance: a cold tenant's lone request lands within the first
    few rounds no matter how deep a hot tenant's backlog is."""
    srv = OverlayServer(bank_capacity=4, round_kernels=1, tile=64,
                        round_policy=POLICIES[policy_name]())
    k_hot = kernels["chebyshev"]
    for i in range(16):
        srv.submit(k_hot, _xs(k_hot, 64, i), tenant="hot")
    k_cold = kernels["poly5"]
    t_cold = srv.submit(k_cold, _xs(k_cold, 64, 99), tenant="cold")
    srv.flush()
    assert srv.record(t_cold)["round"] <= 3, srv.record(t_cold)
    # the backlog really spanned rounds (coalescing legitimately packs
    # the hot backlog into fewer, fuller rounds than DRR's quantum does)
    assert srv.n_rounds >= (4 if policy_name == "drr" else 2)


@pytest.mark.parametrize("policy_name", sorted(POLICIES))
def test_policy_streaming_matches_barrier(kernels, policy_name):
    """Conformance: pipelined and barrier drains serve identical bits
    under every policy (rounds may differ; bytes may not)."""
    def build():
        srv = OverlayServer(bank_capacity=3, round_kernels=2, tile=64,
                            max_inflight=3,
                            round_policy=POLICIES[policy_name]())
        tickets = {}
        for i in range(14):
            k = kernels[ALL_NAMES[i % 6]]
            xs = _xs(k, 48 + 16 * (i % 3), seed=50 + i)
            tickets[srv.submit(k, xs, tenant=f"t{i % 3}")] = (k, xs)
        return srv, tickets

    srv_a, tickets_a = build()
    srv_b, tickets_b = build()
    out_pipe, out_sync = srv_a.flush(), srv_b.flush_sync()
    assert set(out_pipe) == set(out_sync) == set(tickets_a)
    for t, (k, xs) in tickets_a.items():
        want = _oracle(k, xs)
        _assert_bits(out_pipe[t], want)
        _assert_bits(out_sync[t], want)


def test_policies_satisfy_protocol():
    for factory in POLICIES.values():
        assert isinstance(factory(), RoundPolicy)


def test_make_round_policy_env_knob(monkeypatch):
    monkeypatch.delenv("REPRO_ROUND_POLICY", raising=False)
    assert type(make_round_policy()) is DeficitRoundRobin
    monkeypatch.setenv("REPRO_ROUND_POLICY", "coalesce")
    assert type(make_round_policy()) is CoalescingPolicy
    srv = OverlayServer(bank_capacity=2)
    assert type(srv.round_policy) is CoalescingPolicy
    # explicit name/instance beats the env
    assert type(make_round_policy("dynamic")) is DynamicTilePolicy
    srv = OverlayServer(bank_capacity=2, round_policy="drr")
    assert type(srv.round_policy) is DeficitRoundRobin
    with pytest.raises(ValueError):
        make_round_policy("nope")
    monkeypatch.setenv("REPRO_ROUND_POLICY", "typo")
    with pytest.raises(ValueError):
        OverlayServer(bank_capacity=2)
    monkeypatch.delenv("REPRO_ROUND_POLICY")
    # engine-level quantum_tiles alongside an injected instance would be
    # silently ignored — the engine refuses loudly instead
    with pytest.raises(ValueError):
        OverlayServer(bank_capacity=2, quantum_tiles=2.0,
                      round_policy=DeficitRoundRobin())


def test_policy_knob_validation():
    with pytest.raises(ValueError):
        DeficitRoundRobin(quantum_tiles=0)
    with pytest.raises(ValueError):
        CoalescingPolicy(coalesce_tiles=-1)
    with pytest.raises(ValueError):
        DynamicTilePolicy(min_tiles=0)
    with pytest.raises(ValueError):
        DynamicTilePolicy(init_tiles=8, max_tiles=4)
    with pytest.raises(ValueError):
        DynamicTilePolicy(target_latency_s=0.0)
    with pytest.raises(ValueError):
        DynamicTilePolicy(grow=1.0)
    with pytest.raises(ValueError):
        DynamicTilePolicy(shrink=1.5)


# ---------------------------------------------------------- coalescing
def test_coalescing_merges_same_kernel_across_tenants(kernels):
    """A second tenant's same-kernel request that its own deficit cannot
    cover rides the FIRST tenant's round under CoalescingPolicy (the
    deficit-free cross-tenant pull); plain DRR makes it wait for enough
    quantum."""
    k = kernels["chebyshev"]

    def serve(policy):
        srv = OverlayServer(bank_capacity=4, round_kernels=1, tile=64,
                            round_policy=policy)
        ta = srv.submit(k, _xs(k, 64, 0), tenant="a")      # cost 1
        tb = srv.submit(k, _xs(k, 64 * 2, 1), tenant="b")  # cost 2 > quantum
        srv.flush()
        return srv.record(ta)["round"], srv.record(tb)["round"]

    ra, rb = serve(CoalescingPolicy(quantum_tiles=1.0, coalesce_tiles=8))
    assert ra == rb == 0                       # coalesced into round 0
    ra, rb = serve(DeficitRoundRobin(quantum_tiles=1.0))
    assert (ra, rb) == (0, 1)                  # DRR: b waits for quantum 2


def test_coalescing_respects_tile_budget(kernels):
    """Coalesced pulls stop at coalesce_tiles; the rest waits its DRR
    turn."""
    k = kernels["chebyshev"]
    pol = CoalescingPolicy(quantum_tiles=1.0, coalesce_tiles=2)
    srv = OverlayServer(bank_capacity=4, round_kernels=1, tile=64,
                        round_policy=pol)
    # t0's request is affordable (cost 1); the rest cost 2 (> quantum 1)
    # so only coalescing can land them in round 0 — budget 2 fits ONE
    tickets = [srv.submit(k, _xs(k, 64 if i == 0 else 128, i),
                          tenant=f"t{i}") for i in range(6)]
    srv.flush()
    rounds = [srv.record(t)["round"] for t in tickets]
    assert rounds.count(0) == 2, rounds        # base take + one coalesced
    assert pol.n_coalesced >= 1
    assert sorted(rounds)[-1] >= 1             # the rest waited


def test_coalescing_preserves_within_tenant_order(kernels):
    """Regression: when a tenant's OLDER same-kernel request exceeds the
    remaining coalesce budget, its newer one must not jump the queue —
    the scan stops at the unaffordable request instead of skipping it."""
    k = kernels["chebyshev"]
    pol = CoalescingPolicy(quantum_tiles=1.0, coalesce_tiles=1)
    srv = OverlayServer(bank_capacity=4, round_kernels=1, tile=64,
                        round_policy=pol)
    srv.submit(k, _xs(k, 64, 0), tenant="a")            # base round take
    t_old = srv.submit(k, _xs(k, 64 * 2, 1), tenant="b")  # cost 2 > budget
    t_new = srv.submit(k, _xs(k, 64, 2), tenant="b")      # cost 1 fits
    srv.flush()
    # t_new must NOT land in an earlier round than t_old
    assert srv.record(t_new)["round"] >= srv.record(t_old)["round"]


def test_coalescing_budget_zero_is_plain_drr(kernels):
    rec = _load_recorder()
    golden = json.loads(
        (ROOT / "tests" / "golden" / "drr_rounds.json").read_text())
    trace = rec.build_trace(kernels)
    srv = OverlayServer(
        round_policy=CoalescingPolicy(quantum_tiles=2.0, coalesce_tiles=0),
        **{k: v for k, v in rec.SERVER_KW.items()
           if k != "quantum_tiles"})
    rounds, digests = rec.replay(srv, trace, kernels)
    assert rounds == golden["rounds"]


# ------------------------------------------------------------- dynamic
def test_dynamic_policy_adapts_round_budget():
    pol = DynamicTilePolicy(target_latency_s=0.1, init_tiles=32,
                            min_tiles=4, max_tiles=64)
    pol.observe(32, 0.5)                       # overshoot -> shrink
    assert pol.round_tiles == 16 and pol.n_shrunk == 1
    pol.observe(2, 0.001)                      # near-empty round: no grow
    assert pol.round_tiles == 16 and pol.n_grown == 0
    pol.observe(16, 0.001)                     # full + fast -> grow
    assert pol.round_tiles == 20 and pol.n_grown == 1
    for _ in range(20):
        pol.observe(int(pol.round_tiles), 0.001)
    assert pol.round_tiles == 64               # clamped at max_tiles
    for _ in range(20):
        pol.observe(int(pol.round_tiles), 1.0)
    assert pol.round_tiles == 4                # clamped at min_tiles


def test_dynamic_policy_caps_round_tiles(kernels):
    """With a tiny budget, no formed round exceeds it (beyond the
    guaranteed first request)."""
    k = kernels["chebyshev"]
    pol = DynamicTilePolicy(quantum_tiles=None, init_tiles=2, min_tiles=2,
                            max_tiles=2, target_latency_s=1e9)
    srv = OverlayServer(bank_capacity=4, tile=64, round_policy=pol)
    for i in range(8):
        srv.submit(k, _xs(k, 64, i))           # 1 tile each
    srv.flush()
    per_round: dict[int, int] = {}
    for t in range(8):
        r = srv.record(t)["round"]
        per_round[r] = per_round.get(r, 0) + 1
    assert max(per_round.values()) <= 2 and len(per_round) >= 4


# ========================================================== work stealing
def _homes(srv, kernels):
    """Warm every kernel onto its routed home; return {name: replica}
    for kernels still VALIDLY resident (a replica whose bank is smaller
    than its share of the family evicts the overflow — those have no
    home to skew against)."""
    for i, n in enumerate(ALL_NAMES):
        srv.submit(kernels[n], _xs(kernels[n], 32, i))
    srv.flush()
    homes = {n: srv.directory.locate(kernels[n], srv.banks)
             for n in ALL_NAMES}
    return {n: h for n, h in homes.items() if h is not None}


def _skewed_burst(srv, kernels, homes, n_requests, tile_batch=128):
    """Queue a burst aimed entirely at the replica owning the most
    kernels; returns {ticket: (kernel, xs)} and that replica id."""
    by_home: dict[int, list] = {}
    for n, h in homes.items():
        by_home.setdefault(h, []).append(n)
    hot_rep, hot_names = max(by_home.items(), key=lambda kv: len(kv[1]))
    assert len(hot_names) >= 2, (
        "skew recipe needs >= 2 kernels homed together")
    reqs = {}
    for i in range(n_requests):
        k = kernels[hot_names[i % len(hot_names)]]
        xs = _xs(k, tile_batch, 1000 + i)
        reqs[srv.submit(k, xs)] = (k, xs)
    return reqs, hot_rep


@pytest.mark.parametrize("n_replicas", [2, 4, 8])
def test_work_stealing_parity_on_skewed_backlog(kernels, n_replicas):
    """An all-on-one-replica backlog is rebalanced by stealing, with
    every result bit-identical to the single-bank oracle and every pin
    released.  Migration is disabled so stealing is the only mover."""
    srv = ShardedOverlayServer(n_replicas=n_replicas, bank_capacity=4,
                               round_kernels=2, steal=True,
                               migrate_min_tiles=10**9)
    homes = _homes(srv, kernels)
    reqs, hot_rep = _skewed_burst(srv, kernels, homes, 30)
    assert srv.replicas[hot_rep].queued_tiles == 30
    got = srv.flush()
    assert set(got) == set(reqs)
    assert srv.n_steals >= 1, srv.stats()
    assert srv.directory.n_republished >= 1
    for t, (k, xs) in reqs.items():
        _assert_bits(got[t], _oracle(k, xs))
    assert srv.pending == 0
    for bank in srv.banks:
        assert bank.n_pinned == 0


def test_stolen_work_latency_and_records_survive(kernels):
    """A stolen ticket keeps its telemetry (tenant, submit time) and its
    record reports the THIEF replica."""
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=4, steal=True,
                               migrate_min_tiles=10**9)
    homes = _homes(srv, kernels)
    reqs, hot_rep = _skewed_burst(srv, kernels, homes, 20)
    srv.flush()
    assert srv.n_steals >= 1
    moved = [t for t in reqs if srv.record(t)["replica"] != hot_rep]
    assert moved, "stealing moved no tickets off the hot replica"
    for t in moved:
        rec = srv.record(t)
        assert rec["t_done"] is not None and rec["tenant"] == "default"
    assert len(srv.latencies()) >= len(reqs)


def test_stealing_leaves_inflight_rounds_alone(kernels):
    """Pin-safety, probed live: while streaming with stealing on, every
    in-flight round's contexts stay pinned on THEIR replica until
    delivery — stolen work is queued work only."""
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=3,
                               round_kernels=1, max_inflight=2, steal=True,
                               migrate_min_tiles=10**9)
    # warm only 4 kernels so each bank (3 slots) keeps its 2-kernel share
    # resident — homes must survive the warmup for the skew to aim
    for i, n in enumerate(ALL_NAMES[:4]):
        srv.submit(kernels[n], _xs(kernels[n], 32, i))
    srv.flush()
    homes = {n: srv.directory.locate(kernels[n], srv.banks)
             for n in ALL_NAMES[:4]}
    homes = {n: h for n, h in homes.items() if h is not None}
    reqs, _ = _skewed_burst(srv, kernels, homes, 16, tile_batch=64)
    got = {}
    for t, outs in srv.as_completed():
        got[t] = outs
        for rep in srv.replicas:
            for inf in rep._inflight:
                for g in inf.plan.groups:
                    assert rep.bank.is_pinned(g.kernel), (
                        "in-flight context lost its pin under stealing")
    assert set(got) == set(reqs)
    for t, (k, xs) in reqs.items():
        _assert_bits(got[t], _oracle(k, xs))
    for bank in srv.banks:
        assert bank.n_pinned == 0


def test_no_steal_when_balanced(kernels):
    """Balanced queues never steal (every replica busy = no idle thief).
    Banks hold the whole family share so homes survive the warmup."""
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=8, steal=True,
                               migrate_min_tiles=10**9)
    homes = _homes(srv, kernels)
    by_home: dict[int, list] = {}
    for n, h in homes.items():
        by_home.setdefault(h, []).append(n)
    assert len(by_home) == 2
    for i in range(12):                        # even spread over both homes
        for names in by_home.values():
            k = kernels[names[i % len(names)]]
            srv.submit(k, _xs(k, 128, 50 + i))
    srv.flush()
    assert srv.n_steals == 0


def test_no_steal_of_monolithic_group(kernels):
    """A backlog that is ONE kernel-group is not relocated: moving it to
    an idle replica is net-zero balance and pure residency churn."""
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=4, steal=True,
                               migrate_min_tiles=10**9)
    k = kernels["chebyshev"]
    for i in range(12):
        srv.submit(k, _xs(k, 128, i))
    srv.flush()
    assert srv.n_steals == 0


def test_flush_sync_never_steals(kernels):
    """The barrier oracle drains replica by replica with no stealing, and
    still serves exact bits."""
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=4, steal=True,
                               migrate_min_tiles=10**9)
    homes = _homes(srv, kernels)
    reqs, _ = _skewed_burst(srv, kernels, homes, 12)
    got = srv.flush_sync()
    assert srv.n_steals == 0
    for t, (k, xs) in reqs.items():
        _assert_bits(got[t], _oracle(k, xs))


def test_steal_router_knob_validation():
    with pytest.raises(ValueError):
        WorkStealingRouter(steal_min_tiles=0)
    with pytest.raises(ValueError):
        WorkStealingRouter(migrate_factor=0.5)


def test_sharded_stats_expose_scheduling_telemetry(kernels):
    """The satellite stats surface: per-replica queue depth, residency
    hit/miss, rounds, steal count."""
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=4, steal=True)
    k = kernels["chebyshev"]
    srv.submit(k, _xs(k, 64, 0))
    st = srv.stats()
    assert st["queue_depth"] == [1, 0] or st["queue_depth"] == [0, 1]
    assert len(st["queued_tiles"]) == 2
    for key in ("route_hits", "route_misses", "residency_hit_rate",
                "migrations", "steals", "rounds", "directory", "router"):
        assert key in st, key
    srv.flush()
    st = srv.stats()
    assert st["queue_depth"] == [0, 0] and st["requests"] == 1
    rep = st["per_replica"][0]
    for key in ("queued", "queued_tiles", "round_policy", "free",
                "ctx_cache"):
        assert key in rep, key


# =============================================================== autopump
def test_autopump_serves_concurrent_submits(kernels):
    """Concurrent client threads submit; the pump delivers everything
    with NO explicit drain call, bit-identical to the oracle."""
    srv = OverlayServer(bank_capacity=4, round_kernels=2, tile=64)
    tickets: dict[int, tuple] = {}
    lock = threading.Lock()
    with AutoPump(srv) as pump:
        def client(tid):
            for i in range(4):
                k = kernels[ALL_NAMES[(tid * 4 + i) % len(ALL_NAMES)]]
                xs = _xs(k, 64, 100 * tid + i)
                t = pump.submit(k, xs, tenant=f"c{tid}")
                with lock:
                    tickets[t] = (k, xs)
        threads = [threading.Thread(target=client, args=(j,))
                   for j in range(3)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        pump.wait_idle(timeout=120)
        assert pump.pending == 0 and pump.n_pump_rounds >= 1
        for t, (k, xs) in tickets.items():
            _assert_bits(pump.result(t, timeout=30), _oracle(k, xs))
    assert srv.bank.n_pinned == 0


def test_autopump_bounds_inflight_rounds(kernels):
    """The pump never exceeds the engine's max_inflight."""
    srv = OverlayServer(bank_capacity=4, round_kernels=1, max_inflight=2,
                        tile=64)
    max_seen = 0
    with AutoPump(srv) as pump:
        for i in range(12):
            k = kernels[ALL_NAMES[i % 4]]
            pump.submit(k, _xs(k, 64, i))
            max_seen = max(max_seen, len(srv._inflight))
        pump.wait_idle(timeout=120)
        max_seen = max(max_seen, len(srv._inflight))
    assert max_seen <= 2


def test_autopump_flush_sync_is_exact_barrier(kernels):
    """flush_sync through the pump excludes the pump for its whole span
    and returns every unclaimed ticket with oracle-exact bytes."""
    srv = OverlayServer(bank_capacity=4, round_kernels=2, tile=64)
    with AutoPump(srv) as pump:
        reqs = {}
        for i in range(10):
            k = kernels[ALL_NAMES[i % 5]]
            xs = _xs(k, 64, 200 + i)
            reqs[pump.submit(k, xs, tenant=f"t{i % 2}")] = (k, xs)
        out = pump.flush_sync()
        assert set(out) == set(reqs)
        for t, (k, xs) in reqs.items():
            _assert_bits(out[t], _oracle(k, xs))


def test_autopump_clean_shutdown_keeps_queued_work(kernels):
    """close() stops the thread; work queued after shutdown is not lost
    and drains explicitly.  A waiter on a closed pump raises instead of
    spinning forever (already-delivered results stay claimable)."""
    srv = OverlayServer(bank_capacity=2, tile=64)
    pump = AutoPump(srv)
    k = kernels["chebyshev"]
    xs0 = _xs(k, 64, 5)
    t0 = pump.submit(k, xs0)
    pump.wait_idle(timeout=60)                 # t0 delivered, unclaimed
    pump.close()
    pump.close()                               # idempotent
    xs = _xs(k, 64, 0)
    t = pump.submit(k, xs)                     # accepted, just not pumped
    with pytest.raises(RuntimeError):
        pump.result(t)                         # closed pump: raise, not hang
    with pytest.raises(RuntimeError):
        pump.wait_idle()
    _assert_bits(pump.result(t0), _oracle(k, xs0))   # delivered: claimable
    _assert_bits(srv.flush()[t], _oracle(k, xs))


def test_autopump_claim_and_error_semantics(kernels):
    srv = OverlayServer(bank_capacity=2, tile=64)
    with AutoPump(srv) as pump:
        k = kernels["poly5"]
        t = pump.submit(k, _xs(k, 64, 1))
        pump.result(t, timeout=60)
        with pytest.raises(KeyError):
            pump.result(t)                     # claim-once
        with pytest.raises(KeyError):
            pump.result(424242)                # unknown
    with pytest.raises(ValueError):
        AutoPump(srv, poll_interval=0)


def test_autopump_over_sharded_fleet_with_stealing(kernels):
    """The pump drives the sharded engine too: concurrent submits are
    delivered across replicas (stealing allowed), bits exact."""
    srv = ShardedOverlayServer(n_replicas=3, bank_capacity=4, steal=True,
                               migrate_min_tiles=10**9)
    tickets: dict[int, tuple] = {}
    lock = threading.Lock()
    with AutoPump(srv) as pump:
        def client(tid):
            for i in range(4):
                k = kernels[ALL_NAMES[(2 * tid + i) % len(ALL_NAMES)]]
                xs = _xs(k, 96, 300 + 10 * tid + i)
                t = pump.submit(k, xs, tenant=f"c{tid}")
                with lock:
                    tickets[t] = (k, xs)
        threads = [threading.Thread(target=client, args=(j,))
                   for j in range(3)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        pump.wait_idle(timeout=120)
        for t, (k, xs) in tickets.items():
            _assert_bits(pump.result(t, timeout=30), _oracle(k, xs))
    for bank in srv.banks:
        assert bank.n_pinned == 0
