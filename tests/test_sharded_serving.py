"""Differential harness: sharded multi-device serving vs the single-bank
oracle.

Every workload here is served twice — through ``ShardedOverlayServer``
(2/4/8 replicas, each with its own device-pinned ``ContextBank``) and
through the single-bank ``OverlayServer`` barrier drain — and the results
must agree BIT FOR BIT.  The computation is elementwise f32 either way;
residency routing, replica placement, migration, and round formation must
never change a single bit of any tenant's outputs.

Replica count deliberately does NOT require real devices: replicas wrap
onto the live device list (``make_serving_mesh``), so the whole matrix
runs on single-device CI.  The ``JAX_DEVICES=8`` CI job re-runs it with 8
fake host devices and the device-placement assertions (marked
``multi_device``) become live.
"""

import jax
import numpy as np
import pytest

from repro.core.bank import BankDirectory, ContextBank
from repro.core.overlay import Overlay, compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.core.vm import pad_inputs
from repro.launch.mesh import make_serving_mesh
from repro.launch.serve import OverlayServer, ShardedOverlayServer

ALL_NAMES = BENCH_NAMES + ("gradient",)


@pytest.fixture(scope="module")
def kernels():
    return {n: compile_program(benchmark(n)) for n in ALL_NAMES}


def _xs(kernel, batch, seed):
    rng = np.random.RandomState(seed)
    return [rng.uniform(-2, 2, (batch,)).astype(np.float32)
            for _ in kernel.dfg.inputs]


def _zipf_workload(kernels, n_requests, n_tenants=6, s=1.3, seed=0):
    """Skewed multi-tenant mix: tenants pick kernels zipf-style, so a few
    (tenant, kernel) pairs dominate — the residency router's bread and
    butter."""
    rng = np.random.RandomState(seed)
    names = list(kernels)
    ranks = np.arange(1, len(names) + 1, dtype=np.float64)
    p = (1.0 / ranks ** s)
    p /= p.sum()
    work = []
    for i in range(n_requests):
        tenant = f"tenant{i % n_tenants}"
        # each tenant has its own zipf head: rotate the name list
        rot = names[i % n_tenants:] + names[:i % n_tenants]
        k = kernels[rot[rng.choice(len(names), p=p)]]
        batch = int(rng.choice([48, 64, 96, 128]))
        work.append((tenant, k, _xs(k, batch, seed * 1000 + i)))
    return work


def _serve_differential(srv, workload, drain="flush"):
    """Run one workload through ``srv`` and the single-bank oracle; assert
    bit-for-bit parity; return the sharded results keyed by ticket."""
    oracle = OverlayServer(bank_capacity=max(16, len(ALL_NAMES)))
    pairs = []
    for tenant, k, xs in workload:
        pairs.append((srv.submit(k, xs, tenant=tenant),
                      oracle.submit(k, xs, tenant=tenant), k))
    if drain == "flush":
        got = srv.flush()
    elif drain == "flush_sync":
        got = srv.flush_sync()
    else:  # as_completed
        got = dict(srv.as_completed())
    want = oracle.flush_sync()
    assert set(got) == {gt for gt, _, _ in pairs}
    for gt, ot, k in pairs:
        assert len(got[gt]) == len(k.dfg.outputs)
        for y, w in zip(got[gt], want[ot]):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))
    return got


# ------------------------------------------------------------------- parity
@pytest.mark.parametrize("n_replicas", [2, 4, 8])
def test_sharded_bit_parity_all_drains(kernels, n_replicas):
    """The whole mixed-kernel suite through R replicas == single bank, for
    every delivery path."""
    for drain in ("flush", "flush_sync", "as_completed"):
        srv = ShardedOverlayServer(n_replicas=n_replicas, bank_capacity=4,
                                   round_kernels=2, max_inflight=2)
        _serve_differential(
            srv, _zipf_workload(kernels, 27, seed=n_replicas), drain=drain)
        assert srv.pending == 0
        for bank in srv.banks:
            assert bank.n_pinned == 0


@pytest.mark.parametrize("n_replicas", [2, 4])
def test_sharded_result_api_parity(kernels, n_replicas):
    srv = ShardedOverlayServer(n_replicas=n_replicas, bank_capacity=4)
    work = _zipf_workload(kernels, 10, seed=7)
    tickets = [(srv.submit(k, xs, tenant=t), k, xs) for t, k, xs in work]
    for gt, k, xs in reversed(tickets):      # out-of-order claims
        got = srv.result(gt)
        ov = Overlay()
        [want] = ov.dispatch(ContextBank(4), [(k, xs)])
        for y, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))
        with pytest.raises(KeyError):
            srv.result(gt)                   # claimed once
    with pytest.raises(KeyError):
        srv.result(123456)


def test_sharded_interleaved_submit_and_stream(kernels):
    """as_completed across replicas picks up mid-iteration submits."""
    srv = ShardedOverlayServer(n_replicas=3, bank_capacity=4)
    k1, k2 = kernels["chebyshev"], kernels["poly6"]
    t1 = srv.submit(k1, _xs(k1, 64, 0))
    seen = []
    it = srv.as_completed()
    seen.append(next(it)[0])
    t2 = srv.submit(k2, _xs(k2, 64, 1))
    seen.extend(t for t, _ in it)
    assert seen == [t1, t2]


# ---------------------------------------------------------------- residency
def test_residency_hit_rate_under_zipf_mix(kernels):
    """After a warmup wave publishes every working set, routing is >90%
    residency hits (the acceptance bar) — repeat traffic lands on the
    replica already holding its context."""
    srv = ShardedOverlayServer(n_replicas=4, bank_capacity=4)
    srv.flush()  # no-op drain on an idle server must be fine
    for wave in range(3):
        for t, k, xs in _zipf_workload(kernels, 40, seed=wave):
            srv.submit(k, xs, tenant=t)
        srv.flush()
        if wave == 0:
            srv.reset_metrics()              # warmup wave = all misses
    assert srv.n_route_hits + srv.n_route_misses == 80
    assert srv.residency_hit_rate > 0.9, srv.stats()
    # aggregate residency really is sharded, not replicated: each context
    # has one home (plus at most a migration copy)
    resident = [set(b.resident) for b in srv.banks]
    total = sum(len(r) for r in resident)
    assert total <= len(ALL_NAMES) + srv.n_migrations


def test_directory_stale_entry_falls_back(kernels):
    """Evicting a context behind the directory's back (generation bump)
    must surface as a clean stale->miss fallback, never a wrong-slot
    dispatch."""
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=2)
    a, b, c = (kernels[n] for n in ("chebyshev", "poly5", "poly6"))
    ta = srv.submit(a, _xs(a, 64, 0))
    rep = srv.record(ta)["replica"]
    srv.flush()
    # churn the owning bank directly until A is evicted (stale directory)
    bank = srv.banks[rep]
    for extra in (b, c):
        bank.load(extra)
    assert bank.peek(a) is None
    n_stale0 = srv.directory.n_stale
    xs = _xs(a, 64, 1)
    t2 = srv.submit(a, xs)
    assert srv.directory.n_stale == n_stale0 + 1
    got = srv.flush()[t2]
    ov = Overlay()
    [want] = ov.dispatch(ContextBank(4), [(a, xs)])
    for y, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(y), np.asarray(w))


def test_directory_generation_validation_unit(kernels):
    bank0, bank1 = ContextBank(2), ContextBank(2)
    d = BankDirectory()
    a, b, c = (kernels[n] for n in ("chebyshev", "poly5", "poly6"))
    bank1.load(a)
    d.publish_current(a, 1, bank1)
    assert d.locate(a, [bank0, bank1]) == 1
    # eviction on the owner invalidates the entry
    bank1.load(b)
    bank1.load(c)                            # evicts a (capacity 2)
    assert bank1.peek(a) is None
    assert d.locate(a, [bank0, bank1]) is None and d.n_stale == 1
    assert len(d) == 0                       # stale entries are dropped
    # evict-and-RELOAD is also stale: the generation moved
    bank1.load(a)
    d.publish(a, 1, bank1.peek(a)[0], bank1.peek(a)[1] - 1)
    assert d.locate(a, [bank0, bank1]) is None and d.n_stale == 2
    # peek never touches LRU order
    bank0.load(a)
    bank0.load(b)
    lru_before = bank0.resident
    assert bank0.peek(a) is not None
    assert bank0.resident == lru_before


# ---------------------------------------------------------------- migration
def test_migration_under_load(kernels):
    """A hot context on an overloaded replica is re-homed to the coolest
    replica; traffic follows it and results stay bit-exact."""
    k = kernels["chebyshev"]
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=4,
                               migrate_min_tiles=4, migrate_factor=2.0,
                               migrate_cooldown=64)
    tickets = [srv.submit(k, _xs(k, 128, i)) for i in range(12)]
    homes = [srv._owner[t][0] for t in tickets]
    assert srv.n_migrations >= 1
    assert len(set(homes)) == 2              # traffic moved replicas
    # cooldown: exactly one migration within the window
    assert srv.n_migrations == 1
    # the directory now points at the new home
    assert srv.directory.locate(k, srv.banks) == homes[-1]
    got = srv.flush()
    ov = Overlay()
    for i, t in enumerate(tickets):
        [want] = ov.dispatch(ContextBank(4), [(k, _xs(k, 128, i))])
        for y, w in zip(got[t], want):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))


def test_no_migration_when_balanced(kernels):
    """Balanced replicas never migrate (hysteresis floor)."""
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=4,
                               migrate_min_tiles=1000)
    for i in range(20):
        k = kernels[ALL_NAMES[i % 4]]
        srv.submit(k, _xs(k, 64, i))
    srv.flush()
    assert srv.n_migrations == 0


# ------------------------------------------------ eviction/in-flight safety
def test_eviction_never_touches_inflight_per_replica(kernels):
    """Under per-replica LRU pressure, every in-flight round's contexts
    stay pinned in that replica's bank until delivery — probed live at
    each streaming step, then globally at the end."""
    srv = ShardedOverlayServer(n_replicas=2, bank_capacity=2,
                               round_kernels=1, max_inflight=2)
    reqs = {}
    for i in range(16):
        k = kernels[ALL_NAMES[i % len(ALL_NAMES)]]
        xs = _xs(k, 64, i)
        reqs[srv.submit(k, xs)] = (k, xs)
    got = {}
    for t, outs in srv.as_completed():
        got[t] = outs
        for rep in srv.replicas:
            for inf in rep._inflight:
                for g in inf.plan.groups:
                    assert rep.bank.is_pinned(g.kernel), (
                        "in-flight context lost its pin")
    assert set(got) == set(reqs)
    assert sum(b.n_evictions for b in srv.banks) >= 4  # pressure was real
    for bank in srv.banks:
        assert bank.n_pinned == 0
    ov = Overlay()
    for t, (k, xs) in reqs.items():
        [want] = ov.dispatch(ContextBank(4), [(k, xs)])
        for y, w in zip(got[t], want):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))


# ----------------------------------------------------- shared admission
def test_sharded_admission_spans_replicas(kernels):
    """One tenant's token bucket is global: it cannot reset its rate by
    hitting kernels that live on different replicas."""
    from repro.launch.serve import AdmissionError

    class FakeClock:
        t = 0.0

        def __call__(self):
            return self.t

    clock = FakeClock()
    srv = ShardedOverlayServer(n_replicas=4, bank_capacity=4, clock=clock,
                               admission={"metered": (1.0, 2.0)})
    ks = [kernels[n] for n in ("chebyshev", "poly5", "poly6")]
    srv.submit(ks[0], _xs(ks[0], 64, 0), tenant="metered")
    srv.submit(ks[1], _xs(ks[1], 64, 1), tenant="metered")
    with pytest.raises(AdmissionError):
        srv.submit(ks[2], _xs(ks[2], 64, 2), tenant="metered")
    srv.submit(ks[2], _xs(ks[2], 64, 3), tenant="free")
    clock.t += 1.0
    srv.submit(ks[2], _xs(ks[2], 64, 4), tenant="metered")
    assert len(srv.flush()) == 4


# ------------------------------------------- single-device assumption fixes
def test_bank_pinned_to_explicit_device_dispatch_parity(kernels):
    """Regression: a ContextBank committed to a non-default device must
    serve dispatch correctly (inputs are co-located with the bank, not
    implicitly placed on the default device)."""
    dev = jax.devices()[-1]
    ov = Overlay()
    bank = ContextBank(4, device=dev)
    pairs = [(kernels["chebyshev"], _xs(kernels["chebyshev"], 200, 1)),
             (kernels["poly6"], _xs(kernels["poly6"], 33, 2))]
    got = ov.dispatch(bank, pairs)
    want = ov.dispatch(ContextBank(4), pairs)
    for g, w in zip(got, want):
        for y, ref in zip(g, w):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))
    assert all(next(iter(leaf.devices())) == dev for leaf in bank.tree())
    # context writes (loads/evictions) stay on the pinned device
    bank2 = ContextBank(1, device=dev)
    bank2.load(kernels["poly5"])
    bank2.load(kernels["poly6"])             # eviction writes a new slot
    assert next(iter(bank2.op.devices())) == dev


def test_overlay_pinned_single_kernel_path(kernels):
    """Regression: the single-context path (load + __call__) honours the
    overlay's device pin end to end."""
    dev = jax.devices()[-1]
    ov = Overlay(device=dev)
    k = kernels["qspline"]
    xs = _xs(k, 96, 5)
    ctx = ov.load(k)
    assert next(iter(ctx.op.devices())) == dev
    got = ov(ctx, xs)
    assert all(next(iter(y.devices())) == dev for y in got)
    want = Overlay()(Overlay().load(k), xs)
    for y, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(y), np.asarray(w))


def test_pad_inputs_device_placement():
    dev = jax.devices()[-1]
    x = pad_inputs([np.ones(8, np.float32)], device=dev)
    assert next(iter(x.devices())) == dev


def test_make_serving_mesh_wraps_and_validates():
    devs = make_serving_mesh(5)
    assert len(devs) == 5
    live = jax.devices()
    assert [d.id for d in devs] == [live[i % len(live)].id for i in range(5)]
    assert len(make_serving_mesh()) == len(live)
    with pytest.raises(ValueError):
        make_serving_mesh(0)


# -------------------------------------------------------- real multi-device
def test_replica_banks_land_on_distinct_devices(kernels, multi_device):
    """With real (fake-host) devices, each replica's working set is
    committed to its own device and execution happens there."""
    n = min(multi_device, 4)
    srv = ShardedOverlayServer(n_replicas=n, bank_capacity=4)
    ids = [next(iter(b.op.devices())).id for b in srv.banks]
    assert len(set(ids)) == n
    work = _zipf_workload(kernels, 12, seed=3)
    tickets = {srv.submit(k, xs, tenant=t): (t, k, xs)
               for t, k, xs in work}
    got = srv.flush()
    assert set(got) == set(tickets)
    # every replica that served traffic produced results on its own device
    for t in tickets:
        rep = srv._owner.get(t)
        assert rep is None or rep[0] < n
