"""Table I reproduction: cycle-accurate schedule of the 'gradient' kernel."""

from repro.core.paper_bench import gradient
from repro.core.schedule import schedule

#: published (cycle, fu, activity) anchor points from Table I
ANCHORS = [
    (1, 0, "Load R0"), (5, 0, "Load R4"), (6, 0, "SUB (R0 R2)"),
    (8, 0, "SUB (R2 R3)"), (8, 1, "Load R0"), (12, 1, "SQR (R0 R0)"),
    (14, 2, "Load R0"), (18, 2, "ADD (R0 R1)"), (20, 3, "Load R0"),
    (22, 3, "ADD (R0 R1)"), (12, 0, "Load R0"), (23, 0, "Load R0"),
]


def run():
    sch = schedule(gradient())
    rows = dict(sch.cycle_trace(n_iters=3))
    checks = []
    for cyc, fu, act in ANCHORS:
        got = rows.get(cyc, {}).get(fu)
        checks.append((cyc, fu, act, got, got == act))
    return sch, checks


def main():
    sch, checks = run()
    print(f"gradient: II={sch.ii} single_fu_II={sch.single_fu_ii} "
          f"spatial_FUs={sch.spatial_fus} tm_FUs={sch.n_fus}")
    print("cycle,fu,expected,got,match")
    for c in checks:
        print(",".join(str(x) for x in c))
    assert sch.ii == 11 and sch.single_fu_ii == 17 and sch.spatial_fus == 11
    assert all(c[-1] for c in checks), "Table I trace mismatch"


if __name__ == "__main__":
    main()
