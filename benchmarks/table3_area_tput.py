"""Table III reproduction: area (e-Slices) + throughput (GOPS) + the
published SCFU-SCN / Vivado-HLS comparison columns."""

from repro.core.area import (PAPER_BY_NAME, area_eslices, mops_per_eslice,
                             throughput_gops)
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.core.schedule import schedule


def run():
    rows = []
    for name in BENCH_NAMES:
        sch = schedule(benchmark(name))
        row = PAPER_BY_NAME[name]
        tput = throughput_gops(row.ops, sch.ii)
        area = area_eslices(sch.n_fus)
        ok = (area == row.area_eslices and abs(tput - row.tput_gops) < 5e-3)
        rows.append((name, round(tput, 2), area, row.scfu_tput,
                     row.scfu_area, row.hls_tput, row.hls_area,
                     round(100 * (1 - area / row.scfu_area), 1),
                     round(row.scfu_tput / tput, 1),
                     round(mops_per_eslice(row.ops, sch.ii, sch.n_fus), 2),
                     "EXACT" if ok else "MISMATCH"))
    return ("name,tput_gops,area_eslices,scfu_tput,scfu_area,hls_tput,"
            "hls_area,area_savings_pct,tput_gap_x,mops_per_eslice,match"
            ).split(","), rows


def main():
    header, rows = run()
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    assert all(r[-1] == "EXACT" for r in rows), "Table III mismatch"
    savings = [r[7] for r in rows]
    gaps = [r[8] for r in rows]
    # paper: up to 85% fewer e-Slices; throughput 6x-18x lower
    assert max(savings) > 84.0, savings
    assert 5.9 < min(gaps) and max(gaps) < 21, gaps


if __name__ == "__main__":
    main()
