"""Fig. 5 reproduction: FUs required, proposed TM overlay vs SCFU-SCN.

The proposed overlay needs #FUs = graph depth (one per ASAP stage); a
spatially-configured overlay needs one FU per op node.  The paper reports
'up to 63%' FU reduction; exact per-benchmark SCFU FU counts are only
plotted (Fig. 5), so we derive them as op nodes (one FU per operation,
the SCFU-SCN definition in Section I) and report the reduction.
Pipelines longer than 8 FUs cascade two 8-FU pipelines (Section V).
"""

from repro.core.area import pipelines_needed
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.core.schedule import schedule


def run():
    rows = []
    for name in BENCH_NAMES:
        sch = schedule(benchmark(name))
        tm, sp = sch.n_fus, sch.spatial_fus
        rows.append((name, tm, sp, round(100 * (1 - tm / sp), 1),
                     pipelines_needed(tm)))
    return ("name,tm_fus,scfu_fus,reduction_pct,pipelines").split(","), rows


def main():
    header, rows = run()
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    # paper: significant reduction, >8-FU benchmarks cascade 2 pipelines
    assert max(r[3] for r in rows) >= 60.0
    assert all((r[4] == 2) == (r[1] > 8) for r in rows)


if __name__ == "__main__":
    main()
