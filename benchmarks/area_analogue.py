"""The 'area' analogue on TPU: compiled-code size of time-multiplexed vs
spatial execution.

Two levels:
  1. overlay kernels — the TM executor (one compiled program for ALL
     kernels) vs one inlined XLA program per kernel (SCFU analogue);
     metric: HLO ops + executable bytes + compile seconds.
  2. LM stacks — scan (tm) vs unrolled (spatial) deepseek-7b-smoke
     forward: HLO ops and compile time vs layer count.
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def _hlo_ops(compiled) -> int:
    return sum(1 for line in compiled.as_text().splitlines()
               if "=" in line and not line.lstrip().startswith(("//", "ENTRY",
                                                                "HloModule")))


def _exe_bytes(compiled) -> int:
    try:
        m = compiled.memory_analysis()
        return int(getattr(m, "generated_code_size_in_bytes", 0))
    except Exception:
        return 0


def run_overlay_level():
    from repro.core.overlay import compile_program, spatial_jit
    from repro.core.paper_bench import BENCH_NAMES, benchmark
    from repro.core.vm import make_context, vm_exec, pad_inputs

    xs = pad_inputs([jnp.zeros(256, jnp.float32)] * 8)
    # TM executor compiled once
    ctx = make_context(compile_program(benchmark("chebyshev")).program)
    t0 = time.perf_counter()
    tm_compiled = jax.jit(
        lambda tree, oi, x: vm_exec(tree, oi, x)).lower(
        ctx.tree(), ctx.out_idx, xs).compile()
    t_tm = time.perf_counter() - t0
    tm_ops = _hlo_ops(tm_compiled)
    rows = [("tm_executor(all kernels)", tm_ops, round(t_tm, 3))]
    total_sp_ops = 0
    total_sp_t = 0.0
    for name in BENCH_NAMES:
        dfg = benchmark(name)
        xs_n = [jnp.zeros(256, jnp.float32)] * len(dfg.inputs)
        t0 = time.perf_counter()
        from repro.core.vm import dfg_eval
        sp = jax.jit(lambda *a: [dfg_eval(dfg, dict(zip(dfg.inputs, a)))[o]
                                 for o in dfg.outputs]).lower(*xs_n).compile()
        t_sp = time.perf_counter() - t0
        ops = _hlo_ops(sp)
        total_sp_ops += ops
        total_sp_t += t_sp
        rows.append((f"spatial:{name}", ops, round(t_sp, 3)))
    rows.append(("spatial:TOTAL(8 kernels)", total_sp_ops,
                 round(total_sp_t, 3)))
    return rows, tm_ops, total_sp_ops


def run_lm_level():
    from repro.configs import get_smoke_config
    from repro.models import forward, init_params

    cfg = get_smoke_config("deepseek-7b")
    cfg = dataclasses.replace(
        cfg, stacks=(dataclasses.replace(cfg.stacks[0], count=8),))
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((2, 32), jnp.int32)
    out = []
    for mode, scan in (("tm(scan)", True), ("spatial(unroll)", False)):
        c = dataclasses.replace(cfg, scan_layers=scan)
        t0 = time.perf_counter()
        comp = jax.jit(lambda p, t: forward(c, p, t)[0]).lower(
            params, toks).compile()
        dt = time.perf_counter() - t0
        out.append((f"lm8:{mode}", _hlo_ops(comp), round(dt, 3)))
    return out


def main():
    rows, tm_ops, sp_ops = run_overlay_level()
    rows += run_lm_level()
    print("name,hlo_ops,compile_s")
    for r in rows:
        print(",".join(str(x) for x in r))
    red = 100 * (1 - tm_ops / sp_ops)
    print(f"# overlay-level 'area' reduction (one TM executor vs 8 spatial "
          f"programs): {red:.1f}% fewer HLO ops")
    lm = {r[0]: r for r in rows if r[0].startswith("lm8")}
    lm_red = 100 * (1 - lm["lm8:tm(scan)"][1] / lm["lm8:spatial(unroll)"][1])
    print(f"# lm-level HLO reduction (scan vs unroll, 8 layers): "
          f"{lm_red:.1f}%")


if __name__ == "__main__":
    main()
