"""SLO scheduling study: per-flow quanta as SLO classes under contention.

The paper's time-multiplexed functional units share one datapath across
many logical operations; the serving analogue shares one round-forming
engine across tenants with very different latency needs.  This study
asks whether the scheduler's per-flow deficit quanta
(``DeficitRoundRobin(tenant_quanta=...)``) can carve real SLO classes
out of one contended engine:

- a LATENCY tier (``lat0``, ``lat1``): small requests, a large per-flow
  quantum (the whole backlog clears into the next round or two), and a
  tight delivery SLO;
- a preemptible BULK tier (``bulk0``, ``bulk1``): bigger requests, more
  of them, a small quantum (the backlog trickles through without
  crowding the rounds), and a loose SLO.

The sweep crosses the base DRR ``quantum_tiles`` with the latency
tier's quantum multiplier (1x = flat/no classes, the control arm) and
adds ``DynamicTilePolicy`` AIMD round-budget targets on top of the
tiered quanta.  Every configuration serves the SAME interleaved
workload; SLO targets are calibrated from the flat control arm's wall
(so attainment measures scheduling, not machine speed).  Per-config
rows stream to ``--jsonl`` (one JSON line each); ``--json`` gets the
summary row for the bench trajectory ledger (headline:
``slo_attainment`` percent, best config).

Asserted: under the best tiered config the latency tier's p99 beats
the bulk tier's p99 (x ``--tolerance``), and beats its own p99 under
the flat control arm — the quanta, not luck, buy the tier its SLO.

Run: PYTHONPATH=src python -m benchmarks.slo_study [--smoke] \
         [--json artifacts/bench/slo.json] \
         [--jsonl artifacts/bench/slo_configs.jsonl]
Reading the output: docs/TELEMETRY.md#reading-the-slo-study.
"""

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core.overlay import compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.launch.serve import OverlayServer, tenant_latency_summary
from repro.sched import DeficitRoundRobin, DynamicTilePolicy

LAT_TENANTS = ("lat0", "lat1")
BULK_TENANTS = ("bulk0", "bulk1")
LAT_BATCH = 64
BULK_BATCH = 256
#: SLO targets as fractions of the flat control arm's drain wall: the
#: latency tier must clear well before a fair-share drain would finish
#: (tight enough that the FLAT arm misses it — attainment has to be
#: bought by the quanta); the bulk tier only has to finish within a
#: relaxed envelope
LAT_SLO_FRACTION = 0.4
BULK_SLO_FRACTION = 1.5
#: timed repetitions per config; percentiles use the median rep
REPS = 3


def _workload(kernels, lat_per_tenant, bulk_per_tenant, seed=0):
    """Interleaved contention mix: every latency-tier request queues
    behind bulk traffic unless the scheduler's quanta intervene.

    Each tenant streams ONE dedicated kernel.  That keeps every round's
    distinct-kernel budget (``round_kernels``) shared across tiers, so
    both tiers are serviced in (almost) every round and the per-flow
    quantum — not kernel-slot luck — decides each tier's share.  Bulk
    requests are bigger (more dispatch tiles) and more numerous, so the
    drain is many rounds deep: the contention the latency tier's SLO
    has to survive.
    """
    rng = np.random.RandomState(seed)
    names = list(kernels)
    tenant_kernel = {t: names[i % len(names)]
                     for i, t in enumerate(BULK_TENANTS + LAT_TENANTS)}
    plan = []
    n = max(lat_per_tenant, bulk_per_tenant)
    for j in range(n):
        for tenant in BULK_TENANTS:
            if j < bulk_per_tenant:
                k = kernels[tenant_kernel[tenant]]
                xs = [rng.uniform(-2, 2, (BULK_BATCH,)).astype(np.float32)
                      for _ in k.dfg.inputs]
                plan.append((tenant, k, xs))
        for tenant in LAT_TENANTS:
            if j < lat_per_tenant:
                k = kernels[tenant_kernel[tenant]]
                xs = [rng.uniform(-2, 2, (LAT_BATCH,)).astype(np.float32)
                      for _ in k.dfg.inputs]
                plan.append((tenant, k, xs))
    return plan


def _policy(cfg):
    quanta = {t: cfg["quantum_tiles"] * cfg["lat_quantum_mult"]
              for t in LAT_TENANTS}
    if cfg["policy"] == "dynamic":
        return DynamicTilePolicy(quantum_tiles=cfg["quantum_tiles"],
                                 target_latency_s=cfg["target_latency_s"],
                                 tenant_quanta=quanta)
    return DeficitRoundRobin(quantum_tiles=cfg["quantum_tiles"],
                             tenant_quanta=quanta)


def _tier(tenant):
    return "latency" if tenant.startswith("lat") else "bulk"


def run_config(cfg, kernels, workload):
    """Serve the workload under one scheduler config; returns the row.

    One warmup drain (compiles + residency), then ``REPS`` timed drains;
    latency samples pool across timed reps (median-rep behaviour without
    single-rep noise), pooled BY TIER for the headline percentiles.
    """
    srv = OverlayServer(bank_capacity=len(kernels), round_kernels=2,
                        max_inflight=2, round_policy=_policy(cfg))
    for tenant, k, xs in workload:          # warmup: compile the buckets
        srv.submit(k, xs, tenant=tenant)
    srv.flush()
    srv.reset_metrics()
    walls, samples = [], []
    for _rep in range(REPS):
        srv.reset_metrics()
        for tenant, k, xs in workload:
            srv.submit(k, xs, tenant=tenant)
        t0 = time.perf_counter()
        results = srv.flush()
        jax.block_until_ready([y for ys in results.values() for y in ys])
        walls.append(time.perf_counter() - t0)
        samples.extend(srv.tenant_latencies())
    tiered = tenant_latency_summary(
        ((_tier(t), lat) for t, lat in samples),
        slo_s={"latency": cfg["lat_slo_s"], "bulk": cfg["bulk_slo_s"]})
    lat, bulk = tiered["latency"], tiered["bulk"]
    attained = lat["slo_attained"] + bulk["slo_attained"]
    total = lat["slo_total"] + bulk["slo_total"]
    return {
        **{k: v for k, v in cfg.items()},
        "wall_s": float(np.median(walls)),
        "rounds_per_drain": srv.n_rounds // (REPS + 1),
        "latency_p50_ms": lat["p50"] * 1e3,
        "latency_p99_ms": lat["p99"] * 1e3,
        "bulk_p99_ms": bulk["p99"] * 1e3,
        "latency_slo_attainment": lat["slo_attainment"],
        "bulk_slo_attainment": bulk["slo_attainment"],
        "slo_attainment": 100.0 * attained / total,
        "requests_per_drain": len(workload),
    }


def sweep_configs(smoke):
    """The config grid; the FIRST entry is the flat control arm (no SLO
    classes) — its wall calibrates every config's SLO targets and its
    latency p99 is the bar the tiered arms must beat."""
    if smoke:
        grid = [("drr", 2.0, 1.0, None),
                ("drr", 2.0, 16.0, None),
                ("dynamic", 2.0, 16.0, 0.1)]
    else:
        grid = [("drr", 2.0, 1.0, None)]
        for q in (2.0, 4.0):
            for mult in (8.0, 16.0):
                grid.append(("drr", q, mult, None))
        for tgt in (0.05, 0.2):
            grid.append(("dynamic", 2.0, 16.0, tgt))
    return [{"policy": p, "quantum_tiles": q, "lat_quantum_mult": m,
             "target_latency_s": t} for p, q, m, t in grid]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload + 3-config sweep for CI")
    ap.add_argument("--tolerance", type=float, default=1.0,
                    help="win-assertion slack on noisy shared runners")
    ap.add_argument("--json", default=None,
                    help="dump the summary row (best config) to this path")
    ap.add_argument("--jsonl", default=None,
                    help="stream one JSON line per swept config here")
    args = ap.parse_args(argv)
    kernels = {n: compile_program(benchmark(n))
               for n in BENCH_NAMES + ("gradient",)}
    lat_n, bulk_n = (8, 16) if args.smoke else (12, 24)
    workload = _workload(kernels, lat_n, bulk_n)
    configs = sweep_configs(args.smoke)

    # calibrate SLO targets from the flat control arm's wall, then
    # re-run every config (control included) against those fixed targets
    cal = dict(configs[0], lat_slo_s=float("inf"), bulk_slo_s=float("inf"))
    flat_wall = run_config(cal, kernels, workload)["wall_s"]
    lat_slo = flat_wall * LAT_SLO_FRACTION
    bulk_slo = flat_wall * BULK_SLO_FRACTION
    print(f"# SLO targets calibrated from flat-arm wall {flat_wall:.4f}s: "
          f"latency tier {lat_slo * 1e3:.1f}ms, "
          f"bulk tier {bulk_slo * 1e3:.1f}ms")

    jsonl_f = None
    if args.jsonl:
        os.makedirs(os.path.dirname(args.jsonl) or ".", exist_ok=True)
        jsonl_f = open(args.jsonl, "w")
    rows = []
    print("policy,quantum_tiles,lat_quantum,target_latency_s,wall_s,"
          "lat_p99_ms,bulk_p99_ms,lat_slo_att,bulk_slo_att,slo_attainment")
    for cfg in configs:
        row = run_config(dict(cfg, lat_slo_s=lat_slo, bulk_slo_s=bulk_slo),
                         kernels, workload)
        rows.append(row)
        print(f"{row['policy']},{row['quantum_tiles']:.0f},"
              f"{row['lat_quantum_mult']:.0f},{row['target_latency_s']},"
              f"{row['wall_s']:.4f},{row['latency_p99_ms']:.2f},"
              f"{row['bulk_p99_ms']:.2f},{row['latency_slo_attainment']:.2f},"
              f"{row['bulk_slo_attainment']:.2f},{row['slo_attainment']:.1f}")
        if jsonl_f:
            jsonl_f.write(json.dumps(row, sort_keys=True) + "\n")
            jsonl_f.flush()
    if jsonl_f:
        jsonl_f.close()
        print(f"# wrote {len(rows)} config rows to {args.jsonl}")

    flat = rows[0]
    tiered = [r for r in rows[1:] if r["lat_quantum_mult"] > 1.0]
    best = max(tiered, key=lambda r: (r["slo_attainment"],
                                      -r["latency_p99_ms"]))
    summary = {
        "slo_attainment": best["slo_attainment"],
        "latency_p99_ms": best["latency_p99_ms"],
        "bulk_p99_ms": best["bulk_p99_ms"],
        "flat_latency_p99_ms": flat["latency_p99_ms"],
        "flat_slo_attainment": flat["slo_attainment"],
        "policy": best["policy"],
        "quantum_tiles": best["quantum_tiles"],
        "lat_quantum": best["lat_quantum_mult"],
        "lat_slo_ms": lat_slo * 1e3,
        "bulk_slo_ms": bulk_slo * 1e3,
        "configs": len(rows),
        "requests_per_drain": len(workload),
    }
    print(f"# best tiered config: {best['policy']} "
          f"quantum={best['quantum_tiles']:.0f} "
          f"lat_quantum={best['lat_quantum_mult']:.0f}x -> "
          f"slo_attainment {best['slo_attainment']:.1f}% "
          f"(flat control {flat['slo_attainment']:.1f}%); latency-tier "
          f"p99 {best['latency_p99_ms']:.2f}ms vs bulk "
          f"{best['bulk_p99_ms']:.2f}ms "
          f"({best['bulk_p99_ms'] / best['latency_p99_ms']:.1f}x) vs flat "
          f"latency p99 {flat['latency_p99_ms']:.2f}ms")
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"# wrote {args.json}")
    assert best["latency_p99_ms"] < best["bulk_p99_ms"] * args.tolerance, (
        "latency tier's p99 did not beat the bulk tier's under contention",
        best["latency_p99_ms"], best["bulk_p99_ms"], args.tolerance)
    assert (best["latency_p99_ms"]
            < flat["latency_p99_ms"] * args.tolerance), (
        "tiered quanta did not improve the latency tier over the flat arm",
        best["latency_p99_ms"], flat["latency_p99_ms"], args.tolerance)


if __name__ == "__main__":
    main()
