"""Context-switch comparison (paper Section V).

Model quantities: context bytes + daisy-chain cycles + time @300 MHz per
benchmark, vs the published SCFU-SCN (13 us) and partial-reconfiguration
(200 us) costs.

Measured quantities (this host): swapping a kernel on the live overlay
executor (new instruction buffers, NO recompilation) vs the vendor-flow
analogue (fresh XLA trace+compile of the inlined DFG).
"""

import time

import jax
import numpy as np

from repro.core import area
from repro.core.overlay import (Overlay, compile_program, spatial_jit,
                                time_recompile)
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.core.schedule import schedule
from repro.core.isa import encode


def run():
    rows = []
    ov = Overlay()
    kernels = {n: compile_program(benchmark(n)) for n in BENCH_NAMES}
    # warm the executor once (the overlay 'bitstream' compile)
    xs = [np.zeros(256, np.float32)] * 8
    k0 = kernels["chebyshev"]
    ov(ov.load(k0), xs[: len(k0.dfg.inputs)])
    for name in BENCH_NAMES:
        k = kernels[name]
        prog = k.program
        swap_s = ov.time_context_switch(k)
        t0 = time.perf_counter()
        ov(ov.load(k), xs[: len(k.dfg.inputs)])
        swap_and_run_s = time.perf_counter() - t0
        recompile_s = time_recompile(
            k.dfg, xs[: len(k.dfg.inputs)], iters=2)
        rows.append((name, prog.context_bytes,
                     prog.context_switch_cycles(),
                     round(prog.context_switch_us(), 3),
                     round(swap_s * 1e6, 1),
                     round(swap_and_run_s * 1e6, 1),
                     round(recompile_s * 1e6, 1),
                     round(recompile_s / max(swap_and_run_s, 1e-9), 1)))
    return ("name,ctx_bytes,ctx_cycles,model_us@300MHz,measured_swap_us,"
            "swap_and_run_us,xla_recompile_us,speedup_x").split(","), rows


def main():
    header, rows = run()
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    worst_model_us = max(r[3] for r in rows)
    print(f"# paper: worst-case 0.27us @300MHz; ours {worst_model_us}us")
    print(f"# published comparisons: SCFU-SCN {area.SCFU_CONTEXT_US}us, "
          f"PR {area.PR_CONTEXT_US}us")
    assert worst_model_us < 0.35
    # swap+run must beat recompile+run; swap alone beats compile by >>10x
    assert all(r[7] > 2 for r in rows), [r[7] for r in rows]
    swap_only = max(r[4] for r in rows)
    compile_only = min(r[6] for r in rows)
    print(f"# swap-only vs compile-only: {compile_only / swap_only:.0f}x")
    assert compile_only / swap_only > 10


if __name__ == "__main__":
    main()
