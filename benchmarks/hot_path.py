"""Hot-path anatomy: paired legacy-vs-arena study of the round pipeline.

The device launch of a serving round is ONE fused executable; everything
else a round pays is host-side copying around it — the "overlay tax" that
dominates when contexts are cheap (cf. the JIT-assembly overlay line,
arXiv:1603.01187).  PR 9 rebuilt that host half zero-copy:

* ``assemble``: single-pass scatter into a pooled ``RoundArena`` block
  (vs the seed's per-group ``np.zeros`` + ``np.concatenate`` +
  ``reshape().transpose()`` copies, kept as ``assemble_reference``);
* ``execute``: batch already device-resident (no redundant
  ``device_put``), tile stack DONATED to the executable;
* ``collect``: live tiles/rows sliced device-side, one transfer,
  per-request numpy views (vs ``collect_reference``'s full padded
  readback + per-row ``ascontiguousarray`` copies).

This study times the two arms STAGE BY STAGE on identical workloads and
enforces the PR's acceptance bar:

* the arena path strictly beats the legacy path on the combined
  assemble+collect wall at tile=128, G >= 32 (``--tolerance`` adds CI
  jitter slack);
* ZERO executable retraces after warmup (cache sizes of
  ``vm_exec_multi``/``vm_exec_multi_donated``/``_gather_live`` frozen);
* bit parity vs the ``dispatch`` oracle on every measured round.

Headline metric ``hotpath_rps`` (engine-level flush throughput through
the arena+donation pipeline) feeds ``tools/bench_trajectory.py``.

Run: PYTHONPATH=src python -m benchmarks.hot_path
     PYTHONPATH=src python -m benchmarks.hot_path --smoke \
         --json artifacts/bench/hot_path.json --tolerance 0.25
Reading the output: docs/ARCHITECTURE.md#hot-path-anatomy.
"""

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core import overlay as overlay_mod
from repro.core import vm
from repro.core.arena import RoundArena
from repro.core.isa import RF_DEPTH
from repro.core.overlay import Overlay, compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.launch.serve import OverlayServer

TILE = 128


def _kernels():
    names = list(BENCH_NAMES) + ["gradient"]
    return {n: compile_program(benchmark(n)) for n in names}


def _workload(kernels, n_requests, req_batch, seed=0):
    """Mixed-kernel requests; round-robin kernels so groups merge."""
    rng = np.random.RandomState(seed)
    names = list(kernels)
    reqs = []
    for i in range(n_requests):
        k = kernels[names[i % len(names)]]
        reqs.append((k, [rng.uniform(-2, 2, (req_batch,)).astype(np.float32)
                         for _ in k.dfg.inputs]))
    return reqs


def _bit_equal(got, want):
    for g, w in zip(got, want):
        for a, b in zip(g, w):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                return False
    return True


def _cache_sizes():
    return (vm.vm_exec_multi._cache_size(),
            vm.vm_exec_multi_donated._cache_size(),
            overlay_mod._gather_live._cache_size())


def paired_stage_study(kernels, n_requests, req_batch, iters):
    """Time plan/assemble/execute/collect for both arms on one workload."""
    arena_ov = Overlay(arena=RoundArena(), donate=True)
    legacy_ov = Overlay()
    bank = arena_ov.load_many(kernels.values(), capacity=len(kernels))
    reqs = _workload(kernels, n_requests, req_batch)

    # --- warmup: compile every executable bucket both arms will touch
    p = arena_ov.plan(bank, reqs, tile=TILE)
    g_total, g_pad = p.g_total, p.g_pad
    assert g_total >= 32, (
        f"study needs G >= 32 live tiles at tile={TILE}, got {g_total}; "
        f"raise --requests/--req-batch")
    ys = arena_ov.execute(bank, arena_ov.assemble(p))
    jax.block_until_ready(ys)
    arena_ov.collect(p, ys, host=True)
    p.release(bank)
    p = legacy_ov.plan(bank, reqs, tile=TILE)
    ys = legacy_ov.execute(bank, legacy_ov.assemble_reference(p))
    jax.block_until_ready(ys)
    legacy_ov.collect_reference(p, ys, host=True)

    # --- oracle parity: the zero-copy pipeline vs the dispatch oracle
    oracle = legacy_ov.dispatch(bank, reqs, tile=TILE)
    p = arena_ov.plan(bank, reqs, tile=TILE)
    ys = arena_ov.execute(bank, arena_ov.assemble(p))
    jax.block_until_ready(ys)
    got = arena_ov.collect(p, ys, host=True)
    p.release(bank)
    parity = _bit_equal(got, oracle)
    assert parity, "arena pipeline diverged from the dispatch oracle"

    caches0 = _cache_sizes()
    walls = {arm: {"assemble": [], "execute": [], "collect": []}
             for arm in ("legacy", "arena")}
    for _ in range(iters):
        # legacy arm: reference assemble/collect, non-donating execute
        pl_ = legacy_ov.plan(bank, reqs, tile=TILE)
        t0 = time.perf_counter()
        batch = legacy_ov.assemble_reference(pl_)
        t1 = time.perf_counter()
        ys = legacy_ov.execute(bank, batch)
        jax.block_until_ready(ys)
        t2 = time.perf_counter()
        legacy_ov.collect_reference(pl_, ys, host=True)
        t3 = time.perf_counter()
        walls["legacy"]["assemble"].append(t1 - t0)
        walls["legacy"]["execute"].append(t2 - t1)
        walls["legacy"]["collect"].append(t3 - t2)

        # arena arm: pooled scatter, donated execute, live-rows collect
        pa = arena_ov.plan(bank, reqs, tile=TILE)
        t0 = time.perf_counter()
        batch = arena_ov.assemble(pa)
        t1 = time.perf_counter()
        ys = arena_ov.execute(bank, batch)
        jax.block_until_ready(ys)
        t2 = time.perf_counter()
        arena_ov.collect(pa, ys, host=True)
        t3 = time.perf_counter()
        pa.release(bank)
        walls["arena"]["assemble"].append(t1 - t0)
        walls["arena"]["execute"].append(t2 - t1)
        walls["arena"]["collect"].append(t3 - t2)
    retraces = sum(b - a for a, b in zip(caches0, _cache_sizes()))

    med = {arm: {st: float(np.median(ts)) for st, ts in stages.items()}
           for arm, stages in walls.items()}
    stack_bytes = g_pad * RF_DEPTH * TILE * 4
    return {
        "g_total": g_total, "g_pad": g_pad, "tile": TILE,
        "iters": iters, "parity": parity, "retraces": retraces,
        "legacy": med["legacy"], "arena": med["arena"],
        "assemble_speedup": med["legacy"]["assemble"] / med["arena"]["assemble"],
        "collect_speedup": med["legacy"]["collect"] / med["arena"]["collect"],
        "stage_speedup": ((med["legacy"]["assemble"] + med["legacy"]["collect"])
                          / (med["arena"]["assemble"] + med["arena"]["collect"])),
        "assemble_gbps": stack_bytes / med["arena"]["assemble"] / 1e9,
        "arena_stats": arena_ov.arena.stats(),
    }


def engine_throughput(kernels, n_requests, req_batch):
    """Headline: flush throughput through the arena+donation engine."""
    srv = OverlayServer(bank_capacity=len(kernels), tile=TILE,
                        round_kernels=max(1, len(kernels) // 2))
    names = list(kernels)
    rng = np.random.RandomState(1)
    def submit_all():
        for i in range(n_requests):
            k = kernels[names[i % len(names)]]
            xs = [rng.uniform(-2, 2, (req_batch,)).astype(np.float32)
                  for _ in k.dfg.inputs]
            srv.submit(k, xs)
    submit_all()
    srv.flush()                          # warmup: compile the buckets
    submit_all()
    t0 = time.perf_counter()
    srv.flush()
    wall = time.perf_counter() - t0
    s = srv.stats()
    assert s["arena"]["outstanding"] == 0, "engine leaked arena blocks"
    return n_requests / wall, s["stage_walls"]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=42,
                    help="requests per measured round")
    ap.add_argument("--req-batch", type=int, default=384,
                    help="per-request batch length")
    ap.add_argument("--iters", type=int, default=30,
                    help="measured repetitions per arm")
    ap.add_argument("--engine-requests", type=int, default=256,
                    help="requests for the engine-level rps headline")
    ap.add_argument("--tolerance", type=float, default=0.0,
                    help="slack on the arena-beats-legacy assertion: "
                         "arena wall must be < legacy * (1 + tolerance)")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink for CI (keeps G >= 32)")
    ap.add_argument("--json", dest="json_path", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests = min(args.requests, 21)
        args.req_batch = 256
        args.iters = min(args.iters, 8)
        args.engine_requests = min(args.engine_requests, 96)

    kernels = _kernels()
    row = paired_stage_study(kernels, args.requests, args.req_batch,
                             args.iters)
    rps, stage_walls = engine_throughput(kernels, args.engine_requests,
                                         args.req_batch)
    row["hotpath_rps"] = rps
    row["engine_stage_walls"] = stage_walls

    print(f"# hot path @ tile={row['tile']}  G={row['g_total']} live "
          f"({row['g_pad']} padded)  iters={row['iters']}")
    for st in ("assemble", "execute", "collect"):
        print(f"  {st:>9}: legacy {row['legacy'][st] * 1e3:8.3f} ms   "
              f"arena {row['arena'][st] * 1e3:8.3f} ms   "
              f"({row['legacy'][st] / row['arena'][st]:.2f}x)")
    print(f"  assemble+collect speedup: {row['stage_speedup']:.2f}x   "
          f"assemble {row['assemble_gbps']:.2f} GB/s")
    print(f"  retraces after warmup: {row['retraces']}   "
          f"oracle parity: {row['parity']}")
    print(f"  hotpath_rps: {row['hotpath_rps']:.1f}")

    # ------------------------------------------------- acceptance gates
    legacy_wall = row["legacy"]["assemble"] + row["legacy"]["collect"]
    arena_wall = row["arena"]["assemble"] + row["arena"]["collect"]
    assert arena_wall < legacy_wall * (1.0 + args.tolerance), (
        f"arena assemble+collect ({arena_wall * 1e3:.3f} ms) does not beat "
        f"legacy ({legacy_wall * 1e3:.3f} ms) within tolerance "
        f"{args.tolerance:.0%}")
    assert row["retraces"] == 0, (
        f"{row['retraces']} executable retraces after warmup")

    if args.json_path:
        os.makedirs(os.path.dirname(args.json_path) or ".", exist_ok=True)
        with open(args.json_path, "w") as f:
            json.dump(row, f, indent=1, default=float)
        print(f"# wrote {args.json_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
