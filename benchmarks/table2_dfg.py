"""Table II reproduction: DFG characteristics of the benchmark set."""

from repro.core.area import PAPER_BY_NAME
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.core.schedule import schedule


def run():
    rows = []
    header = ("name", "io", "edges", "ops", "depth", "par", "II", "eOPC",
              "match")
    for name in BENCH_NAMES:
        dfg = benchmark(name)
        sch = schedule(dfg)
        st = dfg.stats()
        row = PAPER_BY_NAME[name]
        ok = (st["io_nodes"] == (row.n_in, row.n_out)
              and st["graph_edges"] == row.edges
              and st["op_nodes"] == row.ops
              and st["graph_depth"] == row.depth
              and abs(st["average_parallelism"] - row.parallelism) < 0.02
              and sch.ii == row.ii
              and abs(sch.eopc - row.eopc) < 0.05)
        rows.append((name, f"{row.n_in}/{row.n_out}", st["graph_edges"],
                     st["op_nodes"], st["graph_depth"],
                     st["average_parallelism"], sch.ii, sch.eopc,
                     "EXACT" if ok else "MISMATCH"))
    return header, rows


def main():
    header, rows = run()
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    assert all(r[-1] == "EXACT" for r in rows), "Table II mismatch"


if __name__ == "__main__":
    main()
