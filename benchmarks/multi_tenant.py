"""Multi-tenant serving benchmark: context bank vs per-call load vs recompile.

The paper's area/switch argument at request scale: one resident executor
serving N kernels should beat (a) rebuilding + re-uploading a context per
request (``Overlay.load`` each call) and by orders of magnitude (b) the
vendor-flow analogue (``spatial_jit``: fresh XLA trace + compile per
kernel).  Reports requests/sec over a mixed-kernel workload.

``--percentiles`` runs the latency study instead: a >= 4-tenant mixed
workload served twice through identical round schedules — once with the
pipelined streaming drain (``OverlayServer.flush``: round N+1 assembles on
the host while round N executes on device) and once with the synchronous
barrier drain (``flush_sync``) — reporting wall-clock, p50/p95/p99
delivery latency, and Jain's fairness index over per-tenant mean latency.
The pipelined path must win on wall-clock (asserted).

``--replicas R`` runs the SHARDED study instead: the same skewed
multi-tenant zipf mix served by ``ShardedOverlayServer`` (R replicas,
each with its own device-pinned context bank + residency routing) vs the
single-bank ``OverlayServer`` with the SAME per-engine bank capacity.
Sharding's aggregate residency (R x capacity) absorbs the whole working
set while the single bank churns through evictions — the study reports
both throughputs, the residency hit-rate after warmup, and migration /
eviction counts, and can JSON-dump the row for the bench trajectory
(``--json``).  Set ``JAX_DEVICES=N`` to run against N fake host devices
(see tests/conftest.py); replicas wrap when there are fewer.

``--steal`` runs the WORK-STEALING study instead: a backlog aimed
entirely at one replica's resident kernels, drained twice through
identical fleets — once with the residency-only router (the backlogged
replica grinds alone while the rest idle) and once with the
work-stealing router (idle replicas pull whole queued kernel-groups,
contexts prefetched before the move, directory republished).  Stealing
must win on throughput (asserted, ``--tolerance`` slack) and every
stolen result must stay bit-identical to the single-bank oracle
(asserted).  Per-replica scheduling stats are printed for both arms.

``--autoscale`` runs the ELASTIC-FLEET study instead: a bursty arrival
trace (burst slices of offered load separated by idle lulls) served by
an autoscaled fleet (``PressureAutoscaler``: starts at 1 replica, grows
under queue pressure, drains idle replicas during lulls) vs a STATIC
fleet provisioned for peak (``--max-replicas`` everywhere, all slices).
The study prints the replica-count timeline, asserts the elastic fleet
uses STRICTLY fewer replica-slices (the paper's don't-provision-for-peak
argument at fleet level), asserts throughput within ``--tolerance`` of
static, and checks every elastic result bit-for-bit against the
single-bank oracle while the fleet resizes under the traffic.

``--policy {drr,coalesce,dynamic}`` swaps the round-formation policy
(``repro.sched.rounds``) under the serving studies.

Run: PYTHONPATH=src python -m benchmarks.multi_tenant [--percentiles]
     PYTHONPATH=src python -m benchmarks.multi_tenant --replicas 4 \
         --json artifacts/bench/sharded.json
     JAX_DEVICES=2 PYTHONPATH=src python -m benchmarks.multi_tenant \
         --steal --replicas 4 --json artifacts/bench/steal.json
     JAX_DEVICES=2 PYTHONPATH=src python -m benchmarks.multi_tenant \
         --autoscale --max-replicas 4 --json artifacts/bench/autoscale.json
Reading the output: docs/SERVING.md#reading-the-benchmark,
docs/SCHEDULING.md#the-stealing-study, and
docs/SCHEDULING.md#the-autoscaling-study.
"""

import argparse
import json
import os

# must run before jax initialises (mirrors tests/conftest.py)
_n = os.environ.get("JAX_DEVICES", "")
_FLAG = "--xla_force_host_platform_device_count"
if _n.isdigit() and int(_n) > 1 and _FLAG not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"{os.environ.get('XLA_FLAGS', '')} {_FLAG}={int(_n)}".strip())

import time

import jax
import numpy as np

from repro.core.overlay import (Overlay, compile_program, spatial_jit)
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.core import vm as vm_mod
from repro.launch.serve import OverlayServer, ShardedOverlayServer

REQ_BATCH = 256
N_REQUESTS = 36          # mixed round-robin over the 9 paper kernels
RECOMPILE_REQUESTS = 6   # XLA compile per request is ~seconds; sample it


def _workload(kernels, n_requests, seed=0):
    rng = np.random.RandomState(seed)
    names = list(kernels)
    reqs = []
    for i in range(n_requests):
        k = kernels[names[i % len(names)]]
        xs = [rng.uniform(-2, 2, (REQ_BATCH,)).astype(np.float32)
              for _ in k.dfg.inputs]
        reqs.append((k, xs))
    return reqs


def _block(outs):
    jax.block_until_ready([y for ys in outs for y in ys])


#: timed repetitions per path — the CI smoke job runs on noisy shared
#: runners, so a single timed rep would make the win-assertions flaky
TIMED_REPS = 3


def bench_bank(kernels, reqs) -> tuple[float, int]:
    srv = OverlayServer(bank_capacity=len(kernels))
    for k, xs in reqs:
        srv.submit(k, xs)
    _ = srv.flush()                      # warmup: compiles the bucket
    n0 = vm_mod.vm_exec_multi._cache_size()
    dts = []
    for _rep in range(TIMED_REPS):
        for k, xs in reqs:
            srv.submit(k, xs)
        t0 = time.perf_counter()
        results = srv.flush()
        _block(list(results.values()))
        dts.append(time.perf_counter() - t0)
    retraces = vm_mod.vm_exec_multi._cache_size() - n0
    return len(reqs) / sorted(dts)[len(dts) // 2], retraces


def bench_per_call_load(kernels, reqs) -> float:
    ov = Overlay()
    k0, xs0 = reqs[0]
    _block([ov(ov.load(k0), xs0)])       # warmup the single-context executor
    dts = []
    for _rep in range(TIMED_REPS):
        t0 = time.perf_counter()
        outs = [ov(ov.load(k), xs) for k, xs in reqs]
        _block(outs)
        dts.append(time.perf_counter() - t0)
    return len(reqs) / sorted(dts)[len(dts) // 2]


def bench_spatial_recompile(reqs) -> float:
    t0 = time.perf_counter()
    outs = []
    for k, xs in reqs[:RECOMPILE_REQUESTS]:
        fn = spatial_jit(k.dfg)          # fresh trace + XLA compile each time
        outs.append(fn(xs))
        fn._clear_cache()
    _block(outs)
    return RECOMPILE_REQUESTS / (time.perf_counter() - t0)


# ------------------------------------------------- latency percentile study
N_TENANTS = 6                        # acceptance bar asks for >= 4
PCT_BATCHES = (64, 128, 256)         # host-assembly-heavy request mix
PCT_REPS = 5                         # paired reps; min-wall comparison


def _tenant_workload(kernels, reqs_per_tenant=100, seed=0):
    """Multi-tenant mix: disjoint kernel subsets, varied request sizes.

    Many small requests make round assembly (host-side concat/pack) a real
    cost — exactly the work the pipelined drain hides under device
    execution and the synchronous drain serializes after its barrier.
    """
    rng = np.random.RandomState(seed)
    names = list(kernels)
    plan = []                      # (tenant, kernel, xs) in submission order
    for j in range(reqs_per_tenant):
        for t in range(N_TENANTS):
            subset = names[t::N_TENANTS]
            k = kernels[subset[j % len(subset)]]
            b = int(PCT_BATCHES[rng.randint(len(PCT_BATCHES))])
            xs = [rng.uniform(-2, 2, (b,)).astype(np.float32)
                  for _ in k.dfg.inputs]
            plan.append((f"tenant{t}", k, xs))
    return plan


def _make_server(kernels, policy=None):
    # bank holds every kernel (no eviction noise); rounds of 3 kernels so a
    # drain is several rounds deep — the pipelined path needs rounds to
    # overlap, the sync path pays a host/device barrier per round; the DRR
    # quantum splits each tenant's backlog across rounds
    return OverlayServer(bank_capacity=len(kernels), round_kernels=3,
                         max_inflight=3, quantum_tiles=48,
                         round_policy=policy)


def _jain(values) -> float:
    """Jain's fairness index: 1.0 = perfectly even across tenants."""
    x = np.asarray(list(values), np.float64)
    return float(x.sum() ** 2 / (len(x) * (x ** 2).sum()))


def _drain_metrics(srv, drain, workload) -> tuple[float, dict]:
    srv.reset_metrics()
    for tenant, k, xs in workload:
        srv.submit(k, xs, tenant=tenant)
    t0 = time.perf_counter()
    results = drain()
    _block(list(results.values()))
    wall = time.perf_counter() - t0
    per_tenant: dict[str, list] = {}
    for t, lat in srv.latencies().items():
        per_tenant.setdefault(srv.record(t)["tenant"], []).append(lat)
    pct = srv.latency_percentiles()
    return wall, {"p50_ms": pct["p50"] * 1e3, "p95_ms": pct["p95"] * 1e3,
                  "p99_ms": pct["p99"] * 1e3,
                  "fairness": _jain(np.mean(v)
                                    for v in per_tenant.values())}


def bench_latency(kernels, reqs_per_tenant=100, reps=PCT_REPS,
                  policy=None):
    """Paired pipelined-vs-sync drain study over one tenant workload.

    Reps alternate sync/pipelined so machine drift hits both equally; the
    wall-clock comparison uses best-of-reps (min), which isolates the
    structural cost difference from shared-runner noise.
    """
    workload = _tenant_workload(kernels, reqs_per_tenant)
    srv_pipe = _make_server(kernels, policy)
    srv_sync = _make_server(kernels, policy)
    for srv, drain in ((srv_pipe, srv_pipe.flush),
                       (srv_sync, srv_sync.flush_sync)):
        for tenant, k, xs in workload:   # warmup: compiles bucket family
            srv.submit(k, xs, tenant=tenant)
        drain()
    walls = {"pipelined": [], "sync": []}
    metrics = {"pipelined": [], "sync": []}
    for _rep in range(reps):
        for mode, srv, drain in (("sync", srv_sync, srv_sync.flush_sync),
                                 ("pipelined", srv_pipe, srv_pipe.flush)):
            wall, m = _drain_metrics(srv, drain, workload)
            walls[mode].append(wall)
            metrics[mode].append(m)
    rows = []
    rounds = {"pipelined": srv_pipe.n_rounds, "sync": srv_sync.n_rounds}
    for mode in ("pipelined", "sync"):
        # wall: best-of-reps (structural cost, noise-insensitive);
        # percentiles/fairness: median across reps (not just the last)
        med = {k: float(np.median([m[k] for m in metrics[mode]]))
               for k in metrics[mode][0]}
        rows.append({"mode": mode, "wall_s": min(walls[mode]), **med,
                     "requests": len(workload),
                     "rounds_per_drain": rounds[mode] // (reps + 1)})
    return rows


def percentiles_main(reqs_per_tenant=100, tolerance=1.0, policy=None):
    """Latency study; asserts ``pipe_wall < sync_wall * tolerance``.

    ``tolerance`` > 1 loosens the win assertion for noisy shared runners
    (CI smoke) where host and 'device' compete for the same few cores;
    keep the default strict 1.0 on dedicated hardware.
    """
    kernels = {n: compile_program(benchmark(n))
               for n in BENCH_NAMES + ("gradient",)}
    rows = bench_latency(kernels, reqs_per_tenant, policy=policy)
    print("mode,wall_s,p50_ms,p95_ms,p99_ms,fairness_index,requests,"
          "rounds_per_drain")
    for r in rows:
        print(f"{r['mode']},{r['wall_s']:.4f},{r['p50_ms']:.2f},"
              f"{r['p95_ms']:.2f},{r['p99_ms']:.2f},{r['fairness']:.3f},"
              f"{r['requests']},{r['rounds_per_drain']}")
    pipe, sync = rows
    print(f"# pipelined vs sync drain wall-clock (best of {PCT_REPS}): "
          f"{sync['wall_s'] / pipe['wall_s']:.2f}x "
          f"({N_TENANTS} tenants, {pipe['requests']} requests, "
          f"{pipe['rounds_per_drain']} rounds/drain)")
    assert pipe["wall_s"] < sync["wall_s"] * tolerance, (
        "pipelined drain did not beat synchronous drain",
        pipe["wall_s"], sync["wall_s"], tolerance)
    assert pipe["fairness"] > 0.5, ("tenant latency grossly unfair",
                                    pipe["fairness"])


# --------------------------------------------------------- sharded study
#: per-engine bank capacity for the sharded study: deliberately smaller
#: than the kernel family, so the single bank pays eviction churn while
#: R replicas' aggregate residency (R x capacity) absorbs the working set
SHARD_BANK_CAPACITY = 4
SHARD_TENANTS = 6
SHARD_BATCHES = (64, 128, 256)


def _zipf_workload(kernels, n_requests, n_tenants=SHARD_TENANTS,
                   s=1.3, seed=0):
    """Skewed multi-tenant mix: each tenant's kernel choice is zipf over
    its own rotation of the family, so a few (tenant, kernel) streams
    dominate — the traffic shape residency routing exists for."""
    rng = np.random.RandomState(seed)
    names = list(kernels)
    ranks = np.arange(1, len(names) + 1, dtype=np.float64)
    p = 1.0 / ranks ** s
    p /= p.sum()
    work = []
    for i in range(n_requests):
        t = i % n_tenants
        rot = names[t:] + names[:t]
        k = kernels[rot[rng.choice(len(names), p=p)]]
        b = int(SHARD_BATCHES[rng.randint(len(SHARD_BATCHES))])
        xs = [rng.uniform(-2, 2, (b,)).astype(np.float32)
              for _ in k.dfg.inputs]
        work.append((f"tenant{t}", k, xs))
    return work


def bench_sharded(kernels, replicas, n_requests=240, backend="jnp",
                  policy=None):
    """Paired sharded-vs-single throughput over one skewed workload.

    Both servers get identical per-engine knobs; the sharded fleet's only
    structural edges are aggregate residency and cross-replica round
    overlap.  Timed over ``TIMED_REPS`` reps, median wall.
    """
    work = _zipf_workload(kernels, n_requests)
    srv_sh = ShardedOverlayServer(
        n_replicas=replicas, bank_capacity=SHARD_BANK_CAPACITY,
        round_kernels=3, max_inflight=2, backend=backend,
        round_policy=policy)
    srv_1 = OverlayServer(bank_capacity=SHARD_BANK_CAPACITY,
                          round_kernels=3, max_inflight=2, backend=backend,
                          round_policy=policy)
    walls = {"sharded": [], "single": []}
    for srv, mode in ((srv_1, "single"), (srv_sh, "sharded")):
        for tenant, k, xs in work:          # warmup: compile + residency
            srv.submit(k, xs, tenant=tenant)
        _block(list(srv.flush().values()))
        srv.reset_metrics()
        for _rep in range(TIMED_REPS):
            # time submit + drain together: the sharded router does its
            # residency prefetch/context loads at submit time, the single
            # bank does the equivalent loads inside round planning — the
            # comparison is only fair if both phases are inside the clock
            t0 = time.perf_counter()
            for tenant, k, xs in work:
                srv.submit(k, xs, tenant=tenant)
            results = srv.flush()
            _block(list(results.values()))
            walls[mode].append(time.perf_counter() - t0)
    med = {m: sorted(w)[len(w) // 2] for m, w in walls.items()}
    st = srv_sh.stats()
    return {
        "replicas": replicas,
        "devices": jax.device_count(),
        "requests_per_drain": len(work),
        "sharded_rps": len(work) / med["sharded"],
        "single_rps": len(work) / med["single"],
        "speedup": med["single"] / med["sharded"],
        "residency_hit_rate": srv_sh.residency_hit_rate,
        "migrations": st["migrations"],
        "sharded_evictions": st["evictions"],
        "single_evictions": srv_1.bank.n_evictions,
    }


def sharded_main(replicas, n_requests=240, backend="jnp",
                 tolerance=1.0, json_path=None, policy=None):
    """Sharded study; asserts aggregate throughput >= single-bank baseline
    (x ``tolerance`` slack for noisy shared runners) and residency
    hit-rate > 0.9 after warmup."""
    kernels = {n: compile_program(benchmark(n))
               for n in BENCH_NAMES + ("gradient",)}
    row = bench_sharded(kernels, replicas, n_requests, backend, policy)
    print("replicas,devices,sharded_rps,single_rps,speedup,"
          "residency_hit_rate,migrations,sharded_evictions,single_evictions")
    print(f"{row['replicas']},{row['devices']},{row['sharded_rps']:.1f},"
          f"{row['single_rps']:.1f},{row['speedup']:.2f},"
          f"{row['residency_hit_rate']:.3f},{row['migrations']},"
          f"{row['sharded_evictions']},{row['single_evictions']}")
    print(f"# sharded ({row['replicas']} replicas on {row['devices']} "
          f"devices) vs single bank: {row['speedup']:.2f}x; residency "
          f"hit-rate {row['residency_hit_rate']:.1%} after warmup")
    if json_path:
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(row, f, indent=1)
        print(f"# wrote {json_path}")
    assert row["sharded_rps"] >= row["single_rps"] * tolerance, (
        "sharded fleet did not beat the single-bank baseline",
        row["sharded_rps"], row["single_rps"], tolerance)
    if replicas * SHARD_BANK_CAPACITY >= len(kernels):
        # aggregate residency covers the family: after warmup virtually
        # every request must route to a resident replica
        assert row["residency_hit_rate"] > 0.9, (
            "residency routing missed too often after warmup",
            row["residency_hit_rate"])
    else:
        # structurally capacity-starved (e.g. --replicas 2 x bank 4 < 9
        # kernels): some misses are unavoidable, only sanity-check
        print(f"# aggregate residency {replicas * SHARD_BANK_CAPACITY} < "
              f"{len(kernels)} kernels; 0.9 hit-rate bar not applicable")
        assert row["residency_hit_rate"] > 0.5, (
            "residency routing defeated even its capacity floor",
            row["residency_hit_rate"])


# ----------------------------------------------------- work-stealing study
#: fraction of the stealing-study burst aimed at the hot replica's
#: resident kernels; the rest spreads so the fleet is live but idle-ish
STEAL_HOT_FRACTION = 0.85
#: paired reps for the stealing study (best-of-reps comparison)
STEAL_REPS = 5


def _skew_workload(kernels, homes, n_requests, seed=0):
    """A burst aimed at the replica owning the most contexts: the
    traffic shape residency-only routing cannot rebalance (the backlog is
    already queued where the contexts live) and work stealing exists for."""
    rng = np.random.RandomState(seed)
    by_home: dict = {}
    for n, h in homes.items():
        by_home.setdefault(h, []).append(n)
    hot_rep, hot_names = max(by_home.items(), key=lambda kv: len(kv[1]))
    cold_names = [n for n, h in homes.items() if h != hot_rep]
    work = []
    for i in range(n_requests):
        if not cold_names or rng.uniform() < STEAL_HOT_FRACTION:
            name = hot_names[i % len(hot_names)]
        else:
            name = cold_names[i % len(cold_names)]
        k = kernels[name]
        b = int(SHARD_BATCHES[rng.randint(len(SHARD_BATCHES))])
        xs = [rng.uniform(-2, 2, (b,)).astype(np.float32)
              for _ in k.dfg.inputs]
        work.append((f"tenant{i % SHARD_TENANTS}", k, xs))
    return work, hot_rep


def bench_stealing(kernels, replicas, n_requests=240, backend="jnp",
                   policy=None):
    """Paired stealing-vs-residency-only study on a skewed backlog.

    Two identical fleets (migration disabled so stealing is the only
    rebalancer) serve the same burst; the residency-only fleet leaves
    the backlogged replica to grind alone.  The stealing arm's results
    are additionally checked bit-for-bit against the single-bank
    ``flush_sync`` oracle.  Arms interleave over ``STEAL_REPS`` reps and
    the comparison is BEST-of-reps (min wall) — time-sliced CI hosts
    make medians noisy.
    """
    from repro.launch.mesh import device_sharing

    def build(steal):
        # tight quantum + small rounds: the hot replica's backlog spans
        # MANY rounds (as a live multi-tenant server's does) instead of
        # being swallowed whole into max_inflight giant launches — queued
        # work must exist across drain passes for a thief to have
        # anything to pull
        return ShardedOverlayServer(
            n_replicas=replicas, bank_capacity=SHARD_BANK_CAPACITY,
            round_kernels=2, max_inflight=2, quantum_tiles=4.0,
            backend=backend, round_policy=policy, steal=steal,
            migrate_min_tiles=10 ** 9)

    srv_steal, srv_resid = build(True), build(False)
    # identical warmup -> identical homes -> identical workload per arm
    work = homes = None
    for srv in (srv_resid, srv_steal):
        for i, n in enumerate(kernels):
            srv.submit(kernels[n], [np.zeros(32, np.float32)
                                    for _ in kernels[n].dfg.inputs])
        srv.flush()
        h = {n: srv.directory.locate(kernels[n], srv.banks)
             for n in kernels}
        h = {n: r for n, r in h.items() if r is not None}
        if homes is None:
            homes = h
            work, hot_rep = _skew_workload(kernels, homes, n_requests)
        else:
            assert h == homes, "arms warmed to different homes"
    # oracle parity (and compile warmup) on the stealing arm
    oracle = OverlayServer(bank_capacity=max(16, len(kernels)))
    pairs = [(srv_steal.submit(k, xs, tenant=t),
              oracle.submit(k, xs, tenant=t)) for t, k, xs in work]
    got, want = srv_steal.flush(), oracle.flush_sync()
    for gt, ot in pairs:
        for y, w in zip(got[gt], want[ot]):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))
    warmup_steals = srv_steal.n_steals
    warmup_stolen = srv_steal.router.n_stolen_requests
    for t, k, xs in work:                    # warm the residency arm too
        srv_resid.submit(k, xs, tenant=t)
    srv_resid.flush()
    srv_steal.reset_metrics()
    srv_resid.reset_metrics()
    walls = {"steal": [], "residency": []}
    # arms interleave per rep (drift hits both) and the comparison uses
    # best-of-reps: oversubscribed CI hosts time-slice the fake devices,
    # so min wall isolates the structural difference like bench_latency
    for _rep in range(STEAL_REPS):
        for srv, mode in ((srv_resid, "residency"), (srv_steal, "steal")):
            t0 = time.perf_counter()
            for t, k, xs in work:
                srv.submit(k, xs, tenant=t)
            results = srv.flush()
            _block(list(results.values()))
            walls[mode].append(time.perf_counter() - t0)
    best = {m: min(w) for m, w in walls.items()}
    return {
        "replicas": replicas,
        "devices": jax.device_count(),
        "device_sharing": device_sharing(srv_steal.devices),
        "hot_replica": hot_rep,
        "hot_fraction": STEAL_HOT_FRACTION,
        "requests_per_drain": len(work),
        "steal_rps": len(work) / best["steal"],
        "residency_rps": len(work) / best["residency"],
        "speedup": best["residency"] / best["steal"],
        # steals concentrate in the first drains: each steal republishes
        # the group's directory entry to the thief, so follow-up submits
        # route there DIRECTLY — the fleet converges to balance and
        # steady-state drains need few or no further steals
        "steals_total": warmup_steals + srv_steal.n_steals,
        "steals_timed": srv_steal.n_steals,
        "warmup_steals": warmup_steals,
        "stolen_requests": (warmup_stolen
                            + srv_steal.router.n_stolen_requests),
        "hot_replica_share_steal": (
            srv_steal.replicas[hot_rep].n_requests
            / max(1, sum(r.n_requests for r in srv_steal.replicas))),
        "hot_replica_share_residency": (
            srv_resid.replicas[hot_rep].n_requests
            / max(1, sum(r.n_requests for r in srv_resid.replicas))),
        "stats_steal": srv_steal.stats(),
        "stats_residency": srv_resid.stats(),
    }


# ------------------------------------------------------ autoscaling study
#: the bursty trace: 1.0 = a burst slice (offered load), 0.0 = an idle
#: lull.  Bursts come in runs so pressure sustains across drain passes;
#: lulls come in runs so idle streaks can ripen into scale-downs.
AUTOSCALE_PATTERN = (1.0, 1.0, 1.0, 0.0, 0.0, 0.0,
                     1.0, 1.0, 0.0, 0.0, 1.0, 0.0)
#: idle autoscaler observations per lull slice (what a background pump's
#: poll ticks would deliver); a lull RUN must outlast AUTOSCALE_DOWN_ROUNDS
#: for scale-downs to ripen where they should — in the lulls, not inside
#: a burst's drain
AUTOSCALE_LULL_TICKS = 16
#: idle observations before a replica drains: larger than any burst
#: drain's trailing idle passes, smaller than one lull run's ticks
AUTOSCALE_DOWN_ROUNDS = 12
#: timed repetitions of the full trace per arm (best-of comparison)
AUTOSCALE_REPS = 3


def _bursty_trace(kernels, n_requests, seed=0):
    """Per-slice workloads for AUTOSCALE_PATTERN: zipf bursts and empty
    lulls — the load shape peak provisioning wastes replicas on."""
    n_bursts = sum(1 for p in AUTOSCALE_PATTERN if p > 0)
    per_burst = max(1, n_requests // n_bursts)
    slices, burst_i = [], 0
    for p in AUTOSCALE_PATTERN:
        if p > 0:
            slices.append(_zipf_workload(kernels, per_burst,
                                         seed=seed + 7 * burst_i))
            burst_i += 1
        else:
            slices.append([])
    return slices


def _serve_trace(srv, slices, timeline=None):
    """Serve one pass of the bursty trace; returns {ticket: outputs}.

    A burst slice models ARRIVAL OVER TIME, not one atomic batch: the
    slice's requests land in chunks with pump ticks between them (the
    drain edge a background ``AutoPump`` would drive), then the tail is
    flushed.  This matters for the elastic arm — a whole-burst ``flush``
    collapses into ~4 giant pipeline passes, starving the autoscaler of
    observations before the backlog is gone — and is identical work for
    the static arm.  Lull slices tick ``pump_once`` so an autoscaler
    sees the idleness.  ``timeline`` collects each slice's PEAK replica
    count — the capacity the slice actually consumed (conservative for
    the elastic arm: a replica alive for any part of a slice is charged
    for the whole slice)."""
    results = {}
    for sl in slices:
        srv.peak_replicas = srv.n_replicas     # per-slice high-water mark
        if sl:
            chunk = max(1, len(sl) // 6)
            for i in range(0, len(sl), chunk):
                for tenant, k, xs in sl[i:i + chunk]:
                    srv.submit(k, xs, tenant=tenant)
                srv.pump_once()
            results.update(srv.flush())
        else:
            for _ in range(AUTOSCALE_LULL_TICKS):
                srv.pump_once()
        if timeline is not None:
            timeline.append(srv.peak_replicas)
    return results


def bench_autoscale(kernels, max_replicas, n_requests=240, backend="jnp",
                    policy=None):
    """Paired elastic-vs-static study over one bursty trace.

    Both arms use the stealing router (a grown replica must be able to
    PULL queued work, not just catch new submits).  The elastic arm
    starts at one replica with a trigger-happy ``PressureAutoscaler``;
    the static arm holds ``max_replicas`` through every slice — the
    peak-provisioned baseline.  Bit parity vs the single-bank oracle is
    asserted on a full trace pass WHILE the fleet resizes.
    """
    from repro.sched import PressureAutoscaler

    slices = _bursty_trace(kernels, n_requests)

    def build(elastic):
        auto = PressureAutoscaler(
            up_tiles=8.0, up_rounds=2, down_rounds=AUTOSCALE_DOWN_ROUNDS,
            cooldown_s=0.0, min_replicas=1,
            max_replicas=max_replicas) if elastic else None
        return ShardedOverlayServer(
            n_replicas=1 if elastic else max_replicas,
            bank_capacity=SHARD_BANK_CAPACITY, round_kernels=2,
            max_inflight=2, quantum_tiles=4.0, backend=backend,
            round_policy=policy, steal=True, migrate_min_tiles=10 ** 9,
            autoscaler=auto)

    srv_el, srv_st = build(True), build(False)
    # parity pass (doubles as compile warmup): the elastic arm serves the
    # whole trace, scaling as it goes, against the single-bank oracle
    oracle = OverlayServer(bank_capacity=max(16, len(kernels)))
    pairs = []
    for sl in slices:
        for t, k, xs in sl:
            pairs.append((srv_el.submit(k, xs, tenant=t),
                          oracle.submit(k, xs, tenant=t)))
    got, want = srv_el.flush(), oracle.flush_sync()
    for gt, ot in pairs:
        for y, w in zip(got[gt], want[ot]):
            np.testing.assert_array_equal(np.asarray(y), np.asarray(w))
    warmup_ups = srv_el.n_scale_ups
    _serve_trace(srv_st, slices)              # static-arm warmup
    srv_el.reset_metrics()
    srv_st.reset_metrics()
    walls = {"elastic": [], "static": []}
    timelines: dict = {"elastic": [], "static": []}
    slice_counts = {"elastic": 0, "static": 0}
    for rep in range(AUTOSCALE_REPS):
        for srv, mode in ((srv_st, "static"), (srv_el, "elastic")):
            tl: list = []
            t0 = time.perf_counter()
            results = _serve_trace(srv, slices, timeline=tl)
            _block(list(results.values()))
            walls[mode].append(time.perf_counter() - t0)
            timelines[mode] = tl              # keep the LAST rep's timeline
    # replica-slices: integral of fleet size over the trace — what a
    # peak-provisioned fleet burns replicas on during every lull
    for mode in walls:
        slice_counts[mode] = sum(timelines[mode])
    best = {m: min(w) for m, w in walls.items()}
    n_reqs = sum(len(s) for s in slices)
    return {
        "max_replicas": max_replicas,
        "devices": jax.device_count(),
        "requests_per_trace": n_reqs,
        "slices": len(slices),
        "elastic_rps": n_reqs / best["elastic"],
        "static_rps": n_reqs / best["static"],
        "throughput_ratio": best["static"] / best["elastic"],
        "replica_timeline_elastic": timelines["elastic"],
        "replica_timeline_static": timelines["static"],
        "replica_slices_elastic": slice_counts["elastic"],
        "replica_slices_static": slice_counts["static"],
        "idle_replica_slices_saved": (slice_counts["static"]
                                      - slice_counts["elastic"]),
        "scale_ups": warmup_ups + srv_el.n_scale_ups,
        "scale_ups_timed": srv_el.n_scale_ups,
        "scale_downs_timed": srv_el.n_scale_downs,
        "evacuated_tiles": srv_el.n_evacuated_tiles,
        "evacuated_requests": srv_el.n_evacuated_requests,
        "replicas_retired": srv_el.n_replicas_retired,
        "stats_elastic": srv_el.stats(),
        "stats_static": srv_st.stats(),
    }


def autoscale_main(max_replicas, n_requests=240, backend="jnp",
                   tolerance=1.0, json_path=None, policy=None):
    """Autoscaling study; asserts the elastic fleet used STRICTLY fewer
    replica-slices than the static fleet, actually scaled both ways,
    kept throughput within ``tolerance`` of static, and (inside
    ``bench_autoscale``) stayed bit-identical to the single-bank oracle
    while resizing."""
    kernels = {n: compile_program(benchmark(n))
               for n in BENCH_NAMES + ("gradient",)}
    row = bench_autoscale(kernels, max_replicas, n_requests, backend,
                          policy)
    print("max_replicas,devices,elastic_rps,static_rps,throughput_ratio,"
          "replica_slices_elastic,replica_slices_static,scale_ups,"
          "scale_downs,evacuated_tiles")
    print(f"{row['max_replicas']},{row['devices']},"
          f"{row['elastic_rps']:.1f},{row['static_rps']:.1f},"
          f"{row['throughput_ratio']:.2f},"
          f"{row['replica_slices_elastic']},"
          f"{row['replica_slices_static']},{row['scale_ups']},"
          f"{row['scale_downs_timed']},{row['evacuated_tiles']}")
    print(f"# replica-count timeline (elastic): "
          f"{row['replica_timeline_elastic']} vs static "
          f"{row['replica_timeline_static']}")
    print(f"# elastic fleet used {row['replica_slices_elastic']} "
          f"replica-slices vs {row['replica_slices_static']} static — "
          f"{row['idle_replica_slices_saved']} idle replica-slices saved "
          f"({row['idle_replica_slices_saved'] / max(1, row['replica_slices_static']):.0%} "
          f"of the peak-provisioned budget) at "
          f"{row['throughput_ratio']:.2f}x static wall-clock; results "
          f"bit-identical to the single-bank oracle while scaling")
    _print_fleet_stats("elastic arm", row["stats_elastic"])
    _print_fleet_stats("static arm", row["stats_static"])
    st = row["stats_elastic"]
    print(f"# elastic telemetry: scale_ups={st['scale_ups']} "
          f"scale_downs={st['scale_downs']} "
          f"evacuated_tiles={st['evacuated_tiles']} "
          f"replicas_retired={st['replicas_retired']} "
          f"retired_lifetime_s={st['retired_lifetime_s']:.3f}")
    if json_path:
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        slim = {k: v for k, v in row.items()
                if k not in ("stats_elastic", "stats_static")}
        with open(json_path, "w") as f:
            json.dump(slim, f, indent=1)
        print(f"# wrote {json_path}")
    # TIMED counters: growth during the untimed warmup/parity pass must
    # not satisfy the claim that the autoscaler tracks the bursty trace
    assert row["scale_ups_timed"] >= 1, (
        "autoscaler never grew the fleet during the timed trace", row)
    assert row["scale_downs_timed"] >= 1, (
        "autoscaler never drained an idle replica", row)
    assert (row["replica_slices_elastic"]
            < row["replica_slices_static"]), (
        "elastic fleet did not save replica-slices vs peak provisioning",
        row["replica_slices_elastic"], row["replica_slices_static"])
    assert row["elastic_rps"] >= row["static_rps"] * tolerance, (
        "elastic fleet fell outside the throughput tolerance",
        row["elastic_rps"], row["static_rps"], tolerance)


def _print_fleet_stats(label, st):
    """The satellite telemetry: per-replica queue depth, residency
    hit/miss, rounds, steals — printed so the study is readable."""
    print(f"# {label}: rounds={st['rounds']} "
          f"hits={st['route_hits']} misses={st['route_misses']} "
          f"migrations={st['migrations']} steals={st['steals']}")
    for i, rep in enumerate(st["per_replica"]):
        print(f"#   replica {i}: rounds={rep['rounds']} "
              f"requests={rep['requests']} queued={rep['queued']} "
              f"queued_tiles={rep['queued_tiles']} "
              f"evictions={rep['evictions']} policy={rep['round_policy']}")


def stealing_main(replicas, n_requests=240, backend="jnp",
                  tolerance=1.0, json_path=None, policy=None):
    """Stealing study; asserts steal throughput >= residency-only
    (x ``tolerance`` slack), at least one steal actually happened, and
    (inside ``bench_stealing``) bit parity with the single-bank oracle."""
    kernels = {n: compile_program(benchmark(n))
               for n in BENCH_NAMES + ("gradient",)}
    row = bench_stealing(kernels, replicas, n_requests, backend, policy)
    print("replicas,devices,steal_rps,residency_rps,speedup,steals,"
          "stolen_requests,hot_share_steal,hot_share_residency")
    print(f"{row['replicas']},{row['devices']},{row['steal_rps']:.1f},"
          f"{row['residency_rps']:.1f},{row['speedup']:.2f},"
          f"{row['steals_total']},{row['stolen_requests']},"
          f"{row['hot_replica_share_steal']:.2f},"
          f"{row['hot_replica_share_residency']:.2f}")
    print(f"# work stealing vs residency-only on a "
          f"{row['hot_fraction']:.0%}-skewed backlog "
          f"(hot replica {row['hot_replica']}, {row['replicas']} replicas "
          f"on {row['devices']} devices, sharing {row['device_sharing']}): "
          f"{row['speedup']:.2f}x; hot replica's request share "
          f"{row['hot_replica_share_residency']:.0%} -> "
          f"{row['hot_replica_share_steal']:.0%}; results bit-identical "
          f"to the single-bank oracle")
    _print_fleet_stats("steal arm", row["stats_steal"])
    _print_fleet_stats("residency arm", row["stats_residency"])
    if json_path:
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        slim = {k: v for k, v in row.items()
                if k not in ("stats_steal", "stats_residency")}
        with open(json_path, "w") as f:
            json.dump(slim, f, indent=1)
        print(f"# wrote {json_path}")
    assert row["steals_total"] >= 1, (
        "work stealing never triggered on a skewed backlog", row)
    assert row["steal_rps"] >= row["residency_rps"] * tolerance, (
        "work stealing did not beat residency-only routing",
        row["steal_rps"], row["residency_rps"], tolerance)
    assert (row["hot_replica_share_steal"]
            < row["hot_replica_share_residency"]), (
        "stealing left the hot replica's request share unchanged", row)


def run():
    kernels = {n: compile_program(benchmark(n))
               for n in BENCH_NAMES + ("gradient",)}
    reqs = _workload(kernels, N_REQUESTS)
    rps_bank, retraces = bench_bank(kernels, reqs)
    rps_load = bench_per_call_load(kernels, reqs)
    rps_jit = bench_spatial_recompile(reqs)
    rows = [("bank_dispatch", round(rps_bank, 1), retraces),
            ("per_call_load", round(rps_load, 1), "-"),
            ("spatial_recompile", round(rps_jit, 1), "-")]
    return ("path,requests_per_sec,retraces_after_warmup".split(","),
            rows, rps_bank, rps_load, rps_jit, retraces)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--percentiles", action="store_true",
                    help="latency percentile + fairness study "
                         "(pipelined vs synchronous drain)")
    ap.add_argument("--requests-per-tenant", type=int, default=100,
                    help="per-tenant request count for --percentiles")
    ap.add_argument("--tolerance", type=float, default=1.0,
                    help="win-assertion slack on noisy shared runners "
                         "(applies to --percentiles and --replicas)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="run the sharded study with this many replicas "
                         "(0 = off); set JAX_DEVICES=N for N fake devices")
    ap.add_argument("--steal", action="store_true",
                    help="run the work-stealing study (uses --replicas, "
                         "default 4) instead of the sharded study")
    ap.add_argument("--autoscale", action="store_true",
                    help="run the elastic-autoscaling study (bursty "
                         "trace, elastic vs static fleet)")
    ap.add_argument("--max-replicas", type=int, default=4,
                    help="autoscaler ceiling / static-fleet size for "
                         "--autoscale")
    ap.add_argument("--requests", type=int, default=240,
                    help="requests per drain for --replicas/--steal")
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"),
                    help="executor backend for --replicas/--steal (pallas "
                         "runs in interpret mode off-TPU)")
    ap.add_argument("--policy", default=None,
                    choices=("drr", "coalesce", "dynamic"),
                    help="round-formation policy for the serving studies "
                         "(default: REPRO_ROUND_POLICY env or drr)")
    ap.add_argument("--json", default=None,
                    help="dump the --replicas/--steal study row to this "
                         "JSON path")
    args = ap.parse_args(argv)
    if args.autoscale:
        return autoscale_main(args.max_replicas, args.requests,
                              args.backend, args.tolerance, args.json,
                              args.policy)
    if args.steal:
        return stealing_main(args.replicas or 4, args.requests,
                             args.backend, args.tolerance, args.json,
                             args.policy)
    if args.replicas:
        return sharded_main(args.replicas, args.requests, args.backend,
                            args.tolerance, args.json, args.policy)
    if args.percentiles:
        return percentiles_main(args.requests_per_tenant, args.tolerance,
                                args.policy)
    header, rows, rps_bank, rps_load, rps_jit, retraces = run()
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    print(f"# bank vs per-call load: {rps_bank / rps_load:.1f}x; "
          f"bank vs recompile: {rps_bank / rps_jit:.0f}x")
    assert retraces == 0, "bank path retraced after warmup"
    assert rps_bank > rps_load, (rps_bank, rps_load)
    assert rps_bank > rps_jit, (rps_bank, rps_jit)


if __name__ == "__main__":
    main()
