"""Multi-tenant serving benchmark: context bank vs per-call load vs recompile.

The paper's area/switch argument at request scale: one resident executor
serving N kernels should beat (a) rebuilding + re-uploading a context per
request (``Overlay.load`` each call) and by orders of magnitude (b) the
vendor-flow analogue (``spatial_jit``: fresh XLA trace + compile per
kernel).  Reports requests/sec over a mixed-kernel workload.

Run: PYTHONPATH=src python -m benchmarks.multi_tenant
"""

import time

import jax
import numpy as np

from repro.core.overlay import (Overlay, compile_program, spatial_jit)
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.core import vm as vm_mod
from repro.launch.serve import OverlayServer

REQ_BATCH = 256
N_REQUESTS = 36          # mixed round-robin over the 9 paper kernels
RECOMPILE_REQUESTS = 6   # XLA compile per request is ~seconds; sample it


def _workload(kernels, n_requests, seed=0):
    rng = np.random.RandomState(seed)
    names = list(kernels)
    reqs = []
    for i in range(n_requests):
        k = kernels[names[i % len(names)]]
        xs = [rng.uniform(-2, 2, (REQ_BATCH,)).astype(np.float32)
              for _ in k.dfg.inputs]
        reqs.append((k, xs))
    return reqs


def _block(outs):
    jax.block_until_ready([y for ys in outs for y in ys])


#: timed repetitions per path — the CI smoke job runs on noisy shared
#: runners, so a single timed rep would make the win-assertions flaky
TIMED_REPS = 3


def bench_bank(kernels, reqs) -> tuple[float, int]:
    srv = OverlayServer(bank_capacity=len(kernels))
    for k, xs in reqs:
        srv.submit(k, xs)
    _ = srv.flush()                      # warmup: compiles the bucket
    n0 = vm_mod.vm_exec_multi._cache_size()
    dts = []
    for _rep in range(TIMED_REPS):
        for k, xs in reqs:
            srv.submit(k, xs)
        t0 = time.perf_counter()
        results = srv.flush()
        _block(list(results.values()))
        dts.append(time.perf_counter() - t0)
    retraces = vm_mod.vm_exec_multi._cache_size() - n0
    return len(reqs) / sorted(dts)[len(dts) // 2], retraces


def bench_per_call_load(kernels, reqs) -> float:
    ov = Overlay()
    k0, xs0 = reqs[0]
    _block([ov(ov.load(k0), xs0)])       # warmup the single-context executor
    dts = []
    for _rep in range(TIMED_REPS):
        t0 = time.perf_counter()
        outs = [ov(ov.load(k), xs) for k, xs in reqs]
        _block(outs)
        dts.append(time.perf_counter() - t0)
    return len(reqs) / sorted(dts)[len(dts) // 2]


def bench_spatial_recompile(reqs) -> float:
    t0 = time.perf_counter()
    outs = []
    for k, xs in reqs[:RECOMPILE_REQUESTS]:
        fn = spatial_jit(k.dfg)          # fresh trace + XLA compile each time
        outs.append(fn(xs))
        fn._clear_cache()
    _block(outs)
    return RECOMPILE_REQUESTS / (time.perf_counter() - t0)


def run():
    kernels = {n: compile_program(benchmark(n))
               for n in BENCH_NAMES + ("gradient",)}
    reqs = _workload(kernels, N_REQUESTS)
    rps_bank, retraces = bench_bank(kernels, reqs)
    rps_load = bench_per_call_load(kernels, reqs)
    rps_jit = bench_spatial_recompile(reqs)
    rows = [("bank_dispatch", round(rps_bank, 1), retraces),
            ("per_call_load", round(rps_load, 1), "-"),
            ("spatial_recompile", round(rps_jit, 1), "-")]
    return ("path,requests_per_sec,retraces_after_warmup".split(","),
            rows, rps_bank, rps_load, rps_jit, retraces)


def main():
    header, rows, rps_bank, rps_load, rps_jit, retraces = run()
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    print(f"# bank vs per-call load: {rps_bank / rps_load:.1f}x; "
          f"bank vs recompile: {rps_bank / rps_jit:.0f}x")
    assert retraces == 0, "bank path retraced after warmup"
    assert rps_bank > rps_load, (rps_bank, rps_load)
    assert rps_bank > rps_jit, (rps_bank, rps_jit)


if __name__ == "__main__":
    main()
