"""Train+serve co-scheduling study: what does a training tenant cost?

The paper's overlay shares one DSP-block datapath across operations by
time-multiplexing; PR 10's ``TrainingTenant`` shares one SERVING engine
across a latency tier (inference requests) and a bulk tier (a training
run sliced into micro-rounds, ``launch.trainer_tenant``).  This study
prices that sharing with a PAIRED experiment at matched serving load:

- DEDICATED arm: a serving-only engine drives the request sequence
  (control p99), and a standalone ``run_training`` loop on the same
  seed/step-fn measures un-contended training throughput;
- CO-SCHEDULED arm: the SAME engine config plus a ``TrainingTenant``
  drives the IDENTICAL request sequence — training only runs in rounds
  the latency tier left idle (``sched.preempt.PreemptibleTier``).

Both arms run ``max_inflight=1`` so a latency round's delivery stamp is
never deferred behind an overlapping bulk launch — the p99 comparison
measures SCHEDULING, not pipelining overlap.

Asserted (the ISSUE-10 contract):

- serving p99 under co-scheduling degrades < 10% x ``--tolerance``
  against the dedicated control (median per-arm p99 across ``--reps``
  paired repetitions, plus a small absolute ``--p99-floor-ms`` slack
  that only matters at CPU-runner sub-ms latencies);
- training makes monotonic loss progress while co-scheduled (median of
  the last window < median of the first).

Headline rows for the bench trajectory ledger: ``--json`` gets
``train_steps_per_s_cosched`` (higher is better) and ``--json-p99``
gets ``serve_p99_under_train`` (ms, LOWER is better — the ledger's
first latency-style lane).

Run: PYTHONPATH=src python -m benchmarks.train_serve_study [--smoke] \
         [--json artifacts/bench/train_serve.json] \
         [--json-p99 artifacts/bench/train_serve_p99.json]
Reading the output: docs/SCHEDULING.md#the-preemptible-tier.
"""

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.overlay import compile_program
from repro.core.paper_bench import benchmark
from repro.data.pipeline import DataConfig
from repro.launch.serve import OverlayServer
from repro.launch.train import run_training
from repro.launch.trainer_tenant import TrainingTenant
from repro.runtime import optim as O
from repro.runtime.steps import make_train_step

SERVE_TENANT = "lat0"
SERVE_BATCH = 64


def _requests(kernels, n_beats, seed=0):
    """The matched serving load: one latency request per beat, same
    bytes in both arms."""
    rng = np.random.RandomState(seed)
    names = sorted(kernels)
    plan = []
    for beat in range(n_beats):
        k = kernels[names[beat % len(names)]]
        xs = [rng.uniform(-2, 2, (SERVE_BATCH,)).astype(np.float32)
              for _ in k.dfg.inputs]
        plan.append((k, xs))
    return plan


def _server(kernels):
    return OverlayServer(bank_capacity=max(4, len(kernels)),
                         round_kernels=2, max_inflight=1)


def _warm(srv, plan):
    """Compile every serving bucket, then zero the latency records."""
    for k, xs in plan[: len({id(k) for k, _ in plan})]:
        srv.submit(k, xs, tenant=SERVE_TENANT)
    srv.flush()
    srv.reset_metrics()


def dedicated_arm(kernels, plan, cfg, oc, dc, steps, step_fn):
    """Control: serving alone on the engine, training alone off it."""
    srv = _server(kernels)
    _warm(srv, plan)
    t0 = time.perf_counter()
    for k, xs in plan:
        t = srv.submit(k, xs, tenant=SERVE_TENANT)
        res = srv.flush()
        assert t in res
    serve_wall = time.perf_counter() - t0
    p99 = srv.tenant_latency_percentiles()[SERVE_TENANT]["p99"]

    losses = []
    t0 = time.perf_counter()
    for rec in run_training(cfg, oc, dc, steps=steps, step_fn=step_fn):
        losses.append(rec["loss"])
    train_wall = time.perf_counter() - t0
    return {"serve_p99_s": p99, "serve_wall_s": serve_wall,
            "train_steps_per_s": steps / train_wall, "losses": losses}


def cosched_arm(kernels, plan, cfg, oc, dc, steps, step_fn, yield_every):
    """Treatment: the same serving sequence with the training tenant
    riding the idle rounds of the same engine."""
    srv = _server(kernels)
    _warm(srv, plan)
    tenant = TrainingTenant(srv, cfg, oc, dc, steps=steps,
                            yield_every=yield_every, step_fn=step_fn)
    t0 = time.perf_counter()
    for k, xs in plan:
        t = srv.submit(k, xs, tenant=SERVE_TENANT)
        tenant.tick()
        res = srv.flush()
        assert t in res, "serving request starved by training"
    while not tenant.done:          # drain the training tail, engine idle
        tenant.tick()
        srv.flush()
    wall = time.perf_counter() - t0
    p99 = srv.tenant_latency_percentiles()[SERVE_TENANT]["p99"]
    st = tenant.stats()
    return {"serve_p99_s": p99, "wall_s": wall,
            "train_steps_per_s": st["steps"] / wall,
            "losses": list(tenant.losses), "stats": st,
            "bulk_rounds": srv.round_policy.n_bulk_rounds,
            "latency_rounds": srv.round_policy.n_latency_rounds}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small model + short run for CI")
    ap.add_argument("--steps", type=int, default=None,
                    help="training steps (default 24 smoke / 48 full)")
    ap.add_argument("--beats", type=int, default=None,
                    help="serving requests (default 16 smoke / 64 full)")
    ap.add_argument("--yield-every", type=int, default=2,
                    help="micro-round size (steps) for the tenant")
    ap.add_argument("--reps", type=int, default=3,
                    help="paired repetitions; the gate compares the "
                         "MEDIAN per-arm p99 across reps")
    ap.add_argument("--tolerance", type=float, default=1.0,
                    help="scales the 10%% p99-degradation gate for "
                         "noisy shared runners")
    ap.add_argument("--p99-floor-ms", type=float, default=1.0,
                    help="absolute slack added to the p99 ceiling — "
                         "absorbs sub-ms scheduler jitter on CPU "
                         "runners, negligible at accelerator latencies")
    ap.add_argument("--json", default=None,
                    help="summary row (headline train_steps_per_s_cosched)")
    ap.add_argument("--json-p99", default=None,
                    help="latency row (headline serve_p99_under_train, ms)")
    args = ap.parse_args(argv)

    beats = args.beats or (16 if args.smoke else 48)
    # training spans every serving beat (one micro-round per beat), so
    # the p99 really is measured UNDER training, not after it drained
    steps = args.steps or beats * args.yield_every
    cfg = get_smoke_config("deepseek-7b")
    oc = O.OptConfig(lr=3e-3, warmup_steps=2, total_steps=max(steps, 10))
    dc = DataConfig(global_batch=2, seq_len=32, vocab=cfg.vocab)
    step_fn = jax.jit(make_train_step(cfg, oc))
    kernels = {n: compile_program(benchmark(n))
               for n in ("poly5", "chebyshev", "sgfilter")}
    plan = _requests(kernels, beats)

    print(f"# train+serve study: {steps} steps, {beats} serving beats, "
          f"yield_every={args.yield_every}")
    # compile the train step OUTSIDE both arms' timers, so the paired
    # walls compare steady-state scheduling, not who paid the jit
    for _ in run_training(cfg, oc, dc, steps=2, step_fn=step_fn):
        pass
    ded_reps, cos_reps = [], []
    for rep in range(max(1, args.reps)):
        ded_reps.append(
            dedicated_arm(kernels, plan, cfg, oc, dc, steps, step_fn))
        cos_reps.append(
            cosched_arm(kernels, plan, cfg, oc, dc, steps, step_fn,
                        args.yield_every))
    med = lambda rows, key: float(np.median([r[key] for r in rows]))  # noqa: E731
    ded = dict(ded_reps[0], serve_p99_s=med(ded_reps, "serve_p99_s"),
               train_steps_per_s=med(ded_reps, "train_steps_per_s"))
    cos = dict(cos_reps[0], serve_p99_s=med(cos_reps, "serve_p99_s"),
               train_steps_per_s=med(cos_reps, "train_steps_per_s"))

    degrade = (cos["serve_p99_s"] - ded["serve_p99_s"]) / ded["serve_p99_s"]
    efficiency = cos["train_steps_per_s"] / ded["train_steps_per_s"]
    w = max(2, steps // 4)
    first = float(np.median(cos["losses"][:w]))
    last = float(np.median(cos["losses"][-w:]))
    st = cos["stats"]
    print(f"serve p99: dedicated {ded['serve_p99_s'] * 1e3:.2f}ms, "
          f"co-scheduled {cos['serve_p99_s'] * 1e3:.2f}ms "
          f"({degrade:+.1%})")
    print(f"train steps/s: dedicated {ded['train_steps_per_s']:.2f}, "
          f"co-scheduled {cos['train_steps_per_s']:.2f} "
          f"(efficiency {efficiency:.2f})")
    print(f"loss: first-window median {first:.4f} -> "
          f"last-window median {last:.4f}")
    print(f"rounds: {cos['latency_rounds']} latency / "
          f"{cos['bulk_rounds']} bulk; preemptions {st['preemptions']}, "
          f"resumes {st['resumes']}")

    summary = {
        "train_steps_per_s_cosched": cos["train_steps_per_s"],
        "train_steps_per_s_dedicated": ded["train_steps_per_s"],
        "cosched_efficiency": efficiency,
        "serve_p99_under_train_ms": cos["serve_p99_s"] * 1e3,
        "serve_p99_dedicated_ms": ded["serve_p99_s"] * 1e3,
        "p99_degrade_frac": degrade,
        "train_steps": st["steps"],
        "preemptions": st["preemptions"],
        "resumes": st["resumes"],
        "loss_first": first,
        "loss_last": last,
        "beats": beats,
        "yield_every": args.yield_every,
    }
    for path, row in ((args.json, summary),
                      (args.json_p99,
                       {"serve_p99_under_train": cos["serve_p99_s"] * 1e3,
                        "serve_p99_dedicated_ms": ded["serve_p99_s"] * 1e3,
                        "p99_degrade_frac": degrade})):
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            with open(path, "w") as f:
                json.dump(row, f, indent=1)
            print(f"# wrote {path}")

    assert st["steps"] == steps, (st["steps"], steps)
    assert last < first, (
        "training made no loss progress while co-scheduled", first, last)
    gate = 0.10 * args.tolerance
    ceiling = (ded["serve_p99_s"] * (1.0 + gate)
               + args.p99_floor_ms * 1e-3)
    assert cos["serve_p99_s"] <= ceiling, (
        f"serving p99 degraded {degrade:.1%} under the training tenant "
        f"(gate {gate:.0%} + {args.p99_floor_ms}ms floor): dedicated "
        f"{ded['serve_p99_s'] * 1e3:.2f}ms -> co-scheduled "
        f"{cos['serve_p99_s'] * 1e3:.2f}ms "
        f"(ceiling {ceiling * 1e3:.2f}ms)")
    print("train_serve_study: PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
